"""Persistent LSM backend: WAL replay after simulated crashes, spill +
compaction combiner semantics, scan agreement with EdgeStore, registry
dispatch, binding consistency, and the end-to-end kill-after-flush
pipeline recovery acceptance run."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.assoc import Assoc
from repro.db import (DB, BACKENDS, EdgeStore, LSMMultiInstanceDB,
                      LSMStore, MultiInstanceDB, bind, make_backend, put)
from repro.pipeline import PipelineConfig, TrafficConfig, run_pipeline


def rand_triples(seed, n=200, n_rows=40, n_cols=12):
    rng = np.random.default_rng(seed)
    r = np.asarray([f"p{i:03d}" for i in rng.integers(0, n_rows, n)])
    c = np.asarray([f"ip.dst|{i}" if i % 2 else f"ip.src|{i}"
                    for i in rng.integers(0, n_cols, n)])
    v = rng.integers(0, 9, n).astype(str)
    return r, c, v


def snapshot(store, transpose=False):
    return [(k, tuple(sorted(cells.items())))
            for k, cells in store.scan_everything(transpose=transpose)]


def degrees(store):
    return {k: v for k, v in store.degree_items()}


class TestWALRecovery:
    def test_reopen_replays_synced_writes(self, tmp_path):
        d = str(tmp_path / "lsm")
        s = LSMStore(d)
        r, c, v = rand_triples(0)
        s.put_triples(r, c, v)
        s.sync()
        # crash: abandon without close(); reopen from disk
        s2 = LSMStore(d)
        assert snapshot(s2) == snapshot(s)
        assert snapshot(s2, transpose=True) == snapshot(s, transpose=True)
        assert degrees(s2) == degrees(s)
        assert s2.n_entries == s.n_entries == len(r)

    def test_torn_wal_tail_truncated(self, tmp_path):
        """Kill *before* fsync completes: the WAL's last frame is torn;
        replay keeps every whole frame and drops the tail."""
        d = str(tmp_path / "lsm")
        s = LSMStore(d)
        s.put_triples(*[np.asarray(x) for x in
                        (["p1"], ["ip.dst|a"], ["1"])])
        s.sync()
        s.put_triples(*[np.asarray(x) for x in
                        (["p2"], ["ip.dst|b"], ["1"])])
        s.close()
        wal = os.path.join(d, "wal.log")
        with open(wal, "r+b") as f:
            f.seek(0, os.SEEK_END)
            f.truncate(f.tell() - 3)        # tear the second frame
        s2 = LSMStore(d)
        assert s2.row("p1") == {"ip.dst|a": "1"}
        assert s2.row("p2") == {}           # torn frame dropped
        assert s2.degree("ip.dst|b") == 0.0
        # and the store keeps working after recovery
        s2.put_triples(*[np.asarray(x) for x in
                         (["p3"], ["ip.dst|c"], ["1"])])
        s2.sync()
        assert LSMStore(d).row("p3") == {"ip.dst|c": "1"}

    def test_corrupt_frame_stops_replay(self, tmp_path):
        d = str(tmp_path / "lsm")
        s = LSMStore(d)
        s.put_triples(*[np.asarray(x) for x in
                        (["p1"], ["ip.dst|a"], ["1"])])
        s.put_triples(*[np.asarray(x) for x in
                        (["p2"], ["ip.dst|b"], ["1"])])
        s.close()
        wal = os.path.join(d, "wal.log")
        size = os.path.getsize(wal)
        with open(wal, "r+b") as f:         # flip a payload byte in frame 2
            f.seek(size - 6)
            b = f.read(1)
            f.seek(size - 6)
            f.write(bytes([b[0] ^ 0xFF]))
        s2 = LSMStore(d)
        assert s2.row("p1") == {"ip.dst|a": "1"}
        assert s2.row("p2") == {}

    def test_wal_resets_after_spill(self, tmp_path):
        """Spilled mutations live in the run, not the WAL — reopen must
        not double-apply them."""
        d = str(tmp_path / "lsm")
        s = LSMStore(d, memtable_limit=50)
        r, c, v = rand_triples(1, n=120)
        s.put_triples(r[:60], c[:60], v[:60])   # triggers a spill
        assert s.n_runs >= 1
        s.put_triples(r[60:], c[60:], v[60:])
        s.sync()
        s2 = LSMStore(d)
        assert degrees(s2) == degrees(s)
        assert s2.n_entries == 120


class TestSpillCompaction:
    def test_spill_preserves_scans_and_degrees(self, tmp_path):
        s = LSMStore(str(tmp_path / "a"), memtable_limit=10 ** 9)
        e = EdgeStore(n_tablets=4)
        r, c, v = rand_triples(2)
        s.put_triples(r, c, v)
        e.put_triples(r, c, v)
        before = snapshot(s)
        s.spill()
        assert s.n_runs == 1 and s._mem.n_mutations == 0
        assert snapshot(s) == before == snapshot(e)
        assert degrees(s) == degrees(e)

    def test_compaction_sums_degrees_and_keeps_newest_cell(self, tmp_path):
        s = LSMStore(str(tmp_path / "a"))
        for val in ("old", "mid", "new"):
            s.put_triples(np.asarray(["p1"]), np.asarray(["ip.dst|a"]),
                          np.asarray([val]))
            s.spill()                        # one run per version
        assert s.n_runs == 3
        s.compact()
        assert s.n_runs == 1
        assert s.row("p1") == {"ip.dst|a": "new"}    # newest run won
        assert s.degree("ip.dst|a") == 3.0           # combiner summed
        assert s.n_entries == 3

    def test_auto_compaction_bounds_runs(self, tmp_path):
        s = LSMStore(str(tmp_path / "a"), memtable_limit=5, max_runs=3)
        r, c, v = rand_triples(3, n=200)
        for lo in range(0, 200, 5):
            s.put_triples(r[lo:lo + 5], c[lo:lo + 5], v[lo:lo + 5])
        assert s.n_runs <= 4                 # bounded by max_runs + 1
        e = EdgeStore(n_tablets=2)
        e.put_triples(r, c, v)
        assert snapshot(s) == snapshot(e)
        assert degrees(s) == degrees(e)

    def test_reopen_after_compaction(self, tmp_path):
        d = str(tmp_path / "a")
        s = LSMStore(d)
        r, c, v = rand_triples(4)
        s.put_triples(r, c, v)
        s.spill()
        s.put_triples(r, c, v)               # second tier re-puts all
        s.spill()
        s.compact()
        expected = snapshot(s)
        s.close()
        s2 = LSMStore(d)
        assert snapshot(s2) == expected
        assert s2.degree(c[0]) == s.degree(c[0])


class TestScanAgreement:
    """Property-style cross-check: LSMStore and EdgeStore are
    observationally identical over identical triples."""

    @pytest.mark.parametrize("seed", range(5))
    def test_scans_agree_with_edgestore(self, tmp_path, seed):
        s = LSMStore(str(tmp_path / f"lsm{seed}"),
                     memtable_limit=70)       # force mixed mem/run reads
        e = EdgeStore(n_tablets=3)
        r, c, v = rand_triples(seed, n=250)
        for lo in range(0, 250, 50):          # batched, interleaved spills
            s.put_triples(r[lo:lo + 50], c[lo:lo + 50], v[lo:lo + 50])
            e.put_triples(r[lo:lo + 50], c[lo:lo + 50], v[lo:lo + 50])
        for t in (False, True):
            assert snapshot(s, t) == snapshot(e, t)
            lo_k, hi_k = ("p005", "p025") if not t else ("ip.dst|", "ip.src|5")
            assert list(s.scan_key_range(lo_k, hi_k, transpose=t)) == \
                list(e.scan_key_range(lo_k, hi_k, transpose=t))
            assert list(s.scan_prefix("p01" if not t else "ip.dst|",
                                      transpose=t)) == \
                list(e.scan_prefix("p01" if not t else "ip.dst|",
                                   transpose=t))
            assert list(s.scan_keys([r[0], r[7], "absent"], transpose=t)) \
                == list(e.scan_keys([r[0], r[7], "absent"], transpose=t))
        assert degrees(s) == degrees(e)
        assert sorted(s.keys_with_prefix("ip.dst|")) == \
            sorted(e.keys_with_prefix("ip.dst|"))
        for key in set(c[:20]):
            assert s.degree(key) == e.degree(key)
        assert s.connections("3") == e.connections("3")

    def test_put_degree_matches_edgestore(self, tmp_path):
        s = LSMStore(str(tmp_path / "lsm"))
        e = EdgeStore(n_tablets=2)
        Edeg = Assoc("ip.dst|a,ip.dst|b,", "degree,degree,",
                     np.asarray([3.0, 4.0]))
        s.put_degree(Edeg)
        e.put_degree(Edeg)
        assert degrees(s) == degrees(e)


class TestRegistry:
    def test_memory_dispatch(self):
        assert isinstance(DB("Tedge").backend, EdgeStore)
        assert isinstance(DB("Tedge", n_instances=3).backend,
                          MultiInstanceDB)

    def test_lsm_dispatch(self, tmp_path):
        T = DB("Tedge", backend="lsm", path=str(tmp_path / "a"))
        assert isinstance(T.backend, LSMStore)
        M = DB("Tedge", backend="lsm", path=str(tmp_path / "b"),
               n_instances=2)
        assert isinstance(M.backend, LSMMultiInstanceDB)
        assert len(M.backend.instances) == 2
        assert os.path.isdir(str(tmp_path / "b" / "db1"))

    def test_lsm_requires_path(self):
        with pytest.raises(ValueError, match="path"):
            DB("Tedge", backend="lsm")

    def test_memory_rejects_path(self, tmp_path):
        with pytest.raises(ValueError, match="volatile"):
            DB("Tedge", backend="memory", path=str(tmp_path))

    def test_unknown_backend(self):
        with pytest.raises(ValueError, match="unknown backend"):
            DB("Tedge", backend="nope")

    def test_backend_options_forwarded(self, tmp_path):
        T = DB("Tedge", backend="lsm", path=str(tmp_path / "a"),
               memtable_limit=7)
        assert T.backend.memtable_limit == 7

    def test_custom_registration(self):
        BACKENDS["_test"] = lambda **kw: EdgeStore(n_tablets=1)
        try:
            assert isinstance(make_backend("_test"), EdgeStore)
        finally:
            del BACKENDS["_test"]


class TestBindingOnLSM:
    def test_query_after_put_consistency(self, tmp_path):
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm",
               path=str(tmp_path / "a"), n_instances=2)
        E = Assoc("p1,p1,p2,p3,", "ip.dst|a,ip.src|b,ip.dst|a,ip.dst|c,",
                  "1,1,1,1,")
        put(T, E, sync=False)
        # query-after-put: the binding read flushes (and fsyncs) first
        assert T[:, "ip.dst|*,"].eval().nnz == 3
        assert T.degree("ip.dst|a") == 2.0
        assert T["p1,", :].eval().nnz == 2
        assert T["p1,:,p2,", :].eval().nnz == 3
        r, _, v = T.degree_assoc("ip.dst|").triples()
        assert dict(zip(r, np.asarray(v, float)))["ip.dst|c"] == 1.0
        T.close()

    def test_scan_cache_invalidation_on_lsm(self, tmp_path):
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm",
               path=str(tmp_path / "a"))
        put(T, Assoc("p1,", "ip.dst|a,", "1,"))
        assert T[:, "ip.dst|*,"].eval().nnz == 1
        T.backend.put(Assoc("p2,", "ip.dst|a,", "1,"))   # direct store put
        assert T[:, "ip.dst|*,"].eval().nnz == 2         # evicted, rescanned
        T.close()

    def test_close_syncs_without_pool(self, tmp_path):
        """Sync puts never create a writer pool; close() must still be
        a commit point on a durable backend."""
        d = str(tmp_path / "a")
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=d)
        put(T, Assoc("p1,", "ip.dst|a,", "1,"))   # sync=True, poolless
        T.close()
        assert T.backend.n_syncs >= 1

    def test_flush_is_durability_point(self, tmp_path):
        d = str(tmp_path / "a")
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=d)
        put(T, Assoc("p1,", "ip.dst|a,", "1,"), sync=False)
        T.flush()
        assert T.backend.n_syncs >= 1
        # abandon (simulated crash) and reopen: the flushed write survived
        T2 = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=d)
        assert T2[:, :].eval().nnz == 1
        assert T2.degree("ip.dst|a") == 1.0


class TestCrossProcessRouting:
    CHILD = ("import sys; sys.path.insert(0, sys.argv[2]); "
             "from repro.db import DB, put; "
             "from repro.core.assoc import Assoc; "
             "T = DB('Tedge', 'TedgeT', 'TedgeDeg', backend='lsm', "
             "path=sys.argv[1], n_instances=4); "
             "put(T, Assoc('p1,', 'ip.dst|a,', sys.argv[3] + ',')); "
             "T.close()")

    def run_child(self, dbdir, value, seed):
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        subprocess.run(
            [sys.executable, "-c", self.CHILD, dbdir, src, value],
            env={**os.environ, "PYTHONHASHSEED": seed},
            check=True, timeout=120)

    def test_instance_placement_stable_across_processes(self, tmp_path):
        """Routing uses a process-stable hash: updates to one row from
        differently-salted interpreters land in the same instance
        directory, so last-write-wins survives restarts."""
        d = str(tmp_path / "m")
        self.run_child(d, "old", "1")
        self.run_child(d, "new", "2")      # different hash salt
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=d,
               n_instances=4)
        assert sum(1 for i in T.backend.instances if i.n_entries) == 1
        _, _, v = T["p1,", :].eval().triples()
        assert list(v) == ["new"]


_CRASH_CHILD = r"""
import os, sys
sys.path.insert(0, sys.argv[4])
from repro.db import DB
from repro.pipeline import PipelineConfig, TrafficConfig, run_pipeline

workdir, dbdir, backend = sys.argv[1], sys.argv[2], sys.argv[3]
cfg = PipelineConfig(workdir=workdir, n_files=2, duration_per_file_s=1.0,
                     traffic=TrafficConfig(n_hosts=64, pkt_rate=500.0,
                                           seed=6), n_workers=2)
T = DB("Tedge", "TedgeT", "TedgeDeg", backend=backend,
       path=(dbdir if backend == "lsm" else None), n_instances=2)
stats = run_pipeline(cfg, T.backend)
print("ENTRIES", stats["db_entries"], flush=True)
os._exit(17)   # kill after the flush barrier: no close(), no atexit
"""


class TestPipelineCrashRecovery:
    def test_lsm_recovers_full_ingest_after_kill(self, tmp_path):
        """Acceptance: full stage-6 ingest through the async writer pool
        against backend='lsm', process killed right after the flush
        barrier; reopening recovers every entry — counts and degree sums
        match an identical in-memory run exactly."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        dbdir = str(tmp_path / "lsmdb")
        out = subprocess.run(
            [sys.executable, "-c", _CRASH_CHILD, str(tmp_path / "w_lsm"),
             dbdir, "lsm", src],
            capture_output=True, text=True, timeout=300)
        assert out.returncode == 17, out.stderr
        entries = int(out.stdout.split("ENTRIES")[1].split()[0])
        assert entries > 0

        # reference: the same pipeline against the in-memory backend
        mem = MultiInstanceDB(n_instances=2, tablets_per_instance=4)
        cfg = PipelineConfig(workdir=str(tmp_path / "w_mem"), n_files=2,
                             duration_per_file_s=1.0,
                             traffic=TrafficConfig(n_hosts=64,
                                                   pkt_rate=500.0, seed=6),
                             n_workers=2)
        run_pipeline(cfg, mem)

        # reopen the killed store: WAL replay must recover everything
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=dbdir,
               n_instances=2)
        assert T.n_entries == entries == mem.n_entries
        assert degrees(T.backend) == degrees(mem)
        # column-query analytics agree cell-for-cell
        a = T[:, "ip.dst|*,"].eval()
        b = bind(mem, cache_ttl=0)[:, "ip.dst|*,"].eval()
        assert a.triples()[0].tolist() == b.triples()[0].tolist()
        assert a.triples()[1].tolist() == b.triples()[1].tolist()
        # journal committed at the barrier: a restart re-ingests nothing
        T2 = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=dbdir,
                n_instances=2)
        run_pipeline(dataclasses.replace(cfg,
                                         workdir=str(tmp_path / "w_lsm")),
                     T2.backend)
        assert T2.n_entries == entries


from _hyp import given, settings, st  # hypothesis, skipping when absent


class TestLSMProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 6),
                              st.integers(0, 3)),
                    min_size=1, max_size=60),
           st.integers(1, 40))
    def test_random_triples_agree_with_edgestore(self, trip, limit,
                                                 tmp_path_factory):
        d = str(tmp_path_factory.mktemp("lsm"))
        s = LSMStore(d, memtable_limit=limit)
        e = EdgeStore(n_tablets=2)
        r = np.asarray([f"p{a:02d}" for a, _, _ in trip])
        c = np.asarray([f"f|{b}" for _, b, _ in trip])
        v = np.asarray([str(x) for _, _, x in trip])
        s.put_triples(r, c, v)
        e.put_triples(r, c, v)
        assert snapshot(s) == snapshot(e)
        assert snapshot(s, True) == snapshot(e, True)
        assert degrees(s) == degrees(e)
        s.sync()
        assert snapshot(LSMStore(d)) == snapshot(e)
