"""Checkpoint manager: atomicity, restart, async, resharding."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as C


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.asarray(2.5)}}


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = tree()
        C.save(str(tmp_path), 3, t, {"step": 3, "note": "x"})
        back, meta = C.restore(str(tmp_path), t)
        assert meta["note"] == "x"
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        assert C.latest_step(str(tmp_path)) is None
        C.save(str(tmp_path), 1, tree())
        C.save(str(tmp_path), 5, tree())
        assert C.latest_step(str(tmp_path)) == 5

    def test_uncommitted_ignored(self, tmp_path):
        C.save(str(tmp_path), 1, tree())
        d = os.path.join(str(tmp_path), "step_00000009")
        os.makedirs(d)                       # no COMMITTED marker
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{}")
        assert C.latest_step(str(tmp_path)) == 1

    def test_crash_mid_save_keeps_previous(self, tmp_path):
        C.save(str(tmp_path), 1, tree(1))
        # simulate crash: a .tmp dir left behind
        os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
        back, _ = C.restore(str(tmp_path), tree())
        assert C.latest_step(str(tmp_path)) == 1

    def test_multi_shard(self, tmp_path):
        big = {"x": jnp.ones((1000, 100)), "y": jnp.ones((1000, 100))}
        C.save(str(tmp_path), 0, big, shard_size=200_000)
        files = os.listdir(os.path.join(str(tmp_path), "step_00000000"))
        assert sum(f.startswith("shard_") for f in files) > 1
        back, _ = C.restore(str(tmp_path), big)
        np.testing.assert_array_equal(np.asarray(back["x"]),
                                      np.ones((1000, 100)))


class TestAsync:
    def test_async_save_and_gc(self, tmp_path):
        saver = C.AsyncCheckpointer(str(tmp_path), keep=2)
        for step in range(5):
            saver.save_async(step, tree(step), {"step": step})
        saver.wait()
        steps = sorted(int(n.split("_")[1])
                       for n in os.listdir(str(tmp_path))
                       if n.startswith("step_"))
        assert steps == [3, 4]

    def test_async_error_surfaces(self, tmp_path):
        blocker = tmp_path / "file"
        blocker.write_text("x")               # a FILE where a dir must go
        saver = C.AsyncCheckpointer(str(blocker / "sub"))
        saver.save_async(0, tree())
        with pytest.raises(BaseException):
            saver.wait()


class TestTrainingResume:
    def test_sampler_and_optstate_roundtrip(self, tmp_path):
        from repro.data import SamplerState
        from repro.train import OptConfig, adamw_init, adamw_update
        params = tree()
        opt = adamw_init(params)
        grads = jax.tree.map(jnp.ones_like, params)
        params2, opt2, _ = adamw_update(params, grads, opt, OptConfig())
        sampler = SamplerState(file_index=3, offset=17, epoch=1)
        C.save(str(tmp_path), 7, (params2, opt2, sampler.to_dict()),
               {"step": 7})
        (p, o, s), meta = C.restore(str(tmp_path),
                                    (params2, opt2, sampler.to_dict()))
        assert int(np.asarray(o["step"])) == 1
        assert int(np.asarray(s["file_index"])) == 3
        assert meta["step"] == 7


class TestElasticRemesh:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Elastic restart: a checkpoint written under one mesh topology
        restores (re-shards) onto a different one — subprocess so this
        process keeps its 1-device view."""
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro import checkpoint as C
            from repro.launch.mesh import make_mesh

            tree = {{"w": jax.numpy.arange(64, dtype=jax.numpy.float32)
                    .reshape(8, 8)}}
            mesh1 = make_mesh((2, 4), ("data", "model"))
            sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
            placed = jax.device_put(tree, sh1)
            C.save(r"{tmp_path}", 0, placed)

            mesh2 = make_mesh((4, 2), ("data", "model"))
            sh2 = {{"w": NamedSharding(mesh2, P("model", "data"))}}
            back, _ = C.restore(r"{tmp_path}", tree, shardings=sh2)
            assert back["w"].sharding == sh2["w"]
            np.testing.assert_array_equal(np.asarray(back["w"]),
                                          np.asarray(tree["w"]))
            print("REMESH_OK")
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "REMESH_OK" in out.stdout
