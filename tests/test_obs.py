"""Observability plane: metrics-registry and tracer units, the
/metrics endpoint, end-to-end trace propagation over the net backend
(gateway → planner → scan → per-shard RPC), the /metrics ↔ T.stats()
identity contract, and WriterPool.stats() coherence under live ingest."""
import gc
import http.client
import json
import threading
import time

import numpy as np
import pytest

from repro.core.expr import launch_counts
from repro.db import DB, EdgeStore, put
from repro.db.writer import WriterPool
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               REGISTRY, obj_label)
from repro.obs.trace import Tracer, current_ctx, span, traced_iter
from repro.serve import Gateway, Tenant, TokenAuth
from repro.serve.app import synthetic_incidence


# ---------------------------------------------------------------------------
# Metrics units.
# ---------------------------------------------------------------------------

class TestCounter:
    def test_inc_and_value(self):
        c = Counter()
        c.inc()
        c.inc(41)
        assert c.value == 42

    def test_concurrent_incs_are_atomic(self):
        c = Counter()
        n_threads, per = 8, 10_000

        def hammer():
            for _ in range(per):
                c.inc()

        ts = [threading.Thread(target=hammer) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value == n_threads * per


class TestGauge:
    def test_set_inc_dec(self):
        g = Gauge()
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value == 6

    def test_set_function_reads_live(self):
        g = Gauge()
        box = [0]
        g.set_function(lambda: box[0])
        box[0] = 7
        assert g.value == 7.0

    def test_dying_owner_never_breaks_scrape(self):
        g = Gauge()
        g.set_function(lambda: (_ for _ in ()).throw(AttributeError("dead")))
        assert g.value == 0.0


class TestHistogram:
    def test_bucket_placement_and_cumulative(self):
        h = Histogram(base=1e-6, n_buckets=4)     # bounds 1,2,4,8 µs
        for v in (1e-6, 3e-6, 3e-6, 100.0):       # last is over-range
            h.observe(v)
        samples = list(h.samples())
        by_le = {extra[0][1]: val for sfx, extra, val in samples
                 if sfx == "_bucket"}
        assert by_le["1e-06"] == 1
        assert by_le["4e-06"] == 3                # cumulative
        assert by_le["8e-06"] == 3                # over-range not in finite
        assert by_le["+Inf"] == 4
        assert h.count == 4
        assert h.sum == pytest.approx(1e-6 + 6e-6 + 100.0)


class TestRegistry:
    def test_get_or_create_idempotent(self):
        reg = Registry()
        a = reg.counter("t_total", "help")
        b = reg.counter("t_total")
        assert a is b

    def test_kind_mismatch_rejected(self):
        reg = Registry()
        reg.counter("t_total")
        with pytest.raises(ValueError):
            reg.gauge("t_total")

    def test_label_schema_enforced(self):
        reg = Registry()
        fam = reg.counter("t_total", labels=("who",))
        with pytest.raises(ValueError):
            fam.labels(other="x")

    def test_weak_children_leave_with_owner(self):
        reg = Registry()
        fam = reg.counter("t_total", "h", labels=("who",))
        child = fam.labels(who="alice")
        child.inc(3)
        assert 'who="alice"' in reg.render()
        del child
        gc.collect()
        assert 'who="alice"' not in reg.render()

    def test_unlabeled_child_is_pinned(self):
        reg = Registry()
        reg.counter("t_total", "h").inc()
        gc.collect()
        assert "t_total 1" in reg.render()

    def test_render_format(self):
        reg = Registry()
        reg.counter("t_total", "things done").inc(2)
        reg.histogram("t_seconds", "latency", base=1e-3, n_buckets=2) \
           .observe(0.0015)
        text = reg.render()
        assert "# HELP t_total things done" in text
        assert "# TYPE t_total counter" in text
        assert "t_total 2" in text
        assert "# TYPE t_seconds histogram" in text
        assert 't_seconds_bucket{le="0.001"} 0' in text
        assert 't_seconds_bucket{le="0.002"} 1' in text
        assert 't_seconds_bucket{le="+Inf"} 1' in text
        assert "t_seconds_count 1" in text
        assert text.endswith("\n")

    def test_as_dict(self):
        reg = Registry()
        fam = reg.counter("t_total", labels=("who",))
        child = fam.labels(who="x")
        child.inc(9)
        assert reg.as_dict()[("t_total", (("who", "x"),))] == 9

    def test_obj_label_unique(self):
        assert obj_label("cache") != obj_label("cache")


# ---------------------------------------------------------------------------
# Tracer units.
# ---------------------------------------------------------------------------

class TestTracerUnits:
    def test_untraced_span_is_shared_noop(self):
        assert current_ctx() is None
        s1, s2 = span("a"), span("b", x=1)
        assert s1 is s2                     # no allocation on the hot path
        with s1 as s:
            s.tag(y=2)                      # all no-ops

    def test_nesting_records_parentage(self):
        tr = Tracer()
        with tr.start("root") as root:
            tid = root.trace_id
            with span("child"):
                with span("grandchild", k="v"):
                    pass
            with span("sibling"):
                pass
        recs = {r["name"]: r for r in tr.spans(tid)}
        assert recs["root"]["parent_id"] == 0
        rid = recs["root"]["span_id"]
        assert recs["child"]["parent_id"] == rid
        assert recs["sibling"]["parent_id"] == rid
        assert recs["grandchild"]["parent_id"] == recs["child"]["span_id"]
        assert recs["grandchild"]["tags"] == {"k": "v"}
        tree = tr.tree(tid)
        assert tree["name"] == "root"
        assert sorted(c["name"] for c in tree["children"]) == \
            ["child", "sibling"]

    def test_error_span_tagged(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.start("root") as root:
                tid = root.trace_id
                with span("boom"):
                    raise RuntimeError("kaput")
        recs = {r["name"]: r for r in tr.spans(tid)}
        assert recs["boom"]["tags"]["error"] == "RuntimeError: kaput"

    def test_traced_iter_records_one_span(self):
        tr = Tracer()
        with tr.start("root") as root:
            tid = root.trace_id
            assert list(traced_iter("gen", iter(range(3)), k="v")) == \
                [0, 1, 2]
        names = [r["name"] for r in tr.spans(tid)]
        assert names.count("gen") == 1

    def test_traced_iter_untraced_passthrough(self):
        assert list(traced_iter("gen", iter(range(3)))) == [0, 1, 2]

    def test_max_spans_drops_and_counts(self):
        tr = Tracer(max_spans=3)
        with tr.start("root") as root:
            tid = root.trace_id
            for i in range(10):
                with span(f"s{i}"):
                    pass
        assert len(tr.spans(tid)) == 3
        assert tr.tree(tid)["dropped"] == 8     # 7 children + the root
        assert tr.stats()["n_spans_dropped"] == 8

    def test_lru_trace_eviction(self):
        tr = Tracer(max_traces=2)
        tids = []
        for i in range(3):
            with tr.start(f"r{i}") as root:
                tids.append(root.trace_id)
        assert tr.tree(tids[0]) is None         # evicted
        assert tr.tree(tids[2]) is not None
        assert tr.stats()["live_traces"] == 2
        assert tr.stats()["n_traces"] == 3

    def test_slow_log_keeps_slowest(self):
        tr = Tracer(slow_log_size=2, slow_threshold_s=0.0)
        tr.note_slow("a", 0.0, 0.5)
        tr.note_slow("b", 0.0, 2.0)
        tr.note_slow("c", 0.0, 1.0)
        tr.note_slow("d", 0.0, 0.1)             # slower than nothing kept
        slow = tr.slow()
        assert [e["name"] for e in slow] == ["b", "c"]
        assert all(e["tree"] is None for e in slow)

    def test_traced_root_over_threshold_keeps_tree(self):
        tr = Tracer(slow_threshold_s=0.0)       # everything is "slow"
        with tr.start("root"):
            with span("child"):
                pass
        (entry,) = tr.slow()
        assert entry["tree"]["name"] == "root"
        assert entry["tree"]["children"][0]["name"] == "child"

    def test_note_slow_respects_threshold(self):
        tr = Tracer(slow_threshold_s=10.0)
        tr.note_slow("fast", 0.0, 0.01)
        assert tr.slow() == []

    def test_incoming_trace_id_sanitized(self):
        tr = Tracer()
        with tr.start("r", trace_id="abc-123_X") as root:
            assert root.trace_id == "abc-123_X"
        with tr.start("r", trace_id='ev"il\nid{}' + "x" * 100) as root:
            # capped at 64 raw chars, then the unsafe ones are dropped
            assert root.trace_id == "evilid" + "x" * 54
        with tr.start("r", trace_id="!!!") as root:
            assert len(root.trace_id) == 16     # nothing survived: minted


# ---------------------------------------------------------------------------
# WriterPool.stats() coherence under live ingest (the snapshot is taken
# under the pool lock, so pending/queue_depth can't tear mid-spill).
# ---------------------------------------------------------------------------

class TestWriterStatsCoherence:
    def test_stats_consistent_while_ingesting(self):
        db = EdgeStore(n_tablets=2)
        pool = WriterPool(db, spill_rows=64)
        n_blocks, rows = 60, 32
        stop = threading.Event()
        errors = []

        def ingest():
            try:
                for i in range(n_blocks):
                    r = np.asarray([f"r{i:03d}-{j}" for j in range(rows)])
                    c = np.asarray(["ip.src|x"] * rows)
                    v = np.asarray(["1"] * rows)
                    pool.submit(r, c, v)
            finally:
                stop.set()

        t = threading.Thread(target=ingest)
        t.start()
        last_written = 0
        while not stop.is_set() or t.is_alive():
            s = pool.stats()
            assert s["pending"] >= 0
            assert s["queue_depth"] >= 0
            assert s["n_written"] >= last_written    # monotone
            assert s["n_errors"] == 0
            last_written = s["n_written"]
            if not t.is_alive():
                break
        t.join()
        assert not errors
        pool.flush()
        assert pool.stats()["pending"] == 0
        assert pool.n_written == n_blocks * rows
        pool.close()


# ---------------------------------------------------------------------------
# Gateway integration: /metrics, trace propagation, identity contract.
# ---------------------------------------------------------------------------

TOKENS = {"tok-a": Tenant("alice", rate=1000.0, burst=2000.0)}


@pytest.fixture(scope="module")
def capture():
    return synthetic_incidence(seed=5, duration=10.0, n_hosts=32, n_bots=4)


def make_gateway(capture, backend="memory", **gw_kw):
    T = DB("Tedge", "TedgeT", "TedgeDeg", backend=backend,
           n_instances=2 if backend == "net" else 1,
           tablets_per_instance=2)
    put(T, capture, sync=False)     # async → the WriterPool exists
    T.flush()
    gw = Gateway(T, TokenAuth(TOKENS), stats_interval=0.1, **gw_kw)
    gw.start()
    return gw


def close_gateway(gw):
    gw.stop()
    close = getattr(gw.table.backend, "close", None)
    if close is not None:
        close()


def raw_get(gw, path, token="tok-a", headers=None):
    host, port = gw.address.split(":")
    c = http.client.HTTPConnection(host, int(port), timeout=30)
    h = dict(headers or {})
    if token is not None:
        h["Authorization"] = f"Bearer {token}"
    c.request("GET", path, headers=h)
    r = c.getresponse()
    data = r.read()
    hdrs = dict(r.getheaders())
    c.close()
    return r.status, data, hdrs


def get_json(gw, path, token="tok-a", headers=None):
    status, data, hdrs = raw_get(gw, path, token=token, headers=headers)
    return status, (json.loads(data) if data else None), hdrs


def tree_paths(tree, depth=1):
    """Flatten a span tree into (name, depth) pairs."""
    out = [(tree["name"], depth)]
    for child in tree.get("children", ()):
        out.extend(tree_paths(child, depth + 1))
    return out


@pytest.fixture(scope="module")
def net_gw(capture):
    # coalescing off so the traced request's own thread runs the planner
    g = make_gateway(capture, backend="net", coalesce_window=0.0)
    yield g
    close_gateway(g)


class TestMetricsEndpoint:
    def test_scrape_is_unauthenticated_prometheus_text(self, net_gw):
        s, d, _ = get_json(net_gw, "/v1/topk?k=5")      # traffic first
        assert s == 200
        status, body, hdrs = raw_get(net_gw, "/metrics", token=None)
        assert status == 200
        assert hdrs["Content-Type"].startswith("text/plain")
        text = body.decode()
        # one sample from every layer, per the acceptance checklist
        assert "repro_cache_hits_total{" in text or \
            "repro_cache_misses_total{" in text
        assert "repro_writer_written_total{" in text
        assert "repro_rpc_total{" in text
        assert "# TYPE repro_http_requests_total counter" in text
        assert 'repro_http_requests_total{route="/v1/topk",status="200"}' \
            in text
        assert 'repro_http_request_seconds_bucket{route="/v1/topk",le=' \
            in text

    def test_http_metrics_use_route_pattern_not_raw_path(self, net_gw):
        s, d, _ = get_json(net_gw, "/v1/jobs/nonexistent")
        assert s == 404
        _, body, _ = raw_get(net_gw, "/metrics", token=None)
        text = body.decode()
        assert 'route="/v1/jobs/{id}"' in text           # bounded label
        assert 'route="/v1/jobs/nonexistent"' not in text


class TestTracePropagation:
    def test_trace_spans_gateway_to_shard_rpc(self, net_gw):
        s, d, hdrs = get_json(net_gw, "/v1/scan?prefix=ip.src|&trace=1")
        assert s == 200
        tid = hdrs.get("X-Trace-Id")
        assert tid
        s, d, _ = get_json(net_gw, f"/v1/trace/{tid}")
        assert s == 200 and d["trace"] == tid
        flat = tree_paths(d["tree"])
        names = {n for n, _ in flat}
        assert d["tree"]["name"] == "GET /v1/scan"       # gateway root
        assert "planner.eval" in names                   # planner layer
        assert "db.scan" in names                        # binding layer
        assert any(n.startswith("rpc.") for n in names)  # shard RPC layer
        depth = {n: dep for n, dep in flat}
        assert depth["planner.eval"] == 2
        assert depth["db.scan"] == 3
        assert max(dep for n, dep in flat
                   if n.startswith("rpc.")) >= 4          # ≥ 4 layers deep
        # per-shard RPCs carry their shard address as a tag
        recs = net_gw.tracer.spans(tid)
        rpc_shards = {r["tags"].get("shard") for r in recs
                      if r["name"].startswith("rpc.")}
        addrs = {i.address for i in net_gw.table.backend.instances}
        assert rpc_shards <= addrs and rpc_shards

    def test_incoming_trace_id_is_honored(self, net_gw):
        s, d, hdrs = get_json(net_gw, "/v1/topk?k=3",
                              headers={"X-Trace-Id": "my-trace-42"})
        assert s == 200
        assert hdrs["X-Trace-Id"] == "my-trace-42"
        s, d, _ = get_json(net_gw, "/v1/trace/my-trace-42")
        assert s == 200
        assert d["tree"]["name"] == "GET /v1/topk"

    def test_unknown_trace_404(self, net_gw):
        s, d, _ = get_json(net_gw, "/v1/trace/deadbeef00000000")
        assert s == 404

    def test_slow_log_endpoint_shape(self, net_gw):
        s, d, _ = get_json(net_gw, "/v1/debug/slow")
        assert s == 200
        assert d["threshold_s"] == net_gw.tracer.slow_threshold_s
        assert isinstance(d["slow"], list)

    def test_stats_exposes_tracer(self, net_gw):
        s, d, _ = get_json(net_gw, "/v1/stats")
        assert s == 200
        assert d["trace"]["max_traces"] == 256

    def test_sampling_off_records_zero_spans(self, capture):
        gw = make_gateway(capture)      # trace_sample defaults to 0.0
        try:
            for _ in range(3):
                s, _, hdrs = get_json(gw, "/v1/topk?k=3")
                assert s == 200
                assert "X-Trace-Id" not in hdrs
            assert gw.tracer.stats()["n_spans"] == 0
            assert gw.tracer.stats()["n_traces"] == 0
        finally:
            close_gateway(gw)


class TestStatsMetricsIdentity:
    """/metrics and T.stats() read the SAME underlying counts — locked
    here for every shared counter (the satellite-6 contract)."""

    def test_cache_and_writer_counters_identical(self, capture):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, capture, sync=False)
        T.flush()
        T[:, "ip.src|*,"].eval()
        T[:, "ip.src|*,"].eval()        # a hit
        T[:, "ip.dst|*,"].eval()        # a miss
        st = T.stats()
        d = REGISTRY.as_dict()
        cache = T._cache
        pool = T.backend._writer_pool
        ck = (("cache", cache.metrics_label),)
        pk = (("pool", pool.metrics_label),)
        assert st["cache"]["hits"] == \
            d[("repro_cache_hits_total", ck)] > 0
        assert st["cache"]["misses"] == \
            d[("repro_cache_misses_total", ck)] > 0
        assert st["cache"]["evictions"] == \
            d[("repro_cache_evictions_total", ck)]
        assert st["writers"]["n_written"] == \
            d[("repro_writer_written_total", pk)] > 0
        assert st["writers"]["n_retried"] == \
            d[("repro_writer_retried_total", pk)]
        assert st["writers"]["tap_errors"] == \
            d[("repro_writer_tap_errors_total", pk)]

    def test_rpc_counters_identical(self, net_gw):
        get_json(net_gw, "/v1/topk?k=3")
        st = net_gw.table.stats()
        d = REGISTRY.as_dict()
        total = 0
        for inst in net_gw.table.backend.instances:
            key = (("shard", inst.address),
                   ("client", inst.metrics_label))
            assert inst.n_rpcs == d[("repro_rpc_total", key)] > 0
            total += inst.n_rpcs
        assert st["backend"]["n_rpcs"] == total

    def test_kernel_launch_counters_identical(self):
        d = REGISTRY.as_dict()
        for kernel, count in launch_counts().items():
            assert d[("repro_kernel_launches_total",
                      (("kernel", kernel),))] == count
