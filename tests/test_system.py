"""End-to-end system behaviour: the paper's full loop + the framework
integration (pipeline → database → analytics → LM training → serving)."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analytics
from repro.configs import smoke_config
from repro.core.assoc import Assoc
from repro.data import TokenStream
from repro.db import MultiInstanceDB
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_params
from repro.pipeline import (PipelineConfig, TrafficConfig, botnet_truth,
                            run_pipeline)
from repro.train import OptConfig, adamw_init, make_train_step


@pytest.fixture(scope="module")
def pipeline_run(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("system"))
    tcfg = TrafficConfig(n_hosts=128, pkt_rate=120.0, n_bots=10,
                         beacon_period_s=4.0, beacon_jitter_s=0.1, seed=13)
    cfg = PipelineConfig(workdir=d, n_files=1, duration_per_file_s=40.0,
                         split_size=96 * 1024, traffic=tcfg, n_workers=2)
    db = MultiInstanceDB(n_instances=2, tablets_per_instance=2)
    stats = run_pipeline(cfg, db)
    return d, tcfg, db, stats


class TestPaperLoop:
    def test_pipeline_populates_database(self, pipeline_run):
        _, _, db, stats = pipeline_run
        assert stats["db_entries"] > 1000
        assert all(s in stats["stages"] for s in
                   ("uncompress", "split", "parse", "sort", "sparse",
                    "ingest"))

    def test_fig2_query_from_database(self, pipeline_run):
        _, tcfg, db, _ = pipeline_run
        c2 = botnet_truth(tcfg)["c2"]
        conns = db.connections(c2)
        assert len(conns) >= 5
        assert db.degree(f"ip.dst|{c2}") >= 10

    def test_degree_table_consistency(self, pipeline_run):
        """TedgeDeg (combiner-maintained) equals recount from triples."""
        d, tcfg, db, _ = pipeline_run
        E = Assoc()
        for p in sorted(glob.glob(os.path.join(d, "*.E.npz"))):
            E = E + Assoc.load(p)
        c2 = botnet_truth(tcfg)["c2"]
        col = f"ip.dst|{c2}"
        recount = float(np.asarray(
            E[:, [col]].logical().sum(0).triples()[2]).sum())
        assert db.degree(col) == recount

    def test_detection_from_ingested_graph(self, pipeline_run):
        d, tcfg, _, _ = pipeline_run
        E = Assoc()
        for p in sorted(glob.glob(os.path.join(d, "*.E.npz"))):
            E = E + Assoc.load(p)
        rep = analytics.detect_c2(E, top_k=3)
        assert botnet_truth(tcfg)["c2"] in list(rep.hosts)


class TestFrameworkIntegration:
    def test_train_lm_on_pipeline_corpus(self, pipeline_run):
        """The Fig. 1 story: same environment ingests AND learns."""
        d, _, _, _ = pipeline_run
        pattern = os.path.join(d, "*.tsv")
        assert glob.glob(pattern), "pipeline left no TSV corpus"
        stream = TokenStream(pattern, seq_len=64, batch=2)
        cfg = smoke_config("h2o-danube-1.8b")
        mesh = make_smoke_mesh(len(jax.devices()))
        params = init_params(cfg, jax.random.key(0))
        opt_state = adamw_init(params)
        step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3,
                                                      warmup_steps=2),
                                       mesh), donate_argnums=(0, 1))
        losses = []
        with mesh:
            for _ in range(10):
                batch = {k: jnp.minimum(jnp.asarray(v), cfg.vocab - 1)
                         for k, v in stream.next_batch().items()}
                params, opt_state, m = step(params, opt_state, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
