"""Model zoo: per-arch smoke tests + numerical equivalences.

Per assignment: every architecture gets a REDUCED same-family config
smoke test — one forward/train step on CPU asserting output shapes and
no NaNs — plus decode-vs-teacher-forcing consistency (cache correctness)
and impl-equivalence checks (chunked vs naive attention, wkv forms).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import (ShapeConfig, decode_step, init_params, inputs,
                          loss_fn, prefill)
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import model as M

SMOKE_TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", 32, 2, "prefill")


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            cache[arch] = (cfg, init_params(cfg, jax.random.key(0)))
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step_finite(self, arch, arch_state):
        cfg, params = arch_state(arch)
        batch = inputs.make_batch(cfg, SMOKE_TRAIN)
        loss = loss_fn(params, batch, cfg)
        assert jnp.isfinite(loss), (arch, loss)
        grads = jax.grad(lambda p: loss_fn(p, batch, cfg))(params)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                          for g in jax.tree.leaves(grads)))
        assert jnp.isfinite(gn), arch

    def test_forward_shapes(self, arch, arch_state):
        cfg, params = arch_state(arch)
        batch = inputs.make_batch(cfg, SMOKE_TRAIN)
        x, _ = M.forward(params, batch, cfg, mode="train")
        assert x.shape == (2, SMOKE_TRAIN.seq_len, cfg.d_model)
        logits = M.logits_from_hidden(params, x, cfg)
        assert logits.shape[-1] == cfg.padded_vocab
        assert jnp.isfinite(logits).all()

    def test_decode_matches_teacher_forcing(self, arch, arch_state):
        """prefill(S) then decode(token S) must equal forward(S+1)."""
        cfg, params = arch_state(arch)
        S = SMOKE_PREFILL.seq_len
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, S + 1)), jnp.int32)
        pb = {"tokens": toks[:, :S]}
        fb = {"tokens": toks}
        if cfg.frontend == "vision":
            img = jnp.asarray(rng.normal(0, 1, (2, cfg.n_img_tokens,
                                                 cfg.d_model)), jnp.float32)
            pb["img_embeds"] = img
            fb["img_embeds"] = img
        if cfg.is_encdec:
            frames = jnp.asarray(rng.normal(0, 1, (2, cfg.encoder_seq,
                                                   cfg.d_model)),
                                 jnp.float32)
            pb["frames"] = frames
            fb["frames"] = frames
        # full forward logits at position S (predicting token S+1)
        x, _ = M.forward(params, fb, cfg, mode="train")
        full_logits = M.logits_from_hidden(params, x[:, S:S + 1], cfg)
        # prefill + one decode step (vision: positions continue after
        # the image prefix the prefill consumed)
        offset = cfg.n_img_tokens if cfg.frontend == "vision" else 0
        _, caches = prefill(params, pb, cfg, s_max=S + offset + 4)
        db = {"tokens": toks[:, S:S + 1],
              "positions": jnp.full((2, 1), S + offset, jnp.int32)}
        if cfg.is_encdec:
            db["enc_out"] = M._encode(params, frames, cfg)
        dec_logits, _ = decode_step(params, caches, db, cfg)
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


class TestEquivalences:
    def test_chunked_attention_matches_naive(self):
        key = jax.random.key(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 16))
        k = jax.random.normal(ks[1], (2, 64, 2, 16))
        v = jax.random.normal(ks[2], (2, 64, 2, 16))
        pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
        for window in (0, 24):
            ref = L.attention_naive(q, k, v, pos, pos, True, window)
            for tri in (False, True):
                out = L.attention_chunked(q, k, v, pos, pos, True, window,
                                          chunk=16, triangular=tri)
                np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                           rtol=1e-4, atol=1e-5)

    def test_wkv_chunked_matches_scan(self):
        ks = jax.random.split(jax.random.key(1), 5)
        Bn, S, H, Dh = 2, 64, 2, 16
        r, k, v = (jax.random.normal(ks[i], (Bn, S, H, Dh))
                   for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (Bn, S, H, Dh))) \
            * 0.5 + 0.45
        u = jax.random.normal(ks[4], (H, Dh)) * 0.1
        s0 = jnp.zeros((Bn, H, Dh, Dh))
        o1, st1 = B.wkv_scan(r, k, v, w, u, s0)
        o2, st2 = B.wkv_chunked(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                                   rtol=1e-3, atol=1e-4)

    def test_head_padding_exact(self):
        """Padded-head model computes exactly the logical model."""
        cfg0 = smoke_config("whisper-large-v3")
        cfgP = dataclasses.replace(cfg0, head_pad=8, kv_pad=8)
        p0 = init_params(cfg0, jax.random.key(0))
        pP = init_params(cfgP, jax.random.key(0))

        def graft(a, b):
            out = {}
            for key in b:
                if isinstance(b[key], dict):
                    out[key] = graft(a[key], b[key])
                elif key in ("wq", "wk", "wv", "bq", "bk", "bv"):
                    n = a[key].shape[-1]
                    out[key] = jnp.zeros_like(b[key]).at[..., :n].set(a[key])
                elif key == "wo":
                    n = a[key].shape[-2]
                    out[key] = jnp.zeros_like(b[key]) \
                        .at[..., :n, :].set(a[key])
                else:
                    out[key] = a[key]
            return out

        pP = graft(p0, pP)
        batch = inputs.make_batch(cfg0, SMOKE_TRAIN)
        l0 = loss_fn(p0, batch, cfg0)
        lP = loss_fn(pP, batch, cfgP)
        assert abs(float(l0) - float(lP)) < 1e-4

    def test_rglru_cache_continuation(self):
        """Splitting a sequence across prefill+decode matches one pass."""
        cfg = smoke_config("recurrentgemma-9b")
        params = init_params(cfg, jax.random.key(0))
        S = 24
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (1, S + 1)),
            jnp.int32)
        x, _ = M.forward(params, {"tokens": toks}, cfg, mode="train")
        full = M.logits_from_hidden(params, x[:, -1:], cfg)
        _, caches = prefill(params, {"tokens": toks[:, :S]}, cfg,
                            s_max=S + 4)
        dec, _ = decode_step(params, caches,
                             {"tokens": toks[:, S:],
                              "positions": jnp.full((1, 1), S, jnp.int32)},
                             cfg)
        np.testing.assert_allclose(np.asarray(dec, np.float32),
                                   np.asarray(full, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestConfigs:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_full_config_exact(self, arch):
        """The registered full configs carry the assignment's numbers."""
        cfg = get_config(arch)
        expected = {
            "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
            "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
            "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
            "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
            "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
            "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
            "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
            "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
            "rwkv6_1_6b": (24, 2048, 32, 32, 7168, 65536),
            "phi_3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        }
        from repro.configs import canonical
        L_, D, H, KV, F, V = expected[canonical(arch)]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L_, D, H, KV, F, V), arch

    def test_moe_configs(self):
        g = get_config("granite-moe-3b-a800m")
        assert g.moe.n_experts == 40 and g.moe.top_k == 8
        q = get_config("qwen3-moe-235b-a22b")
        assert q.moe.n_experts == 128 and q.moe.top_k == 8
        assert q.resolved_head_dim == 128

    def test_param_counts_plausible(self):
        # analytic param counts in the right ballpark (±40% of nameplate)
        approx = {"qwen2_5_14b": 14e9, "internlm2_20b": 20e9,
                  "rwkv6_1_6b": 1.6e9, "h2o_danube_1_8b": 1.8e9,
                  "qwen3_moe_235b_a22b": 235e9}
        for arch, n in approx.items():
            got = get_config(arch).n_params()
            assert 0.6 * n < got < 1.5 * n, (arch, got, n)
