"""Distributed machinery: sharding specs, dry-run cells (subprocess).

Multi-device tests run in a subprocess with forced host devices so the
main pytest process keeps the default 1-device view (per assignment).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config
from repro.models import abstract_params
from repro.train import sharding as S

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestParamSpecs:
    def test_dense_rules(self):
        params = abstract_params(get_config("qwen2.5-14b"))
        specs = S.param_specs(params)
        wq = specs["groups"]["slot0"]["attn"]["wq"]
        assert tuple(wq) == (None, "data", "model")
        assert tuple(specs["embed"]) == ("model", "data")

    def test_moe_vs_stacked_dense_disambiguation(self):
        """Stacked dense (L,D,F) w_gate must NOT get expert rules."""
        dense = abstract_params(get_config("phi3-mini-3.8b"))
        moe = abstract_params(get_config("qwen3-moe-235b-a22b"))
        d_spec = S.param_specs(dense)["groups"]["slot0"]["mlp"]["w_gate"]
        m_spec = S.param_specs(moe)["groups"]["slot0"]["mlp"]["w_gate"]
        assert tuple(d_spec) == (None, "data", "model")     # (L, D, F)
        assert tuple(m_spec)[1] == "model"                  # (L, E, D, F)

    def test_nondivisible_dims_dropped(self):
        """granite: 40 experts on tp=16 → hybrid (no expert sharding)."""
        import numpy as np
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        params = abstract_params(get_config("granite-moe-3b-a800m"))
        # with tp=16 metadata: use explicit spec fn on shapes

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        specs = S.param_specs(params, FakeMesh())
        wg = specs["groups"]["slot0"]["mlp"]["w_gate"]   # (L, 40, D, F)
        assert tuple(wg) == (None, None, "data", "model")

    def test_zero3_profile(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        params = abstract_params(get_config("h2o-danube-1.8b"))
        specs = S.param_specs(params, FakeMesh(), profile="zero3")
        flat = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index")
                               or x is None)
        # every spec either replicates or shards over ALL axes combined
        for spec in jax.tree.leaves(
                specs, is_leaf=lambda s: s.__class__.__name__ ==
                "PartitionSpec"):
            for entry in spec:
                assert entry in (None, ("data", "model"))


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_smoke_cell_lowering(self, tmp_path):
        """Lower+compile a smoke config on an 8-device fake mesh in a
        subprocess (keeps this process single-device)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import jax, json
            from repro.configs import smoke_config
            from repro.models import inputs as I
            from repro.models.config import ShapeConfig
            from repro.train import OptConfig, abstract_train_state, \
                sharding as S
            from repro.train.trainer import make_train_step

            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            cfg = smoke_config("recurrentgemma-9b")
            shape = ShapeConfig("t", 32, 4, "train")
            specs = I.input_specs(cfg, shape)
            params, opt_state = abstract_train_state(cfg)
            p_sh = S.param_shardings(params, mesh)
            o_sh = {"m": p_sh, "v": p_sh,
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            b_sh = S.batch_shardings(specs, mesh)
            step = make_train_step(cfg, OptConfig(), mesh)
            with mesh:
                c = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                            donate_argnums=(0, 1)) \
                    .lower(params, opt_state, specs).compile()
            print(json.dumps({"ok": True,
                              "temp": c.memory_analysis()
                              .temp_size_in_bytes}))
        """)
        env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        assert rec["ok"] and rec["temp"] > 0


@pytest.mark.slow
class TestDistributedAnalytics:
    def test_sharded_analytics_match_single_device(self):
        """shard_map degree/SpMV/PageRank over 8 fake devices equal the
        single-device versions (the paper's analytics, mesh-parallel)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.sparse import COO, spmv_t
            from repro.core import graph
            from repro.analytics import distributed as D

            from repro.launch.mesh import make_mesh
            mesh = make_mesh((8,), ("data",))
            rng = np.random.default_rng(0)
            n, nnz = 200, 3000
            m = COO.from_numpy(rng.integers(0, n, nnz),
                               rng.integers(0, n, nnz),
                               rng.integers(1, 4, nnz).astype(np.float32),
                               (n, n))
            got = D.degree_sharded(m, mesh)
            exp = jax.ops.segment_sum(jnp.ones_like(m.vals), m.cols,
                                      num_segments=n)
            np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                       rtol=1e-5)
            x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
            np.testing.assert_allclose(
                np.asarray(D.spmv_t_sharded(m, x, mesh)),
                np.asarray(spmv_t(m, x)), rtol=1e-4, atol=1e-4)
            pr_d = D.pagerank_sharded(m, mesh, num_iters=15)
            pr_s = graph.pagerank(m, num_iters=15)
            np.testing.assert_allclose(np.asarray(pr_d), np.asarray(pr_s),
                                       rtol=1e-3, atol=1e-5)
            print("SHARDED_ANALYTICS_OK")
        """)
        env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARDED_ANALYTICS_OK" in out.stdout


class TestPodFsdp:
    def test_pod_fsdp_specs_span_pod_axis(self):
        class FakeMesh:
            shape = {"pod": 2, "data": 16, "model": 16}
            axis_names = ("pod", "data", "model")
        params = abstract_params(get_config("qwen2.5-14b"))
        specs = S.param_specs(params, FakeMesh(), profile="2d_podfsdp")
        wq = specs["groups"]["slot0"]["attn"]["wq"]      # (L, D, H·Dh)
        assert tuple(wq) == (None, ("pod", "data"), "model")
        # single-pod mesh: profile degrades gracefully to plain data-FSDP
        class SinglePod:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        specs1 = S.param_specs(params, SinglePod(), profile="2d_podfsdp")
        wq1 = specs1["groups"]["slot0"]["attn"]["wq"]
        assert tuple(wq1) == (None, "data", "model")


@pytest.mark.slow
class TestGradCompression:
    def test_int8_pod_mean_error_bounded(self):
        """int8 cross-pod mean: wire bytes 4× less than f32, error within
        the quantization bound (subprocess: 2-pod fake mesh)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.train.compression import compressed_pod_mean

            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
            rng = np.random.default_rng(0)
            g = jnp.asarray(rng.normal(0, 0.1, (64, 32))
                            .astype(np.float32))
            grads = {"w": g, "b": jnp.asarray(
                rng.normal(0, 3.0, (16,)).astype(np.float32))}
            out = compressed_pod_mean(grads, mesh)
            # replicated inputs: exact mean == input; error ≤ scale/2
            for k in grads:
                scale = float(jnp.max(jnp.abs(grads[k]))) / 127.0
                err = float(jnp.max(jnp.abs(out[k] - grads[k])))
                assert err <= scale / 2 + 1e-7, (k, err, scale)
            print("COMPRESS_OK")
        """)
        env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "COMPRESS_OK" in out.stdout
