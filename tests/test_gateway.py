"""Serving gateway: endpoint families on memory and net backends, auth,
rate limiting (429 + Retry-After), the degree guard as 413, write-rate
admission, background jobs, SSE streaming, the unified stats snapshot —
and concurrent mixed load: reader threads hammering cached queries while
the WriterPool ingests, with a rate-limited tenant never blocking an
admitted one."""
import json
import threading
import time
import http.client

import numpy as np
import pytest

from repro.core.assoc import Assoc
from repro.db import DB, put
from repro.serve import (Gateway, QueueFull, RateLimited, RateLimiter,
                        Tenant, TokenAuth, TokenBucket)
from repro.serve.app import synthetic_incidence


@pytest.fixture(scope="module")
def capture():
    """One synthetic traffic incidence shared by every gateway."""
    return synthetic_incidence(seed=3, duration=20.0, n_hosts=64, n_bots=6)


TOKENS = {
    "tok-a": Tenant("alice", rate=1000.0, burst=2000.0),
    "tok-b": Tenant("bob", rate=0.5, burst=2.0),        # 2 requests, then 429
    "tok-z": Tenant("zeno", rate=1000.0, burst=2000.0, max_jobs=0),
}


def make_gateway(capture, backend="memory", **gw_kw):
    T = DB("Tedge", "TedgeT", "TedgeDeg", backend=backend,
           n_instances=2 if backend == "net" else 1,
           tablets_per_instance=2)
    put(T, capture)
    gw = Gateway(T, TokenAuth(TOKENS), stats_interval=0.1, **gw_kw)
    gw.start()
    return gw


@pytest.fixture
def gw(capture):
    g = make_gateway(capture)
    yield g
    g.stop()


@pytest.fixture(params=["memory", "net"])
def gw_any(request, capture):
    g = make_gateway(capture, backend=request.param)
    yield g
    g.stop()
    close = getattr(g.table.backend, "close", None)
    if close is not None:
        close()


def req(gw, method, path, token="tok-a", body=None, timeout=30):
    host, port = gw.address.split(":")
    c = http.client.HTTPConnection(host, int(port), timeout=timeout)
    headers = {}
    if token is not None:
        headers["Authorization"] = f"Bearer {token}"
    raw = json.dumps(body).encode() if body is not None else None
    if raw is not None:
        headers["Content-Type"] = "application/json"
    c.request(method, path, body=raw, headers=headers)
    r = c.getresponse()
    data = r.read()
    hdrs = dict(r.getheaders())
    c.close()
    return r.status, (json.loads(data) if data else None), hdrs


def get(gw, path, token="tok-a"):
    return req(gw, "GET", path, token=token)


def wait_job(gw, jid, token="tok-a", deadline=60.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline:
        s, d, _ = get(gw, f"/v1/jobs/{jid}", token=token)
        assert s == 200
        if d["status"] in ("done", "failed"):
            return d
        time.sleep(0.05)
    raise AssertionError(f"job {jid} never finished")


# ---------------------------------------------------------------------------
# Unit level: buckets, limiter, unified stats.
# ---------------------------------------------------------------------------

class TestRateLimitUnits:
    def test_token_bucket_refills(self):
        t = [0.0]
        b = TokenBucket(rate=2.0, burst=4.0, clock=lambda: t[0])
        assert [b.try_acquire() for _ in range(4)] == [0.0] * 4
        retry = b.try_acquire()
        assert retry == pytest.approx(0.5)      # 1 token at 2/s
        t[0] += 0.5
        assert b.try_acquire() == 0.0

    def test_limiter_isolates_tenants(self):
        lim = RateLimiter()
        a, b = Tenant("a", rate=1e6, burst=1e6), Tenant("b", rate=1.0,
                                                        burst=1.0)
        lim.acquire(b)
        with pytest.raises(RateLimited):
            lim.acquire(b)
        for _ in range(100):                    # b's rejections don't bill a
            lim.acquire(a)
        assert lim.stats()["n_rejected"] == 1

    def test_unified_stats_snapshot(self, capture):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        T.put(capture, sync=False)              # through the WriterPool
        T.flush()
        T[:, "ip.dst|*,"].eval()
        T[:, "ip.dst|*,"].eval()
        assert T.stats["col"] == 1 and T.stats["cache_hit"] == 1  # mapping
        merged = T.stats()                                        # callable
        assert merged["routes"]["col"] == 1
        assert merged["cache"]["hits"] == 1
        assert merged["writers"]["n_written"] > 0
        assert merged["backend"]["kind"] == "EdgeStore"
        json.dumps(merged)                    # snapshot is JSON-serializable


# ---------------------------------------------------------------------------
# Endpoint families (memory + net backends).
# ---------------------------------------------------------------------------

class TestEndpoints:
    def test_all_families_on_both_backends(self, gw_any):
        gw = gw_any
        # family 1: query endpoints
        s, d, _ = get(gw, "/v1/topk?prefix=ip.dst|&k=5")
        assert s == 200 and len(d["hosts"]) == 5
        assert d["hosts"][0]["degree"] >= d["hosts"][-1]["degree"]
        s, d, _ = get(gw, "/v1/degree?prefix=ip.dst|")
        assert s == 200 and d["fit"]["alpha"] > 0 and "resid" not in d["fit"]
        # family 2: admission-limited scans
        s, d, _ = get(gw, "/v1/scan?axis=col&prefix=ip.dst|&max_cells=10")
        assert s == 200 and d["truncated"] and len(d["triples"]) == 10
        # family 3: async jobs
        s, d, _ = req(gw, "POST", "/v1/jobs", body={"kind": "degree_fit"})
        assert s == 200 and d["status"] == "queued"
        done = wait_job(gw, d["job"])
        assert done["status"] == "done"
        s, d, _ = get(gw, f"/v1/jobs/{done['job']}/result")
        assert s == 200 and d["result"]["fit"]["alpha"] > 0
        # family 4: live stats stream (raw SSE over the socket)
        host, port = gw.address.split(":")
        c = http.client.HTTPConnection(host, int(port), timeout=30)
        c.request("GET", "/v1/stream/stats?n=2",
                  headers={"Authorization": "Bearer tok-a"})
        r = c.getresponse()
        assert r.status == 200
        assert r.getheader("Content-Type") == "text/event-stream"
        frames = [l for l in r.read().decode().splitlines()
                  if l.startswith("data: ")]
        c.close()
        assert len(frames) == 2
        sample = json.loads(frames[0][len("data: "):])
        assert {"rows_written_window", "queue_depth",
                "writes_per_s"} <= set(sample)

    def test_topk_matches_degree_table(self, gw):
        s, d, _ = get(gw, "/v1/topk?prefix=ip.dst|&k=3")
        deg = gw.table.degree_assoc("ip.dst|")
        r, _, v = deg.triples()
        v = np.asarray(v, np.float64)
        best = r[np.argmax(v)]
        assert d["hosts"][0]["key"] == str(best)
        assert d["hosts"][0]["degree"] == float(v.max())

    def test_c2_and_scanners_json(self, gw):
        s, d, _ = get(gw, "/v1/c2?top_k=3")
        assert s == 200 and len(d["report"]["hosts"]) == 3
        assert isinstance(d["report"]["scores"][0], float)
        s, d, _ = get(gw, "/v1/scanners?min_fanout=16")
        assert s == 200 and d["report"]["min_fanout"] == 16

    def test_scan_selectors(self, gw):
        s, d, _ = get(gw, "/v1/scan?axis=row&start=000000000&stop=000000010")
        assert s == 200 and d["nnz"] > 0
        a_key = d["triples"][0][0]
        s, d2, _ = get(gw, f"/v1/scan?axis=row&keys={a_key},")
        assert s == 200 and all(t[0] == a_key for t in d2["triples"])

    def test_pagerank_job(self, gw):
        s, d, _ = req(gw, "POST", "/v1/jobs",
                      body={"kind": "pagerank",
                            "params": {"num_iters": 5, "top_k": 5}})
        assert s == 200
        done = wait_job(gw, d["job"])
        assert done["status"] == "done"
        s, d, _ = get(gw, f"/v1/jobs/{d['job']}/result")
        assert s == 200 and len(d["result"]["nodes"]) == 5
        ranks = [n["rank"] for n in d["result"]["nodes"]]
        assert ranks == sorted(ranks, reverse=True)


# ---------------------------------------------------------------------------
# Error surface: 400/401/404/413/429/503.
# ---------------------------------------------------------------------------

class TestErrors:
    def test_health_needs_no_auth(self, gw):
        assert req(gw, "GET", "/healthz", token=None)[0] == 200

    def test_401_missing_and_bad_token(self, gw):
        assert get(gw, "/v1/topk", token=None)[0] == 401
        assert get(gw, "/v1/topk", token="wrong")[0] == 401

    def test_404_unknown_route_and_job(self, gw):
        assert get(gw, "/v1/nope")[0] == 404
        assert get(gw, "/v1/jobs/deadbeef")[0] == 404

    def test_400_bad_params(self, gw):
        assert get(gw, "/v1/topk?k=banana")[0] == 400
        assert get(gw, "/v1/scan?axis=diag")[0] == 400
        s, d, _ = req(gw, "POST", "/v1/jobs", body={"kind": "mine-bitcoin"})
        assert s == 400

    def test_413_degree_guard(self, capture):
        g = make_gateway(capture, degree_limit=3.0)
        try:
            s, d, _ = get(g, "/v1/scan?axis=col&prefix=ip.dst|")
            assert s == 413
            assert "degree guard" in d["error"]
        finally:
            g.stop()

    def test_429_rate_limit_sets_retry_after(self, gw):
        codes = [get(gw, "/v1/topk", token="tok-b")[0] for _ in range(4)]
        assert codes.count(429) >= 1            # bob: burst 2 at cost 1
        s, d, hdrs = get(gw, "/v1/topk", token="tok-b")
        assert s == 429 and float(hdrs["Retry-After"]) > 0

    def test_429_admission_on_write_pressure(self, gw):
        cache = gw.table.backend._scan_cache
        cache.full_scan_wps_limit = 0.0     # any trailing write trips it
        gw.table.put(Assoc("px,", "ip.dst|adm,", "1,"))
        s, d, hdrs = get(gw, "/v1/scan")
        assert s == 429 and "inadmissible" in d["error"]
        assert float(hdrs["Retry-After"]) > 0
        # selective scans stay admitted — only full-table work is shed
        assert get(gw, "/v1/scan?axis=col&prefix=ip.dst|&max_cells=5")[0] \
            == 200

    def test_503_tenant_job_bound(self, gw):
        s, d, _ = req(gw, "POST", "/v1/jobs", token="tok-z",
                      body={"kind": "degree_fit"})
        assert s == 503                         # zeno: max_jobs=0

    def test_job_result_202_while_pending(self, gw):
        gate = threading.Event()
        job = gw.jobs.submit("slow", lambda: gate.wait(10) or {"ok": 1},
                             TOKENS["tok-a"])
        try:
            s, _, _ = get(gw, f"/v1/jobs/{job.id}/result")
            assert s == 202
        finally:
            gate.set()


# ---------------------------------------------------------------------------
# Coherence: cache invalidation through the serving path.
# ---------------------------------------------------------------------------

class TestCoherence:
    def test_gateway_reads_see_new_writes(self, gw):
        key = "ip.dst|fresh-host"
        s, d, _ = get(gw, f"/v1/topk?prefix={key}")
        assert d["hosts"] == []
        gw.table.put(Assoc("q1,q2,", f"{key},{key},", "1,1,"), sync=False)
        s, d, _ = get(gw, f"/v1/topk?prefix={key}")    # read barrier drains
        assert d["hosts"][0]["degree"] == 2.0

    def test_cached_band_invalidated_by_write(self, gw):
        path = "/v1/scan?axis=col&prefix=ip.dst|cache-band&max_cells=99"
        get(gw, path)
        hits0 = gw.table.stats["cache_hit"]
        get(gw, path)
        assert gw.table.stats["cache_hit"] == hits0 + 1   # served hot
        gw.table.put(Assoc("q9,", "ip.dst|cache-band,", "1,"))
        s, d, _ = get(gw, path)                 # write evicted the band
        assert [t[:2] for t in d["triples"]] == [["q9", "ip.dst|cache-band"]]


# ---------------------------------------------------------------------------
# Concurrent mixed load — the tentpole's concurrency contract.
# ---------------------------------------------------------------------------

class TestMixedLoad:
    N_READERS = 8
    N_REQS = 12

    def test_readers_vs_ingest_no_torn_reads(self, gw):
        """N reader threads during active WriterPool ingest: every read
        succeeds, and the sum-combined degree of the hammered key is
        non-decreasing per thread (a torn read would regress it)."""
        stop = threading.Event()
        wrote = [0]

        def ingest():
            i = 0
            while not stop.is_set():
                rows = np.asarray([f"ld{i}-{j}" for j in range(50)], str)
                cols = np.asarray(["ip.dst|hammered"] * 50, str)
                gw.table.put(Assoc(rows, cols, np.asarray(["1"] * 50)),
                             sync=False)
                wrote[0] += 50
                i += 1
                time.sleep(0.005)

        failures = []

        def reader(tid):
            last = 0.0
            for _ in range(self.N_REQS):
                s, d, _ = get(gw, "/v1/topk?prefix=ip.dst|hammered&k=1")
                if s != 200:
                    failures.append((tid, s))
                    return
                if d["hosts"]:
                    deg = d["hosts"][0]["degree"]
                    if deg < last:
                        failures.append((tid, "regressed", last, deg))
                        return
                    last = deg

        t_ing = threading.Thread(target=ingest)
        t_ing.start()
        readers = [threading.Thread(target=reader, args=(i,))
                   for i in range(self.N_READERS)]
        for t in readers:
            t.start()
        for t in readers:
            t.join()
        stop.set()
        t_ing.join()
        assert failures == []
        gw.table.flush()
        assert gw.table.degree("ip.dst|hammered") == wrote[0]

    def test_rejected_tenant_never_blocks_admitted_one(self, gw):
        """bob hammers past his budget and collects 429s; alice's
        concurrent requests all succeed — rejection is per-tenant."""
        bob_codes, alice_codes = [], []

        def bob():
            for _ in range(25):
                bob_codes.append(get(gw, "/v1/topk", token="tok-b")[0])

        def alice():
            for _ in range(25):
                alice_codes.append(get(gw, "/v1/topk", token="tok-a")[0])

        threads = [threading.Thread(target=bob),
                   threading.Thread(target=alice)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert bob_codes.count(429) >= 1
        assert all(c in (200, 429) for c in bob_codes)
        assert alice_codes == [200] * 25

    def test_read_barrier_not_serialized_behind_ingest(self, gw):
        """A reader that arrives while ingest keeps streaming must wait
        only for writes that preceded it — with the old queue-empty
        barrier this read would block for the whole ingest run."""
        pool = gw.table.writer()
        stop = threading.Event()

        def ingest():
            i = 0
            while not stop.is_set():
                rows = np.asarray([f"rb{i}-{j}" for j in range(200)], str)
                gw.table.put(Assoc(rows,
                                   np.asarray(["ip.dst|rb"] * 200, str),
                                   np.asarray(["1"] * 200)), sync=False)
                i += 1

        t = threading.Thread(target=ingest)
        t.start()
        try:
            time.sleep(0.05)                # let the queue build up
            t0 = time.monotonic()
            s, _, _ = get(gw, "/v1/topk?prefix=ip.dst|rb&k=1")
            dt = time.monotonic() - t0
            assert s == 200
            assert dt < 5.0                 # snapshot wait, not queue-empty
        finally:
            stop.set()
            t.join()
            pool.flush()
