"""Analytics layer: power-law fitting, detection, dimensional analysis."""
import jax.numpy as jnp
import numpy as np

from repro import analytics
from repro.core import Assoc
from repro.core.schema import parse_tsv, val2col
from repro.pipeline import TrafficConfig, botnet_truth
from repro.pipeline.pcap import records_to_tsv, synth_packets


def capture(seed=5, duration=60.0, n_bots=12):
    tcfg = TrafficConfig(n_hosts=256, pkt_rate=120.0, n_bots=n_bots,
                         beacon_period_s=5.0, beacon_jitter_s=0.1,
                         seed=seed)
    rec = synth_packets(tcfg, duration)
    return tcfg, val2col(parse_tsv(records_to_tsv(rec)))


class TestPowerLaw:
    def test_fit_recovers_exponent(self):
        rng = np.random.default_rng(0)
        rank = np.arange(1, 2000)
        deg = jnp.asarray((1e4 * rank ** -1.5).astype(np.float32))
        fit = analytics.fit_rank_size(deg)
        assert abs(float(fit.alpha) - 1.5) < 0.2
        assert float(fit.r2) > 0.95

    def test_histogram_conserves_mass(self):
        d = jnp.asarray(np.random.default_rng(1).pareto(1.3, 5000)
                        .astype(np.float32))
        _, counts = analytics.degree_histogram(d, n_bins=32)
        assert abs(float(counts.sum()) - 5000) < 1

    def test_background_scores_flag_outlier(self):
        """Rank-size background subtraction flags hosts ABOVE the fitted
        line at their rank.  (A mid-rank host boosted to a value that is
        normal for its new rank is — correctly — invisible to this
        detector; that is why detect_c2 fuses three signals.)"""
        rank = np.arange(1, 500)
        deg = (1e3 * rank ** -1.2).astype(np.float32)
        deg[0] *= 50.0                         # head far above the line
        scores = np.asarray(analytics.background_scores(jnp.asarray(deg)))
        assert scores[0] == scores.max()
        assert scores[0] > 1.0


class TestDetection:
    def test_c2_detected_top3(self):
        tcfg, E = capture(seed=3, duration=90.0)
        truth = botnet_truth(tcfg)
        rep = analytics.detect_c2(E, top_k=3)
        assert truth["c2"] in list(rep.hosts)

    def test_no_false_certainty_without_botnet(self):
        tcfg, E = capture(seed=6, n_bots=0)
        rep = analytics.detect_c2(E, top_k=3)
        # without injected C2, fused scores stay small
        assert rep.scores[0] < 0.5


class TestDimensional:
    def test_field_stats(self):
        _, E = capture(duration=10.0)
        st = analytics.field_stats(E)
        assert "ip.src" in st and "ip.dst" in st
        assert st["ip.proto"]["cardinality"] <= 3
        assert st["ip.src"]["entropy_bits"] > \
            st["ip.proto"]["entropy_bits"]

    def test_field_correlation_shapes(self):
        _, E = capture(duration=10.0)
        C = analytics.field_correlation(E, "ip.src", "tcp.dstport")
        assert C.nnz > 0
        assert all(r.startswith("ip.src|") for r in C.row[:5])
