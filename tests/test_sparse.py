"""Device sparse payloads: COO/CSR semiring ops vs dense oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, skipping when absent

from repro.core import (COO, MAX_PLUS, MIN_PLUS, OR_AND, PLUS_TIMES,
                        coo_to_csr, csr_to_coo, col_degree, row_degree,
                        spmm, spmv, spmv_t)
from repro.core import graph


def random_coo(rng, nr=8, nc=6, nnz=20):
    rows = rng.integers(0, nr, nnz)
    cols = rng.integers(0, nc, nnz)
    vals = rng.integers(1, 5, nnz).astype(np.float32)
    return COO.from_numpy(rows, cols, vals, (nr, nc))


class TestCOO:
    def test_from_numpy_coalesces(self):
        m = COO.from_numpy([0, 0, 1], [1, 1, 2], [1.0, 2.0, 3.0], (2, 3))
        assert m.nnz == 2
        assert float(m.to_dense()[0, 1]) == 3.0

    def test_csr_roundtrip(self):
        rng = np.random.default_rng(0)
        m = random_coo(rng)
        back = csr_to_coo(coo_to_csr(m))
        np.testing.assert_allclose(np.asarray(back.to_dense()),
                                   np.asarray(m.to_dense()))

    @pytest.mark.parametrize("ring,combine", [
        (PLUS_TIMES, lambda A, x: A @ x),
        (MIN_PLUS, lambda A, x: np.where(
            (A != 0).any(1), np.min(np.where(A != 0, A + x[None, :],
                                             np.inf), axis=1), np.inf)),
    ])
    def test_spmv_semirings(self, ring, combine):
        rng = np.random.default_rng(1)
        m = random_coo(rng)
        x = rng.normal(0, 1, m.shape[1]).astype(np.float32)
        got = np.asarray(spmv(m, jnp.asarray(x), ring))
        A = np.asarray(m.to_dense())
        exp = combine(A, x)
        mask = exp != np.inf
        np.testing.assert_allclose(got[mask], exp[mask], rtol=1e-5)

    def test_spmm_matches_dense(self):
        rng = np.random.default_rng(2)
        m = random_coo(rng)
        X = rng.normal(0, 1, (m.shape[1], 4)).astype(np.float32)
        got = np.asarray(spmm(m, jnp.asarray(X)))
        np.testing.assert_allclose(got, np.asarray(m.to_dense()) @ X,
                                   rtol=1e-5)

    def test_degrees(self):
        m = COO.from_numpy([0, 0, 1], [0, 1, 1], [2.0, 1.0, 1.0], (3, 2))
        np.testing.assert_allclose(np.asarray(row_degree(m)), [2, 1, 0])
        np.testing.assert_allclose(np.asarray(col_degree(m)), [1, 2])
        np.testing.assert_allclose(
            np.asarray(row_degree(m, weighted=True)), [3, 1, 0])


class TestGraph:
    def test_pagerank_sums_to_one(self):
        m = COO.from_numpy([0, 1, 2], [1, 2, 0], [1., 1., 1.], (3, 3))
        pr = graph.pagerank(m, num_iters=30)
        assert abs(float(pr.sum()) - 1.0) < 1e-4
        # symmetric cycle → uniform
        np.testing.assert_allclose(np.asarray(pr), 1 / 3, atol=1e-4)

    def test_pagerank_sink_handling(self):
        # node 2 is dangling
        m = COO.from_numpy([0, 1], [1, 2], [1., 1.], (3, 3))
        pr = graph.pagerank(m, num_iters=50)
        assert abs(float(pr.sum()) - 1.0) < 1e-4
        assert float(pr[2]) > float(pr[0])

    def test_bfs_reachable(self):
        m = COO.from_numpy([0, 1], [1, 2], [1., 1.], (4, 4))
        seed = jnp.zeros(4).at[0].set(1.0)
        out = graph.bfs_reachable(m, seed, hops=2)
        assert list(np.asarray(out)) == [True, True, True, False]


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 50))
def test_property_spmv_transpose_consistency(seed):
    rng = np.random.default_rng(seed)
    m = random_coo(rng, nr=6, nc=5, nnz=12)
    x = rng.normal(0, 1, m.shape[0]).astype(np.float32)
    got = np.asarray(spmv_t(m, jnp.asarray(x)))
    exp = np.asarray(m.to_dense()).T @ x
    np.testing.assert_allclose(got, exp, rtol=1e-4, atol=1e-5)
