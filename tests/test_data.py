"""Data layer: tokenizer roundtrip, stream determinism + resume."""
import os

import numpy as np
import pytest

from repro.data import SamplerState, TokenStream, tokenizer as T


class TestTokenizer:
    def test_roundtrip(self):
        s = "ip.src|1.2.3.4 → port 6667 ✓"
        assert T.decode(T.encode(s)) == s

    def test_specials(self):
        ids = T.encode("x", add_bos=True, add_eos=True)
        assert ids[0] == T.BOS and ids[-1] == T.EOS
        assert T.decode(ids) == "x"


@pytest.fixture
def corpus(tmp_path):
    for i in range(3):
        (tmp_path / f"f{i}.txt").write_text(f"file{i} " * 200)
    return str(tmp_path / "*.txt")


class TestStream:
    def test_batch_shapes(self, corpus):
        st = TokenStream(corpus, seq_len=32, batch=2)
        b = st.next_batch()
        assert b["tokens"].shape == (2, 32)
        assert b["labels"].shape == (2, 32)
        # labels are next-token shifted
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_deterministic(self, corpus):
        a = TokenStream(corpus, seq_len=16, batch=2).next_batch()
        b = TokenStream(corpus, seq_len=16, batch=2).next_batch()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_resume_from_state(self, corpus):
        s1 = TokenStream(corpus, seq_len=16, batch=2)
        for _ in range(3):
            s1.next_batch()
        saved = s1.state.to_dict()
        want = s1.next_batch()
        s2 = TokenStream(corpus, seq_len=16, batch=2,
                         state=SamplerState.from_dict(saved))
        got = s2.next_batch()
        np.testing.assert_array_equal(want["tokens"], got["tokens"])

    def test_sharding_disjoint_files(self, tmp_path):
        for i in range(4):
            (tmp_path / f"g{i}.txt").write_text(f"shard{i} " * 100)
        pattern = str(tmp_path / "*.txt")
        a = TokenStream(pattern, 16, 1, shard=0, n_shards=2)
        b = TokenStream(pattern, 16, 1, shard=1, n_shards=2)
        assert set(a.files).isdisjoint(b.files)
        assert set(a.files) | set(b.files) == set(
            TokenStream(pattern, 16, 1).files)

    def test_epoch_wraps(self, corpus):
        st = TokenStream(corpus, seq_len=512, batch=4)
        for _ in range(5):
            st.next_batch()
        assert st.state.epoch >= 1
