"""Batched evaluation: eval_batch planner fusion (N chains → one SpMM
launch), DBTable._scan_batch union scans + ScanCache interplay, the
gateway QueryCoalescer, and job-queue batch_key dedup."""
import threading
import time

import numpy as np
import pytest

from repro.core import Assoc, StartsWith, eval_batch, lazy, lazy_batch
from repro.core import keys as K
from repro.core import expr as X
from repro.db import DB, AccidentalDenseError, put
from repro.serve import QueryCoalescer
from repro.serve.auth import Tenant
from repro.serve.jobs import JobQueue


def small_incidence():
    rows = "p1,p1,p2,p2,p3,p3,p4,p4,"
    cols = ("ip.src|a,ip.dst|b,ip.src|a,ip.dst|c,"
            "ip.src|d,ip.dst|b,ip.src|a,ip.dst|b,")
    return Assoc(rows, cols, "1,1,1,1,1,1,1,1,")


def random_graph(n=200, nnz=2000, seed=1):
    rng = np.random.default_rng(seed)
    rows = np.asarray([f"v{i:04d}" for i in rng.integers(0, n, nnz)],
                      dtype=str)
    cols = np.asarray([f"v{i:04d}" for i in rng.integers(0, n, nnz)],
                      dtype=str)
    return Assoc(rows, cols, np.ones(nnz))


def seed_vec(j):
    return Assoc(np.asarray([f"v{j:04d}"]), np.asarray([f"seed{j}"]),
                 np.asarray([1.0]))


class TestEvalBatchScans:
    def test_col_batch_matches_individual(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        sels = ["ip.dst|b,", "ip.src|a,ip.src|d,", StartsWith("ip.dst|")]
        got = eval_batch([T[:, s] for s in sels])
        for s, g in zip(sels, got):
            assert g == T._scan(None, s)
        # the whole batch hit the tablets through ONE union col scan
        assert T.stats["col"] == 1
        assert T.stats["cache_miss"] == 3

    def test_row_batch_matches_individual(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        pairs = [("p1,p2,", None), (StartsWith("p"), None),
                 ("p3,", "ip.dst|*,")]
        got = eval_batch([T[r, c] for r, c in pairs])
        for (r, c), g in zip(pairs, got):
            assert g == T._scan(r, c)
        assert T.stats["row"] == 1

    def test_degree_batch_matches_individual(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        Td = DB("TedgeDeg", backend=T.backend)
        sels = ["ip.dst|b,ip.dst|c,", StartsWith("ip.src|")]
        got = eval_batch([Td[s, :] for s in sels])
        for s, g in zip(sels, got):
            assert g == Td._scan(s, None)

    def test_batch_populates_and_hits_cache(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        cache = T._cache
        sels = ["ip.dst|b,", "ip.dst|c,", "ip.src|a,"]
        eval_batch([T[:, s] for s in sels])
        assert cache.batch_misses == 3 and cache.batch_hits == 0
        # a batch member's entry serves a later SINGLE query...
        assert T[:, "ip.dst|b,"].eval() == T._scan(None, "ip.dst|b,")
        assert cache.hits >= 2       # the eval + the _scan both hit
        # ...and a cached single query serves a later batch member
        hits0 = cache.batch_hits
        eval_batch([T[:, s] for s in sels])
        assert cache.batch_hits == hits0 + 3
        assert T.stats()["cache"]["batch_hits"] == cache.batch_hits
        assert T.stats()["cache"]["batch_misses"] == cache.batch_misses

    def test_guarded_member_raises_alone(self):
        """A member refused by the degree guard must not poison the
        batch prefetch — it raises when IT evaluates."""
        T = DB("Tedge", "TedgeT", "TedgeDeg", degree_limit=2.0)
        put(T, small_incidence())
        exprs = [T[:, "ip.dst|b,"], T[:, "ip.dst|c,"]]
        with pytest.raises(AccidentalDenseError):
            eval_batch(exprs)            # deg(ip.dst|b) == 3 > 2
        # the safe member alone is fine
        ok = eval_batch([T[:, "ip.dst|c,"], T[:, "ip.src|d,"]])
        assert ok[0] == T.with_degree_limit(None)._scan(None, "ip.dst|c,")

    def test_duplicate_members_cse(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        a, b = eval_batch([T[:, "ip.dst|b,"], T[:, "ip.dst|b,"]])
        assert a == b
        assert T.stats["col"] == 1

    def test_lazy_batch_wraps(self):
        A = small_incidence()
        nodes = lazy_batch([A, lazy(A).logical()])
        got = eval_batch(nodes)
        assert got[0] == A and got[1] == A.logical()


class TestSpmmChainFusion:
    def _setup(self, monkeypatch, n_chains=8):
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 1)
        T = DB("Tedge", "TedgeT")
        put(T, random_graph())
        exprs = [T.lazy() * lazy(seed_vec(j)) for j in range(n_chains)]
        return T, exprs

    def test_n_chains_one_launch(self, monkeypatch):
        """The acceptance criterion: N matvec chains over the same
        table scan execute as ONE fused SpMM launch, not N SpMVs."""
        T, exprs = self._setup(monkeypatch)
        c0 = X.launch_counts()
        got = eval_batch(exprs)
        c1 = X.launch_counts()
        assert c1["spmm"] - c0["spmm"] == 1
        assert c1["spmv"] - c0["spmv"] == 0
        # ...and every fused column equals its solo evaluation
        for j, g in enumerate(got):
            solo = (T.lazy() * lazy(seed_vec(j))).eval()
            assert g == solo

    def test_two_factor_chains_two_launches(self, monkeypatch):
        T, _ = self._setup(monkeypatch)
        exprs = [T.lazy() * T.lazy() * lazy(seed_vec(j)) for j in range(4)]
        c0 = X.launch_counts()
        got = eval_batch(exprs)
        c1 = X.launch_counts()
        assert c1["spmm"] - c0["spmm"] == 2      # one per factor
        assert c1["spmv"] - c0["spmv"] == 0
        for j, g in enumerate(got):
            assert g == (T.lazy() * T.lazy() * lazy(seed_vec(j))).eval()

    def test_pallas_spmm_path(self, monkeypatch):
        monkeypatch.setattr(X, "USE_PALLAS_SPMV", True)
        T, exprs = self._setup(monkeypatch, n_chains=4)
        c0 = X.launch_counts()
        got = eval_batch(exprs)
        assert X.launch_counts()["spmm"] - c0["spmm"] == 1
        monkeypatch.setattr(X, "USE_PALLAS_SPMV", False)
        for j, g in enumerate(got):
            assert g == (T.lazy() * lazy(seed_vec(j))).eval()

    def test_single_chain_not_fused(self, monkeypatch):
        T, exprs = self._setup(monkeypatch, n_chains=1)
        c0 = X.launch_counts()
        eval_batch(exprs)
        assert X.launch_counts()["spmm"] - c0["spmm"] == 0

    def test_below_threshold_stays_on_host(self, monkeypatch):
        """Small payloads keep the host path (and its f64 precision)."""
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 10 ** 9)
        T = DB("Tedge", "TedgeT")
        put(T, random_graph())
        exprs = [T.lazy() * lazy(seed_vec(j)) for j in range(4)]
        c0 = X.launch_counts()
        got = eval_batch(exprs)
        c1 = X.launch_counts()
        assert c1["spmm"] - c0["spmm"] == 0
        assert c1["spmv"] - c0["spmv"] == 0
        for j, g in enumerate(got):
            assert g == (T.lazy() * lazy(seed_vec(j))).eval()


class TestQueryCoalescer:
    def test_concurrent_requests_one_batch(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        qc = QueryCoalescer(window=0.05)
        sels = ["ip.dst|b,", "ip.dst|c,", "ip.src|a,", "ip.src|d,"]
        results = [None] * len(sels)

        def worker(i):
            results[i] = qc.eval(T[:, sels[i]])

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(sels))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = qc.stats()
        assert st["n_batches"] == 1 and st["n_coalesced"] == len(sels)
        for i, s in enumerate(sels):
            assert results[i] == T._scan(None, s)

    def test_disabled_window_is_solo(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg")
        put(T, small_incidence())
        qc = QueryCoalescer(window=0.0)
        out = qc.eval(T[:, "ip.dst|b,"])
        assert out == T._scan(None, "ip.dst|b,")
        assert qc.stats()["n_solo"] == 1 and qc.stats()["n_batches"] == 0

    def test_poisoned_member_fails_alone(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", degree_limit=2.0)
        put(T, small_incidence())
        qc = QueryCoalescer(window=0.05)
        errs, oks = [None], [None]

        def bad():
            try:
                qc.eval(T[:, "ip.dst|b,"])      # deg 3 > limit 2
            except AccidentalDenseError as e:
                errs[0] = e

        def good():
            oks[0] = qc.eval(T[:, "ip.dst|c,"])

        tb, tg = threading.Thread(target=bad), threading.Thread(target=good)
        tb.start(), tg.start()
        tb.join(), tg.join()
        assert isinstance(errs[0], AccidentalDenseError)
        assert oks[0] == T.with_degree_limit(None)._scan(None, "ip.dst|c,")


class TestJobCoalescing:
    def test_queued_duplicates_share_one_execution(self):
        q = JobQueue(n_workers=1)
        tenant = Tenant("a", rate=100.0, burst=100.0)
        gate = threading.Event()
        runs = []

        def slow():
            gate.wait(5)
            runs.append(1)
            return {"n": len(runs)}

        blocker = q.submit("blk", lambda: gate.wait(5) or {}, tenant)
        a = q.submit("fit", slow, tenant, batch_key="fit|{}")
        b = q.submit("fit", slow, tenant, batch_key="fit|{}")
        c = q.submit("fit", slow, tenant, batch_key="fit|{}")
        assert b.id != a.id and c.id != a.id     # own ids, shared run
        gate.set()
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            if all(j.status == "done" for j in (blocker, a, b, c)):
                break
            time.sleep(0.01)
        assert a.status == b.status == c.status == "done"
        assert len(runs) == 1                    # ONE execution
        assert a.result == b.result == c.result
        assert q.n_coalesced == 2
        assert q.stats()["n_coalesced"] == 2
        q.close()

    def test_finished_job_never_absorbs(self):
        q = JobQueue(n_workers=1)
        tenant = Tenant("a", rate=100.0, burst=100.0)
        runs = []

        def fn():
            runs.append(1)
            return {"n": len(runs)}

        a = q.submit("fit", fn, tenant, batch_key="k")
        deadline = time.monotonic() + 5
        while a.status != "done" and time.monotonic() < deadline:
            time.sleep(0.01)
        b = q.submit("fit", fn, tenant, batch_key="k")
        deadline = time.monotonic() + 5
        while b.status != "done" and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(runs) == 2                    # fresh snapshot re-runs
        assert a.result != b.result
        q.close()
