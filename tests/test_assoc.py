"""Associative-array algebra: unit + property tests (paper §II-B)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, skipping when absent

from repro.core import Assoc, KeyRange, StartsWith
from repro.core.schema import col2val, parse_tsv, to_tsv, val2col


def A(r, c, v, **kw):
    return Assoc(r, c, v, **kw)


class TestConstruction:
    def test_triple_dedupe_sums(self):
        a = A("r1,r2,r1,", "c1,c2,c1,", [1.0, 2.0, 3.0])
        assert a.nnz == 2
        r, c, v = a.triples()
        assert v[list(r).index("r1")] == 4.0

    def test_categorical_min_collision(self):
        a = A("r,r,", "c,c,", "beta,alpha,")
        assert a.nnz == 1
        assert a.triples()[2][0] == "alpha"

    def test_broadcast_scalar_value(self):
        a = A("r1,r2,", "c1,c2,", 1.0)
        assert a.nnz == 2

    def test_empty(self):
        a = Assoc()
        assert a.nnz == 0 and a.shape == (0, 0)

    def test_delimited_string_keys(self):
        a = A("a,b,c,", "x,y,z,", [1, 2, 3])
        assert list(a.row) == ["a", "b", "c"]


class TestSelection:
    def setup_method(self):
        self.a = A("r1,r1,r2,r3,", "ip.src|1.1.1.1,ip.dst|2.2.2.2,"
                   "ip.src|3.3.3.3,tcp.dstport|80,", [1, 2, 3, 4])

    def test_startswith(self):
        sub = self.a[:, StartsWith("ip.src|")]
        assert sub.shape[1] == 2

    def test_keyrange(self):
        sub = self.a[KeyRange("r1", "r2"), :]
        assert set(sub.row) == {"r1", "r2"}

    def test_exact_keys(self):
        sub = self.a[["r1"], :]
        assert list(sub.row) == ["r1"] and sub.nnz == 2

    def test_missing_key_empty(self):
        sub = self.a[["zzz"], :]
        assert sub.nnz == 0


class TestAlgebra:
    def test_add_union(self):
        x = A("a,b,", "c,c,", [1.0, 2.0])
        y = A("b,z,", "c,c,", [10.0, 5.0])
        s = x + y
        r, c, v = s.triples()
        d = dict(zip(r, v))
        assert d["a"] == 1.0 and d["b"] == 12.0 and d["z"] == 5.0

    def test_matmul_key_aligned(self):
        # A: packets × src, B: packets × dst ⇒ A.T * B: src × dst
        e = A("p1,p1,p2,p2,", "src|s1,dst|d1,src|s1,dst|d2,", 1.0)
        adj = e[:, StartsWith("src|")].T * e[:, StartsWith("dst|")]
        r, c, v = adj.triples()
        assert adj.shape == (1, 2) and v.sum() == 2.0

    def test_matmul_matches_scipy(self):
        rng = np.random.default_rng(0)
        r = rng.integers(0, 8, 30).astype(str)
        c = rng.integers(0, 8, 30).astype(str)
        v = rng.integers(1, 5, 30).astype(float)
        x = Assoc(r, c, v)
        got = (x.T * x).triples()[2]
        sp = x._numeric_sm()
        exp = (sp.T @ sp).tocoo()
        assert np.isclose(sorted(got), sorted(exp.data[exp.data != 0])).all()

    def test_elementwise_multiply(self):
        x = A("a,b,", "c,c,", [2.0, 3.0])
        y = A("a,z,", "c,c,", [10.0, 5.0])
        m = x.multiply(y)
        assert m.nnz == 1 and m.triples()[2][0] == 20.0

    def test_transpose_involution(self):
        x = A("a,b,", "c,d,", [1.0, 2.0])
        assert (x.T.T == x)

    def test_sum_axes(self):
        x = A("a,a,b,", "c,d,c,", [1.0, 2.0, 3.0])
        rs = x.sum(1)
        assert dict(zip(rs.triples()[0], rs.triples()[2])) == \
            {"a": 3.0, "b": 3.0}
        cs = x.sum(0)
        assert dict(zip(cs.triples()[1], cs.triples()[2])) == \
            {"c": 4.0, "d": 2.0}

    def test_paper_degree_idiom(self):
        e = A("p1,p1,p2,", "src|a,dst|b,src|a,", 1.0)
        deg = e.T.sum(1).putcol("degree,")
        d = dict(zip(deg.triples()[0], deg.triples()[2]))
        assert d["src|a"] == 2.0 and list(deg.col) == ["degree"]

    def test_putval_logical(self):
        x = A("a,b,", "c,d,", [5.0, 7.0])
        ones = x.putval("1,")
        assert set(ones.triples()[2]) == {"1"}
        logical = x.logical()
        assert set(logical.triples()[2]) == {1.0}

    def test_filters(self):
        x = A("a,b,c,", "z,z,z,", [1.0, 5.0, 9.0])
        assert (x > 4.0).nnz == 2
        assert (x <= 1.0).nnz == 1

    def test_num2str_roundtrip(self):
        x = A("a,b,", "z,z,", [1.0, 5.0])
        y = x.num2str().str2num()
        assert (y == x)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6),
                          st.integers(1, 9)), min_size=1, max_size=40))
def test_property_add_commutes(triples):
    r = np.asarray([f"r{t[0]}" for t in triples])
    c = np.asarray([f"c{t[1]}" for t in triples])
    v = np.asarray([float(t[2]) for t in triples])
    half = len(triples) // 2 or 1
    a = Assoc(r[:half], c[:half], v[:half])
    b = Assoc(r[half:], c[half:], v[half:]) if len(triples) > half \
        else Assoc()
    assert ((a + b) == (b + a))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(1, 9)), min_size=1, max_size=30))
def test_property_transpose_matmul(triples):
    """(A'·A)' == A'·A (gram matrix symmetric)."""
    r = np.asarray([f"r{t[0]}" for t in triples])
    c = np.asarray([f"c{t[1]}" for t in triples])
    v = np.asarray([float(t[2]) for t in triples])
    a = Assoc(r, c, v)
    g = a.sqin()
    assert (g == g.T)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                          st.integers(0, 4)), min_size=1, max_size=30))
def test_property_schema_roundtrip(triples):
    """col2val(val2col(A)) recovers the dense table."""
    r = np.asarray([f"p{t[0]}" for t in triples])
    c = np.asarray([f"f{t[1]}" for t in triples])
    v = np.asarray([f"v{t[2]}" for t in triples])
    dense = Assoc(r, c, v)
    back = col2val(val2col(dense, "|"), "|")
    assert (back == dense)


def test_tsv_roundtrip():
    tsv = ("id\tip.src\tip.dst\np1\t1.1.1.1\t2.2.2.2\n"
           "p2\t3.3.3.3\t4.4.4.4\n")
    a = parse_tsv(tsv)
    assert parse_tsv(to_tsv(a)) == a


def test_save_load_roundtrip(tmp_path):
    x = A("a,b,", "c,d,", [1.0, 2.0])
    p = str(tmp_path / "x.npz")
    x.save(p)
    assert (Assoc.load(p) == x)
    y = A("a,b,", "c,d,", "u,w,")
    y.save(p)
    assert (Assoc.load(p) == y)
