"""Streaming temporal analytics: hierarchical rollup conservation (child
buckets sum exactly to parents, and stream totals match a batch recount
of the same traffic), online detector precision/recall against injected
ground-truth attacks (clean diurnal traffic stays quiet), root-cause
localization, report round-trips, the WriterPool ingest tap under
concurrent async writes, and the gateway's windows/alerts/SSE surface."""
import json
import threading
import time
import http.client

import numpy as np
import pytest

from repro.core.assoc import Assoc
from repro.db import DB
from repro.serve import Gateway, Tenant, TokenAuth
from repro.stream import (AlertReport, AttackSpec, DetectorBank,
                          RootCauseReport, ScenarioConfig, StreamAnalytics,
                          TemporalRollup, WesternElectric, WindowSummary,
                          root_cause, scenario_incidence, stream_blocks,
                          synth_scenario)

T0 = 1_492_000_000.0
N_FIELDS = 9            # val2col explodes each packet into 9 field|value cells


def attack_cfg(seed=3):
    """The canonical scenario mix: diurnal background plus one attack
    of each kind.  The DDoS sits in a later minute bucket than the pure
    beacon windows so the C2 scorer is exercised both with and without
    a competing flood (a flood is itself a legitimate beacon-score
    candidate: high fan-in, one port)."""
    return ScenarioConfig(
        duration_s=150.0, n_hosts=96, base_rate=70.0, seed=seed, t0=T0,
        attacks=(
            AttackSpec("c2", start=5, duration=140, n_hosts=8,
                       period_s=2.0, port=6667),
            AttackSpec("scan", start=30, duration=10, rate=60.0),
            AttackSpec("ddos", start=85, duration=10, n_hosts=8,
                       rate=40.0, port=80),
        ))


@pytest.fixture(scope="module")
def driven():
    """Scenario streamed block-by-block through a rollup + detector
    bank, detectors run as windows close — shared by the conservation
    and detector tests."""
    cfg = attack_cfg()
    rec, truth = synth_scenario(cfg)
    roll = TemporalRollup(lateness_s=2.0)
    bank = DetectorBank(roll)
    alerts = []
    for _, A in stream_blocks(cfg, rec=rec):
        roll.ingest(*A.triples())
        alerts.extend(bank.process())
    alerts.extend(bank.process(force=True))
    return dict(cfg=cfg, rec=rec, truth=truth, roll=roll, bank=bank,
                alerts=alerts)


def overlaps(alert, att, pad=0.0):
    return (alert.window_start < att["stop"] + pad
            and alert.window_stop > att["start"] - pad)


# ---------------------------------------------------------------------------
# synthetic scenario harness
# ---------------------------------------------------------------------------

class TestSynth:
    def test_deterministic(self):
        cfg = attack_cfg()
        r1, t1 = synth_scenario(cfg)
        r2, t2 = synth_scenario(cfg)
        assert np.array_equal(r1, r2)
        assert t1 == t2

    def test_truth_labels(self, driven):
        truth = driven["truth"]
        kinds = [a["kind"] for a in truth["attacks"]]
        assert kinds == ["c2", "scan", "ddos"]
        for a in truth["attacks"]:
            assert T0 <= a["start"] < a["stop"] <= T0 + 150.0
            assert a["n_packets"] > 0
        assert len(truth["attacks"][2]["attackers"]) == 8

    def test_stream_blocks_cover_everything(self, driven):
        n = sum(A.nnz for _, A in
                stream_blocks(driven["cfg"], rec=driven["rec"]))
        assert n == driven["rec"].shape[0] * N_FIELDS


# ---------------------------------------------------------------------------
# rollup conservation + recount
# ---------------------------------------------------------------------------

class TestRollupConservation:
    def test_levels_agree_exactly(self, driven):
        roll = driven["roll"]
        tots = {lv: roll.totals(lv) for lv, _ in roll.levels}
        cells = {lv: t["n_cells"] for lv, t in tots.items()}
        pkts = {lv: t["n_packets"] for lv, t in tots.items()}
        assert len(set(cells.values())) == 1, cells
        assert len(set(pkts.values())) == 1, pkts
        # degree sketches conserve too: summing per-level counters over
        # all buckets gives identical key → count maps
        degs = [t["deg"] for t in tots.values()]
        assert degs[0] == degs[1] == degs[2]

    def test_child_buckets_sum_to_parent(self, driven):
        roll = driven["roll"]
        secs = {w.start: w for w in roll.summaries("second", limit=10_000)}
        for m in roll.summaries("minute", limit=10_000):
            kids = [w for s, w in secs.items()
                    if m.start <= s < m.start + m.width]
            assert sum(w.n_cells for w in kids) == m.n_cells
            assert sum(w.n_packets for w in kids) == m.n_packets

    def test_totals_match_batch_recount(self, driven):
        """The streamed rollup must agree exactly with a from-scratch
        batch pass over the same records."""
        A, _ = scenario_incidence(driven["cfg"])
        tot = driven["roll"].totals("second")
        assert tot["n_cells"] == A.nnz
        assert tot["n_packets"] == driven["rec"].shape[0]
        st = driven["roll"].stats()
        assert st["n_attributed"] == A.nnz
        assert st["n_unattributed"] == 0
        assert st["n_pending"] == 0

    def test_per_second_packets_match_recount(self, driven):
        rec = driven["rec"]
        ts = rec["ts_sec"].astype(np.float64) + rec["ts_usec"] * 1e-6
        want = {}
        for s in np.floor(ts):
            want[s] = want.get(s, 0) + 1
        got = {w.start: w.n_packets
               for w in driven["roll"].summaries("second", limit=10_000)}
        assert got == want

    def test_slice_matches_window_population(self, driven):
        roll = driven["roll"]
        rec = driven["rec"]
        ts = rec["ts_sec"].astype(np.float64) + rec["ts_usec"] * 1e-6
        lo, hi = T0 + 20.0, T0 + 23.0
        E = roll.slice(lo, hi)
        n_pkts = int(((ts >= lo) & (ts < hi)).sum())
        assert E.nnz == n_pkts * N_FIELDS
        assert len(E.row) == n_pkts

    def test_scaling_fit_per_level(self, driven):
        """Each closed minute carries a power-law fit of its dst-degree
        distribution — the paper's sub-window scaling relation."""
        mins = [w for w in driven["roll"].summaries("minute", limit=100)
                if w.n_packets > 100]
        assert mins
        for w in mins:
            assert np.isfinite(w.alpha) and w.alpha > 0
            assert np.isfinite(w.r2)

    def test_degree_view_feeds_fit_degree_table(self, driven):
        from repro.analytics import fit_degree_table
        roll = driven["roll"]
        start = roll.summaries("minute", limit=1)[0].start
        fit = fit_degree_table(roll.degree_view("minute", start),
                               "ip.dst|")
        assert np.isfinite(float(fit.alpha))


class TestRollupMechanics:
    @staticmethod
    def _pkt(row, t):
        """One packet's triples: the time cell plus two field cells."""
        r = [row] * 3
        c = [f"frame.time|{t:.6f}", "ip.src|1.2.3.4", "ip.dst|5.6.7.8"]
        return np.asarray(r), np.asarray(c), np.asarray(["1"] * 3)

    def test_watermark_close_semantics(self):
        roll = TemporalRollup(levels=("second",), lateness_s=2.0)
        for i in range(6):
            roll.ingest(*self._pkt(f"p{i}", 100.0 + i))
        closed = roll.close_due()
        # max_ts = 105, watermark 103 → seconds 100..102 close, rest stay
        assert [w.start for w in closed] == [100.0, 101.0, 102.0]
        assert roll.close_due() == []           # idempotent
        flush = roll.close_due(force=True)
        assert {w.start for w in flush} == {103.0, 104.0, 105.0}

    def test_late_arrival_counted_not_lost(self):
        roll = TemporalRollup(levels=("second",), lateness_s=0.5)
        for i in range(4):
            roll.ingest(*self._pkt(f"p{i}", 100.0 + i))
        roll.close_due()
        roll.ingest(*self._pkt("late", 100.2))  # into a closed bucket
        assert roll.stats()["n_late"] == 3
        assert roll.totals("second")["n_packets"] == 5

    def test_split_block_attribution(self):
        """A packet split across put batches: field cells arrive before
        the block carrying its frame.time — the pending map must hold
        them and attribute on resolution."""
        roll = TemporalRollup(levels=("second",))
        r, c, v = self._pkt("px", 100.0)
        roll.ingest(r[1:], c[1:], v[1:])        # fields first, no time
        assert roll.stats()["n_pending"] == 2
        assert roll.stats()["n_attributed"] == 0
        roll.ingest(r[:1], c[:1], v[:1])        # the time cell lands
        st = roll.stats()
        assert st["n_pending"] == 0
        assert st["n_attributed"] == 3
        assert roll.totals("second")["n_cells"] == 3

    def test_pending_bound_evicts_and_counts(self):
        roll = TemporalRollup(levels=("second",), max_pending_rows=2)
        for i in range(4):                      # 4 rows, no time cells
            roll.ingest(np.asarray([f"p{i}"]),
                        np.asarray(["ip.src|1.1.1.1"]),
                        np.asarray(["1"]))
        st = roll.stats()
        assert st["n_unattributed"] == 2        # two oldest evicted
        assert st["n_pending"] == 2

    def test_time_relative_prefix_not_confused(self):
        """frame.time_relative| shares the frame.time prefix as a plain
        string — the rollup must key timestamps off frame.time| only."""
        roll = TemporalRollup(levels=("second",))
        r = np.asarray(["p0"] * 3)
        c = np.asarray(["frame.time_relative|0.5",
                        "frame.time|200.0", "ip.dst|9.9.9.9"])
        roll.ingest(r, c, np.asarray(["1"] * 3))
        assert roll.totals("second")["n_packets"] == 1
        assert list(roll._buckets["second"]) == [200.0]

    def test_eviction_keeps_totals_exact(self):
        roll = TemporalRollup(levels=("second",), lateness_s=0.0,
                              max_buckets=3)
        for i in range(10):
            roll.ingest(*TestRollupMechanics._pkt(f"p{i}", 100.0 + i))
        roll.close_due(force=True)
        assert len(roll._buckets["second"]) <= 3
        tot = roll.totals("second")
        assert tot["n_packets"] == 10           # evicted counts retained
        assert tot["n_evicted_buckets"] > 0


# ---------------------------------------------------------------------------
# SPC / Western Electric
# ---------------------------------------------------------------------------

class TestWesternElectric:
    def test_steady_series_never_fires(self):
        we = WesternElectric(min_baseline=10)
        rng = np.random.default_rng(0)
        fires = [we.update(100 + rng.normal(0, 3))[0] for _ in range(200)]
        assert all(f == 0 for f in fires)

    def test_step_change_fires_rule1(self):
        we = WesternElectric(min_baseline=10, sigma_floor_frac=0.05)
        rng = np.random.default_rng(1)
        for _ in range(30):
            we.update(100 + rng.normal(0, 3))
        rule, z = we.update(200.0)
        assert rule == 1
        assert z > 3

    def test_two_of_three_fires_rule2(self):
        we = WesternElectric(min_baseline=10, sigma_floor_frac=0.05)
        rng = np.random.default_rng(2)
        for _ in range(30):
            we.update(100 + rng.normal(0, 4))
        we.update(112.0)                        # > 2σ, < 3σ
        rule, _ = we.update(112.0)
        assert rule == 2

    def test_sustained_shift_fires_a_run_rule(self):
        we = WesternElectric(min_baseline=60, sigma_floor_frac=0.05)
        for _ in range(60):
            we.update(100.0)
        fired = set()
        for _ in range(10):
            fired.add(we.update(104.0)[0])      # ~0.8σ above, same side
        assert 4 in fired                       # eight-in-a-row rule

    def test_sigma_floor_blocks_zero_variance_trip(self):
        we = WesternElectric(min_baseline=10)
        for _ in range(20):
            we.update(100.0)
        rule, z = we.update(101.0)              # σ=0 without the floor
        assert rule == 0
        assert z < 1.0


# ---------------------------------------------------------------------------
# detectors against ground truth
# ---------------------------------------------------------------------------

class TestDetectors:
    def test_every_injected_attack_detected(self, driven):
        truth = {a["kind"]: a for a in driven["truth"]["attacks"]}
        for kind, att in truth.items():
            hits = [a for a in driven["alerts"]
                    if a.kind == kind and overlaps(a, att)]
            assert hits, f"no {kind} alert inside its truth window"

    def test_c2_alert_names_the_c2_server(self, driven):
        att = driven["truth"]["attacks"][0]
        c2 = [a for a in driven["alerts"] if a.kind == "c2"]
        assert any(a.victim == att["victim"] for a in c2)

    def test_scan_alert_names_the_scanner(self, driven):
        att = driven["truth"]["attacks"][1]
        scans = [a for a in driven["alerts"] if a.kind == "scan"]
        assert scans
        for a in scans:
            assert att["attackers"][0] in a.hosts.tolist()

    def test_ddos_alert_names_the_victim(self, driven):
        att = driven["truth"]["attacks"][2]
        dd = [a for a in driven["alerts"] if a.kind == "ddos"]
        assert dd
        assert all(a.victim == att["victim"] for a in dd)

    def test_attack_alerts_only_during_attacks(self, driven):
        """Precision: every attack-kind alert overlaps *some* injected
        attack (minute-level alerts padded by their window width)."""
        atts = driven["truth"]["attacks"]
        for a in driven["alerts"]:
            if a.kind == "spc":
                continue
            assert any(overlaps(a, att, pad=a.window_stop - a.window_start)
                       for att in atts), (a.kind, a.window_start - T0)

    def test_clean_diurnal_stays_quiet(self):
        cfg = ScenarioConfig(duration_s=120.0, n_hosts=64, base_rate=70.0,
                             seed=0, t0=T0)
        roll = TemporalRollup()
        bank = DetectorBank(roll)
        alerts = []
        for _, A in stream_blocks(cfg):
            roll.ingest(*A.triples())
            alerts.extend(bank.process())
        alerts.extend(bank.process(force=True))
        assert not [a for a in alerts if a.kind in ("c2", "scan", "ddos")]
        assert len(alerts) <= 2                 # SPC noise stays rare

    def test_root_cause_ranks_attackers(self, driven):
        att = driven["truth"]["attacks"][2]
        rc = root_cause(driven["roll"], att["start"] - 1.0,
                        att["stop"] + 1.0, [att["victim"]], top_k=3)
        hits = [h for h in rc.hosts if h in att["attackers"]]
        assert len(hits) >= 2                   # acceptance floor is 1
        assert att["victim"] not in rc.hosts    # seeds excluded

    def test_stream_analytics_seeds_from_alerts(self, driven):
        """StreamAnalytics.root_cause with no seeds borrows them from
        the most recent alert overlapping the window."""
        att = driven["truth"]["attacks"][2]
        sa = StreamAnalytics(rollup=driven["roll"], bank=driven["bank"])
        rc = sa.root_cause(att["start"] - 1.0, att["stop"] + 1.0, top_k=3)
        assert rc.seeds.shape[0] >= 1


# ---------------------------------------------------------------------------
# report round-trips
# ---------------------------------------------------------------------------

class TestReports:
    def test_alert_report_roundtrip(self, driven):
        a = next(x for x in driven["alerts"] if x.kind == "ddos")
        back = AlertReport.from_dict(json.loads(a.to_json()))
        assert back.kind == a.kind and back.victim == a.victim
        assert back.window_start == a.window_start
        assert back.detail == a.detail
        assert np.array_equal(np.asarray(back.hosts, dtype=str), a.hosts)

    def test_window_summary_roundtrip(self, driven):
        w = driven["roll"].summaries("minute", limit=1)[0]
        back = WindowSummary.from_dict(json.loads(w.to_json()))
        assert back.n_cells == w.n_cells and back.level == w.level
        assert back.top_dst == w.top_dst
        assert back.alpha == pytest.approx(w.alpha)

    def test_root_cause_roundtrip(self, driven):
        att = driven["truth"]["attacks"][2]
        rc = root_cause(driven["roll"], att["start"], att["stop"],
                        [att["victim"]], top_k=2, num_iters=5)
        back = RootCauseReport.from_dict(json.loads(rc.to_json()))
        assert np.array_equal(np.asarray(back.hosts, dtype=str), rc.hosts)
        assert np.allclose(np.asarray(back.ranks, float), rc.ranks)


# ---------------------------------------------------------------------------
# WriterPool ingest tap
# ---------------------------------------------------------------------------

class TestIngestTap:
    def test_tap_coherent_under_concurrent_async_writes(self):
        """Blocks enqueued from several threads over a sharded pool: the
        rollup must still see exactly the table's contents."""
        cfg = ScenarioConfig(duration_s=30.0, n_hosts=48, base_rate=50.0,
                             seed=9, t0=T0)
        blocks = list(stream_blocks(cfg))
        total = sum(A.nnz for _, A in blocks)
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="memory",
               n_instances=2, tablets_per_instance=2)
        roll = TemporalRollup()
        T.add_ingest_tap(roll.ingest)
        lanes = [blocks[i::4] for i in range(4)]

        def lane(blks):
            for _, A in blks:
                T.put(A, sync=False)

        threads = [threading.Thread(target=lane, args=(l,)) for l in lanes]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        T.flush()
        st = roll.stats()
        assert st["n_attributed"] + st["n_pending"] == total
        assert st["n_pending"] == 0             # blocks carry their times
        assert roll.totals("second")["n_cells"] == total
        assert T.writer().stats()["tap_errors"] == 0
        T.close()

    def test_sync_puts_also_reach_the_tap(self):
        T = DB("Tedge", backend="memory")
        seen = []
        T.add_ingest_tap(lambda r, c, v: seen.append(len(r)))
        A = Assoc("r1,r2,", "c1,c2,", [1.0, 2.0])
        T.put(A, sync=True)
        assert sum(seen) == 2
        T.close()

    def test_tap_errors_counted_not_fatal(self):
        T = DB("Tedge", backend="memory")

        def bad_tap(r, c, v):
            raise RuntimeError("observer bug")

        T.add_ingest_tap(bad_tap)
        T.put(Assoc("r1,", "c1,", [1.0]), sync=False)
        T.flush()                               # must not raise
        st = T.writer().stats()
        assert st["tap_errors"] >= 1
        assert st["n_written"] >= 1
        assert st["n_taps"] == 1
        T.close()

    def test_remove_tap_stops_updates(self):
        T = DB("Tedge", backend="memory")
        seen = []
        tap = lambda r, c, v: seen.append(len(r))
        T.add_ingest_tap(tap)
        T.put(Assoc("r1,", "c1,", [1.0]), sync=False)
        T.flush()
        T.remove_ingest_tap(tap)
        T.put(Assoc("r2,", "c2,", [1.0]), sync=False)
        T.flush()
        assert sum(seen) == 1
        T.close()


# ---------------------------------------------------------------------------
# gateway surface (+ the end-to-end acceptance demo)
# ---------------------------------------------------------------------------

TOKENS = {"tok-a": Tenant("alice", rate=1000.0, burst=2000.0)}


def _req(gw, method, path, body=None, timeout=30):
    host, port = gw.address.split(":")
    c = http.client.HTTPConnection(host, int(port), timeout=timeout)
    headers = {"Authorization": "Bearer tok-a"}
    raw = json.dumps(body).encode() if body is not None else None
    c.request(method, path, body=raw, headers=headers)
    r = c.getresponse()
    data = json.loads(r.read())
    c.close()
    return r.status, data


@pytest.fixture(scope="module")
def sgw():
    """The acceptance demo, held open for the route tests: the scenario
    mix streamed through async ingest into a gateway with streaming
    analytics attached."""
    cfg = attack_cfg(seed=3)
    rec, truth = synth_scenario(cfg)
    T = DB("Tedge", "TedgeT", "TedgeDeg", backend="memory")
    sa = StreamAnalytics(interval=30.0)         # tests drive step()
    gw = Gateway(T, TokenAuth(TOKENS), stats_interval=0.2,
                 stream_analytics=sa)
    gw.start()
    for _, A in stream_blocks(cfg, rec=rec):
        T.put(A, sync=False)
        sa.step()
    T.flush()
    sa.step(force=True)
    yield dict(gw=gw, cfg=cfg, rec=rec, truth=truth, sa=sa)
    gw.stop()
    T.close()


class TestGatewayStreaming:
    def test_windows_route(self, sgw):
        s, d = _req(sgw["gw"], "GET", "/v1/windows?level=second&limit=500")
        assert s == 200 and d["n"] > 60
        w = d["windows"][0]
        assert w["level"] == "second" and w["n_packets"] > 0
        s, d = _req(sgw["gw"], "GET", "/v1/windows?level=minute")
        assert s == 200 and 1 <= d["n"] <= 5
        since = T0 + 60.0
        s, d = _req(sgw["gw"], "GET",
                    f"/v1/windows?level=second&since={since}")
        assert s == 200
        assert all(w["start"] >= since for w in d["windows"])

    def test_windows_route_validates_level(self, sgw):
        s, d = _req(sgw["gw"], "GET", "/v1/windows?level=fortnight")
        assert s == 400

    def test_alerts_route_with_kind_filter(self, sgw):
        s, d = _req(sgw["gw"], "GET", "/v1/alerts?kind=ddos")
        assert s == 200 and d["n"] >= 1
        att = sgw["truth"]["attacks"][2]
        for a in d["alerts"]:
            assert a["kind"] == "ddos"
            assert a["victim"] == att["victim"]

    def test_all_attacks_surface_with_correct_windows(self, sgw):
        """Acceptance: all three injected attacks appear as alerts with
        the right type and window."""
        s, d = _req(sgw["gw"], "GET", "/v1/alerts?limit=1000")
        assert s == 200
        for att in sgw["truth"]["attacks"]:
            hits = [a for a in d["alerts"] if a["kind"] == att["kind"]
                    and a["window_start"] < att["stop"]
                    and a["window_stop"] > att["start"]]
            assert hits, f"{att['kind']} missing from /v1/alerts"

    def test_rollup_matches_table_recount(self, sgw):
        """Acceptance: per-level totals exactly match a batch recount of
        the ingested table."""
        gw = sgw["gw"]
        A = gw.table[:, :].eval()
        roll = gw.stream_analytics.rollup
        for lv, _ in roll.levels:
            assert roll.totals(lv)["n_cells"] == A.nnz
        n_time = int(np.char.startswith(A.triples()[1],
                                        "frame.time|").sum())
        assert roll.totals("second")["n_packets"] == n_time

    def test_root_cause_job_ranks_attacker_top3(self, sgw):
        """Acceptance: the root-cause job puts an injected attacker in
        its top-3."""
        att = sgw["truth"]["attacks"][2]
        s, d = _req(sgw["gw"], "POST", "/v1/jobs",
                    body={"kind": "root_cause",
                          "params": {"start": att["start"] - 1.0,
                                     "stop": att["stop"] + 1.0,
                                     "seeds": [att["victim"]],
                                     "top_k": 3}})
        assert s == 200
        jid = d["job"]
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline:
            s, d = _req(sgw["gw"], "GET", f"/v1/jobs/{jid}/result")
            if s != 202:
                break
            time.sleep(0.1)
        assert s == 200, d
        hosts = d["result"]["report"]["hosts"]
        assert any(h in att["attackers"] for h in hosts)

    def test_root_cause_job_rejects_bad_params(self, sgw):
        s, d = _req(sgw["gw"], "POST", "/v1/jobs",
                    body={"kind": "root_cause", "params": {}})
        jid = d["job"]
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            s, d = _req(sgw["gw"], "GET", f"/v1/jobs/{jid}")
            if d["status"] in ("done", "failed"):
                break
            time.sleep(0.05)
        assert d["status"] == "failed"

    def test_stats_exposes_streaming_section(self, sgw):
        s, d = _req(sgw["gw"], "GET", "/v1/stats")
        assert s == 200
        st = d["streaming"]
        assert st["rollup"]["n_attributed"] > 0
        assert st["bank"]["n_alerts"] >= 1
        writers = d["table"]["writers"]
        assert writers["n_taps"] == 1

    def test_sse_alert_replay(self, sgw):
        host, port = sgw["gw"].address.split(":")
        c = http.client.HTTPConnection(host, int(port), timeout=15)
        c.request("GET", "/v1/stream/alerts?replay=3&n=2",
                  headers={"Authorization": "Bearer tok-a"})
        r = c.getresponse()
        assert r.status == 200
        assert r.getheader("Content-Type") == "text/event-stream"
        frames = [l for l in r.read().decode().split("\n\n")
                  if l.startswith("data: ")]
        c.close()
        assert len(frames) == 2
        alert = json.loads(frames[0][len("data: "):])
        assert alert["kind"] in ("spc", "c2", "scan", "ddos")

    def test_sse_live_alert_delivery(self, sgw):
        """A subscriber connected *before* the traffic arrives receives
        the alert pushed when the detector pass raises it."""
        gw, cfg = sgw["gw"], sgw["cfg"]
        host, port = gw.address.split(":")
        got = []

        def subscribe():
            c = http.client.HTTPConnection(host, int(port), timeout=60)
            c.request("GET", "/v1/stream/alerts?n=1",
                      headers={"Authorization": "Bearer tok-a"})
            r = c.getresponse()
            got.append(r.read().decode())
            c.close()

        t = threading.Thread(target=subscribe)
        t.start()
        time.sleep(0.3)                     # let the subscription settle
        # a fresh flood burst 100 s after the scenario: new ddos alerts
        burst = ScenarioConfig(
            duration_s=200.0, n_hosts=64, base_rate=1.0, seed=4, t0=T0,
            attacks=(AttackSpec("ddos", start=190, duration=8,
                                n_hosts=8, rate=40.0),))
        rec, _ = synth_scenario(burst)
        keep = rec["ts_sec"] >= T0 + 185
        for _, A in stream_blocks(burst, rec=rec[keep]):
            gw.table.put(A, sync=False)
        gw.table.flush()
        sgw["sa"].step(force=True)
        t.join(timeout=30)
        assert got and "data: " in got[0]


class TestGatewayWithoutStreaming:
    def test_routes_404_when_not_enabled(self):
        T = DB("Tedge", backend="memory")
        gw = Gateway(T, TokenAuth(TOKENS))
        gw.start()
        try:
            for path in ("/v1/windows", "/v1/alerts", "/v1/stream/alerts"):
                s, d = _req(gw, "GET", path)
                assert s == 404, path
        finally:
            gw.stop()
            T.close()
