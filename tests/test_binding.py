"""D4M binding layer: DBTable routing, put round-trips, degree guard,
and lazy-vs-eager deferred-algebra equivalence."""
import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis, skipping when absent

from repro.core import Assoc, KeyRange, StartsWith, lazy
from repro.core import expr as X
from repro.db import (DB, AccidentalDenseError, DBTable, EdgeStore,
                      MultiInstanceDB, bind, put)


def assoc_close(a, b, tol=1e-9):
    """Keys identical, values numerically close (device sums are f32)."""
    if hasattr(a, "eval"):
        a = a.eval()
    if hasattr(b, "eval"):
        b = b.eval()
    if not (np.array_equal(a.row, b.row) and np.array_equal(a.col, b.col)):
        return False
    ra, ca, va = a.triples()
    rb, cb, vb = b.triples()
    if not (np.array_equal(ra, rb) and np.array_equal(ca, cb)):
        return False
    if a.val is not None or b.val is not None:
        return np.array_equal(np.asarray(va, str), np.asarray(vb, str))
    return np.allclose(np.asarray(va, float), np.asarray(vb, float),
                       atol=tol, rtol=1e-6)


def small_incidence():
    rows = "p1,p1,p2,p2,p3,p3,p4,p4,"
    cols = ("ip.src|a,ip.dst|b,ip.src|a,ip.dst|c,"
            "ip.src|d,ip.dst|b,ip.src|a,ip.dst|b,")
    return Assoc(rows, cols, "1,1,1,1,1,1,1,1,")


class TestRouting:
    def test_row_query_routes_to_row_table(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        A = T["p2,", :].eval()
        assert T.stats["row"] == 1 and T.stats["col"] == 0
        assert set(A.col) == {"ip.src|a", "ip.dst|c"}

    def test_col_query_routes_to_transpose_table(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        A = T[:, "ip.dst|b,"].eval()
        assert T.stats["col"] == 1 and T.stats["row"] == 0
        assert T.stats["full"] == 0
        assert set(A.row) == {"p1", "p3", "p4"}

    def test_prefix_and_range_and_full(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        block = T[:, "ip.src|*,"].eval()
        assert set(block.col) == {"ip.src|a", "ip.src|d"}
        rng = T["p2,:,p3,", :].eval()
        assert set(rng.row) == {"p2", "p3"}
        assert T[:, :].eval().nnz == 8
        assert T.stats["full"] == 1

    def test_degree_reads_degree_table(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        assert T.degree("ip.dst|b") == 3.0
        deg = T.degree_assoc("ip.dst|")
        assert assoc_close(deg, Assoc("ip.dst|b,ip.dst|c,", "degree,",
                                      [3.0, 1.0]))

    def test_degree_table_binding_alone(self):
        backend = EdgeStore(n_tablets=2)
        T = bind(backend)
        put(T, small_incidence())
        Tdeg = DBTable(backend, ("TedgeDeg",))
        A = Tdeg["ip.src|*,", :].eval()
        assert set(A.row) == {"ip.src|a", "ip.src|d"}
        r, _, v = A.triples()
        assert dict(zip(r, np.asarray(v, float)))["ip.src|a"] == 3.0

    def test_column_query_without_transpose_table_fails(self):
        T = DB("Tedge", tablets_per_instance=2)
        put(T, small_incidence())
        with pytest.raises(KeyError):
            T[:, "ip.dst|b,"].eval()


class TestDegreeGuard:
    def test_supernode_column_query_raises(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2,
               degree_limit=2.0)
        put(T, small_incidence())
        with pytest.raises(AccidentalDenseError) as ei:
            T[:, "ip.dst|*,"].eval()
        assert ("ip.dst|b", 3.0) in ei.value.offenders
        # below-limit columns still pass
        assert T[:, "ip.dst|c,"].eval().nnz == 1

    def test_guard_lift(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2,
               degree_limit=2.0)
        put(T, small_incidence())
        assert T.with_degree_limit(None)[:, "ip.dst|*,"].eval().nnz == 4


class TestPutRoundTrip:
    def test_multi_instance_roundtrip(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=3,
               tablets_per_instance=2)
        E = small_incidence()
        n = put(T, E, batch_size=3)  # forces multiple writer batches
        assert n == 8
        assert assoc_close(T[:, :].eval().logical(), E.logical())
        # degrees aggregate across instances
        assert T.degree("ip.src|a") == 3.0

    def test_batches_spread_across_instances(self):
        db = MultiInstanceDB(n_instances=4, tablets_per_instance=2)
        T = bind(db)
        rows = [f"p{i}" for i in range(64)]
        E = Assoc(rows, ["ip.src|x"] * 64, "1," * 64)
        put(T, E)
        used = sum(1 for inst in db.instances if inst.n_entries > 0)
        assert used >= 3  # row-hash partitioning keeps write paths busy

    def test_file_id_pins_instance(self):
        db = MultiInstanceDB(n_instances=4, tablets_per_instance=2)
        T = bind(db)
        put(T, small_incidence(), file_id="capture0")
        used = sum(1 for inst in db.instances if inst.n_entries > 0)
        assert used == 1  # the paper's file→instance routing

    def test_query_shim_still_works_and_warns(self):
        db = EdgeStore(n_tablets=2)
        put(bind(db), small_incidence())
        with pytest.warns(DeprecationWarning):
            cells = db.query_col("ip.dst|b")
        assert set(cells) == {"p1", "p3", "p4"}
        with pytest.warns(DeprecationWarning):
            assert db.query_degree("ip.dst|b") == 3.0


class TestSelectionGrammar:
    def test_star_prefix_on_assoc(self):
        E = small_incidence()
        assert set(E[:, "ip.src|*,"].col) == {"ip.src|a", "ip.src|d"}
        mixed = E[:, "ip.dst|c,ip.src|*,"]
        assert set(mixed.col) == {"ip.dst|c", "ip.src|a", "ip.src|d"}

    def test_selector_objects_match_string_grammar(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        assert assoc_close(T[:, StartsWith("ip.src|")],
                           T[:, "ip.src|*,"])
        assert assoc_close(T[KeyRange("p2", "p3"), :],
                           T["p2,:,p3,", :])


def rand_assoc(rng, nr=8, nc=8, nnz=24):
    r = [f"r{int(i):02d}" for i in rng.integers(0, nr, nnz)]
    c = [f"c{int(j):02d}" for j in rng.integers(0, nc, nnz)]
    v = rng.integers(1, 6, nnz).astype(np.float64)
    return Assoc(r, c, v)


class TestLazyEagerEquivalence:
    """The eager Assoc semantics are the spec for the deferred executor."""

    def test_chain_matches_eager(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            A = rand_assoc(rng)
            B = rand_assoc(rng)
            eager = ((A.logical().T * B.logical()) > 1.0) * 3.0
            lz = ((lazy(A).logical().T * lazy(B).logical()) > 1.0) * 3.0
            assert assoc_close(eager, lz)

    def test_every_op_matches_eager(self):
        rng = np.random.default_rng(1)
        A, B = rand_assoc(rng), rand_assoc(rng)
        cases = [
            (A + B, lazy(A) + lazy(B)),
            (A - B, lazy(A) - lazy(B)),
            (A.multiply(B), lazy(A).multiply(lazy(B))),
            (A * B, lazy(A) * lazy(B)),
            (A.T, lazy(A).T),
            (A.logical(), lazy(A).logical()),
            (A * 2.5, lazy(A) * 2.5),
            (A + 1.0, lazy(A) + 1.0),
            (A > 2, lazy(A) > 2),
            (A <= 3, lazy(A) <= 3),
            (A.sum(0), lazy(A).sum(0)),
            (A.sum(1), lazy(A).sum(1)),
            (A.sqin(), lazy(A).sqin()),
            (A[StartsWith("r0"), :], lazy(A)[StartsWith("r0"), :]),
            (A["r01,:,r05,", "c02,c04,"], lazy(A)["r01,:,r05,",
                                                  "c02,c04,"]),
        ]
        for i, (eager, lz) in enumerate(cases):
            assert assoc_close(eager, lz), f"case {i} diverged"

    def test_selection_pushdown_through_transpose_and_matmul(self):
        rng = np.random.default_rng(2)
        A, B = rand_assoc(rng), rand_assoc(rng)
        eager = (A.T * B)[StartsWith("c0"), "c03,c05,"]
        lz = (lazy(A).T * lazy(B))[StartsWith("c0"), "c03,c05,"]
        assert assoc_close(eager, lz)

    def test_pushdown_reaches_table_scan(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        # subscript applied *after* algebra still routes as a col query
        expr = T.lazy()[:, "ip.dst|*,"]
        expr.eval()
        assert T.stats["col"] == 1 and T.stats["full"] == 0

    def test_cse_single_scan_for_repeated_subscript(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        chain = (T[:, "ip.dst|*,"].logical().T
                 * T[:, "ip.dst|*,"].logical()) > 0.5
        chain.eval()
        assert T.stats["col"] == 1  # CSE: two subscripts, one scan

    def test_device_lowered_sum_and_spmv_match_host(self, monkeypatch):
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 1)
        rng = np.random.default_rng(3)
        A = rand_assoc(rng, nr=12, nc=12, nnz=60)
        assert assoc_close(A.sum(0), lazy(A).sum(0), tol=1e-4)
        assert assoc_close(A.sum(1), lazy(A).sum(1), tol=1e-4)
        ones = Assoc([f"c{j:02d}" for j in range(12)], ["total"] * 12,
                     np.ones(12))
        assert assoc_close(A * ones, lazy(A) * lazy(ones), tol=1e-4)

    def test_fused_matmul_chain_matches_eager(self, monkeypatch):
        """A @ B @ x lowers to successive device spmvs (the intermediate
        vector never leaves the device); result must match the eager
        left-associated host chain."""
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 1)
        rng = np.random.default_rng(5)
        A = rand_assoc(rng, nr=12, nc=12, nnz=60)
        B = rand_assoc(rng, nr=12, nc=12, nnz=60)
        x = Assoc([f"c{j:02d}" for j in range(12)], ["total"] * 12,
                  np.ones(12))
        eager = (A * B) * x
        lz = (lazy(A) * lazy(B)) * lazy(x)
        assert assoc_close(eager, lz, tol=1e-3)

    def test_fused_matmul_chain_pallas_path(self, monkeypatch):
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 1)
        monkeypatch.setattr(X, "USE_PALLAS_SPMV", True)
        rng = np.random.default_rng(6)
        A = rand_assoc(rng, nr=10, nc=10, nnz=40)
        B = rand_assoc(rng, nr=10, nc=10, nnz=40)
        x = Assoc([f"c{j:02d}" for j in range(10)], ["total"] * 10,
                  np.ones(10))
        eager = (A * B) * x
        lz = (lazy(A) * lazy(B)) * lazy(x)
        assert assoc_close(eager, lz, tol=1e-3)

    def test_long_chain_and_nonvector_fallback(self, monkeypatch):
        monkeypatch.setattr(X, "DEVICE_NNZ_THRESHOLD", 1)
        rng = np.random.default_rng(7)
        A = rand_assoc(rng, nr=9, nc=9, nnz=40)
        B = rand_assoc(rng, nr=9, nc=9, nnz=40)
        C = rand_assoc(rng, nr=9, nc=9, nnz=40)
        x = Assoc([f"c{j:02d}" for j in range(9)], ["total"] * 9,
                  np.ones(9))
        # four-factor chain ending in a vector
        assert assoc_close(((A * B) * C) * x,
                           ((lazy(A) * lazy(B)) * lazy(C)) * lazy(x),
                           tol=1e-3)
        # matrix-valued chain falls back to pairwise host matmul
        assert assoc_close((A * B) * C,
                           (lazy(A) * lazy(B)) * lazy(C), tol=1e-3)

    def test_categorical_filter_keeps_eager_semantics(self):
        A = Assoc("r1,r2,r3,", "c,c,c,", "beta,alpha,gamma,", agg="min")
        assert assoc_close(A > "alpha", lazy(A) > "alpha")

    def test_explicit_zero_parity(self):
        A = Assoc("r1,r2,", "c1,c2,", [3.0, -5.0])
        assert assoc_close((A > -10) + 5.0, ((lazy(A) > -10) + 5.0))
        assert assoc_close((A > 0) * 0.0, ((lazy(A) > 0) * 0.0))

    def test_positional_selectors_are_pushdown_barriers(self):
        A = Assoc(["p1", "p2", "p3"], ["a", "b", "c"], [1.0, 9.0, 9.0])
        mask = np.array([True, False])
        eager = (A > 5)[np.array([0]), :]
        assert assoc_close(eager, (lazy(A) > 5)[np.array([0]), :])
        B = Assoc(["p1", "p2"], ["a", "b"], [1.0, 1.0])
        assert assoc_close((A + B)[mask[:1], :],
                           (lazy(A) + lazy(B))[mask[:1], :])

    def test_key_list_selection_keeps_sorted_dictionaries(self):
        E = Assoc(["p1", "p1", "p2"], ["a", "b", "b"], [1.0, 2.0, 5.0])
        A = E[:, "b,a,"]          # reversed request still sorts
        assert list(A.col) == ["a", "b"]
        vec = Assoc(["p1", "p2"], ["total", "total"], [1.0, 1.0])
        prod = A.T * vec          # alignment relies on sorted dictionaries
        r, _, v = prod.triples()
        assert dict(zip(r, np.asarray(v, float))) == {"a": 1.0, "b": 7.0}

    def test_positional_selector_rejected_on_table(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        put(T, small_incidence())
        with pytest.raises(TypeError):
            T[np.array([True, False]), :].eval()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6),
                              st.integers(1, 5)), min_size=1, max_size=30),
           st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6),
                              st.integers(1, 5)), min_size=1, max_size=30),
           st.sampled_from(["matmul", "add", "emul", "chain"]),
           st.floats(0.5, 4.0))
    def test_property_random_chains(self, ta, tb, mode, k):
        A = Assoc([f"r{i}" for i, _, _ in ta],
                  [f"c{j}" for _, j, _ in ta],
                  [float(v) for _, _, v in ta])
        B = Assoc([f"r{i}" for i, _, _ in tb],
                  [f"c{j}" for _, j, _ in tb],
                  [float(v) for _, _, v in tb])
        if mode == "matmul":
            eager, lz = A.T * B, lazy(A).T * lazy(B)
        elif mode == "add":
            eager, lz = (A + B) > k, (lazy(A) + lazy(B)) > k
        elif mode == "emul":
            eager, lz = A.multiply(B), lazy(A).multiply(lazy(B))
        else:
            eager = ((A.logical().T * A.logical()) > k) * 2.0
            lz = ((lazy(A).logical().T * lazy(A).logical()) > k) * 2.0
        assert assoc_close(eager, lz)


class TestIngestThroughBinding:
    def test_stage6_equivalent(self, tmp_path):
        """bind(db) + put == the old direct db.put path."""
        E = small_incidence()
        db_old = EdgeStore(n_tablets=2)
        db_old.put(E.putval("1,"))
        db_new = EdgeStore(n_tablets=2)
        put(bind(db_new), E.putval("1,"))
        assert db_old.n_entries == db_new.n_entries
        assert db_old.degree("ip.dst|b") == db_new.degree("ip.dst|b")
