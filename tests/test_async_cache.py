"""Hot-path overhaul coverage: async writer pool (ordering, flush
barrier, error propagation under FaultInjector) and the binding-layer
TTL scan cache (hit/miss accounting, write-path invalidation, TTL
expiry re-scan)."""
import numpy as np
import pytest

from repro.core.assoc import Assoc
from repro.db import (DB, AsyncWriterError, DBTable, EdgeStore,
                      MultiInstanceDB, WriterPool, bind, put)
from repro.pipeline.runner import FaultInjector


def small_incidence():
    rows = "p1,p1,p2,p2,p3,p3,p4,p4,"
    cols = ("ip.src|a,ip.dst|b,ip.src|a,ip.dst|c,"
            "ip.src|d,ip.dst|b,ip.src|a,ip.dst|b,")
    return Assoc(rows, cols, "1,1,1,1,1,1,1,1,")


class TestAsyncWriter:
    def test_async_put_visible_after_flush(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=3,
               tablets_per_instance=2)
        E = small_incidence()
        n = put(T, E, sync=False)
        assert n == 8
        T.flush()
        assert T.backend.n_entries == 8
        A = T[:, :].eval()
        assert A.nnz == 8
        assert T.degree("ip.src|a") == 3.0
        T.close()

    def test_scan_auto_flushes(self):
        """Queued writes become visible at the next binding scan, with
        no explicit flush."""
        T = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=2,
               tablets_per_instance=2)
        put(T, small_incidence(), sync=False)
        assert T[:, "ip.dst|b,"].eval().nnz == 3
        T.close()

    def test_ordering_last_write_wins(self):
        """One writer thread per instance + FIFO queues: batches apply
        in submission order, so re-putting a cell overwrites it."""
        T = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=4,
               tablets_per_instance=2)
        for i in range(20):
            put(T, Assoc("p1,", "ip.src|a,", f"v{i:02d},"), sync=False)
        T.flush()
        _, _, v = T["p1,", :].eval().triples()
        assert list(v) == ["v19"]
        T.close()

    def test_flush_barrier_drains_everything(self):
        db = MultiInstanceDB(n_instances=3, tablets_per_instance=2)
        T = bind(db)
        rows = [f"p{i}" for i in range(300)]
        E = Assoc(rows, ["ip.src|x"] * 300, "1," * 300)
        put(T, E, batch_size=7, sync=False)   # many small batches
        pool = T.writer()
        T.flush()
        assert pool.pending == 0
        assert db.n_entries == 300
        # writes spread across instance write paths
        assert sum(1 for i in db.instances if i.n_entries > 0) >= 2
        T.close()

    def test_sync_put_through_existing_pool_stays_ordered(self):
        """Once a pool exists, sync puts route through it (and flush),
        so they cannot overtake queued async batches."""
        T = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=2,
               tablets_per_instance=2)
        put(T, Assoc("p1,", "ip.src|a,", "old,"), sync=False)
        put(T, Assoc("p1,", "ip.src|a,", "new,"), sync=True)
        _, _, v = T["p1,", :].eval().triples()
        assert list(v) == ["new"]
        T.close()

    def test_exception_propagates_at_flush(self):
        db = MultiInstanceDB(n_instances=2, tablets_per_instance=2)
        T = bind(db)
        pool = T.writer(fault_injector=FaultInjector(kill_rate=1.0, seed=1))
        put(T, small_incidence(), sync=False)
        with pytest.raises(AsyncWriterError):
            T.flush()
        # the error also fails the next submit, not just barriers
        with pytest.raises(AsyncWriterError):
            pool.submit(np.asarray(["p9"]), np.asarray(["ip.src|z"]),
                        np.asarray(["1"]))

    def test_close_reraises_and_stops(self):
        db = EdgeStore(n_tablets=2)
        T = bind(db)
        T.writer(fault_injector=FaultInjector(kill_rate=1.0, seed=2))
        put(T, small_incidence(), sync=False)
        with pytest.raises(AsyncWriterError):
            T.close()
        # pool detached: a fresh put succeeds synchronously
        assert put(T, small_incidence()) == 8

    def test_pin_routes_to_one_instance(self):
        db = MultiInstanceDB(n_instances=4, tablets_per_instance=2)
        T = bind(db)
        put(T, small_incidence(), file_id="capture0", sync=False)
        T.flush()
        assert sum(1 for i in db.instances if i.n_entries > 0) == 1
        T.close()


class TestScanCache:
    def make_table(self, **kw):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2, **kw)
        put(T, small_incidence())
        return T

    def test_hit_serves_without_rescan(self):
        T = self.make_table()
        a = T[:, "ip.dst|*,"].eval()
        b = T[:, "ip.dst|*,"].eval()
        assert T.stats["cache_miss"] == 1 and T.stats["cache_hit"] == 1
        assert T.stats["col"] == 1          # the tablets saw one scan
        assert a.triples()[0].tolist() == b.triples()[0].tolist()

    def test_put_into_cached_band_evicts(self):
        T = self.make_table()
        assert T[:, "ip.dst|*,"].eval().nnz == 4
        put(T, Assoc("p9,", "ip.dst|b,", "1,"))
        assert T[:, "ip.dst|*,"].eval().nnz == 5   # re-scanned
        assert T.stats["cache_miss"] == 2

    def test_put_outside_band_keeps_cache(self):
        T = self.make_table()
        T[:, "ip.dst|*,"].eval()
        put(T, Assoc("p9,", "tcp.dstport|80,", "1,"))
        T[:, "ip.dst|*,"].eval()
        assert T.stats["cache_hit"] == 1            # band untouched

    def test_row_band_invalidation(self):
        T = self.make_table()
        assert T["p2,", :].eval().nnz == 2
        put(T, Assoc("p2,", "udp.dstport|53,", "1,"))
        assert T["p2,", :].eval().nnz == 3
        assert T.stats["cache_miss"] == 2

    def test_direct_store_write_also_invalidates(self):
        """Writes that bypass the binding still evict via the store-side
        hook (the cache is attached to every instance)."""
        T = self.make_table()
        assert T[:, "ip.dst|*,"].eval().nnz == 4
        T.backend.put(Assoc("p9,", "ip.dst|z,", "1,"))
        assert T[:, "ip.dst|*,"].eval().nnz == 5

    def test_ttl_expiry_rescans(self):
        T = self.make_table()
        T[:, "ip.dst|*,"].eval()
        T[:, "ip.dst|*,"].eval()
        assert T.stats["col"] == 1
        cache = T._cache
        real = cache.clock
        cache.clock = lambda: real() + cache.ttl + 1.0   # jump past TTL
        T[:, "ip.dst|*,"].eval()
        assert T.stats["col"] == 2                       # re-scanned
        assert T.stats["cache_miss"] == 2

    def test_view_ttl_honored_on_shared_cache(self):
        """A later view's cache_ttl governs the entries it inserts, even
        though the ScanCache object was created by an earlier view."""
        T = self.make_table()                      # default TTL
        T2 = bind(T.backend, cache_ttl=5.0)        # shorter view TTL
        T2[:, "ip.dst|*,"].eval()
        cache = T._cache
        real = cache.clock
        cache.clock = lambda: real() + 6.0         # past 5 s, before 60 s
        T2[:, "ip.dst|*,"].eval()
        assert T2.stats["cache_miss"] == 2         # expired, re-scanned

    def test_concurrent_write_blocks_stale_admission(self):
        """A write landing between the store read and cache admission
        must prevent the pre-write result from being cached."""
        T = self.make_table()
        cache = T._cache
        v0 = cache.version
        out = T._scan_route(None, "ip.dst|*,")
        put(T, Assoc("p9,", "ip.dst|b,", "1,"))    # bumps version
        key = (T.tables, ":", "ip.dst|*,")
        from repro.db.binding import _Atoms
        cache.put(key, out, "col", _Atoms("atoms", prefixes=("ip.dst|",)),
                  if_version=v0)
        assert cache.get(key) is None              # admission was skipped

    def test_cache_shared_across_views(self):
        T = self.make_table()
        T2 = bind(T.backend)
        T[:, "ip.dst|*,"].eval()
        T2[:, "ip.dst|*,"].eval()
        assert T2.stats["cache_hit"] == 1

    def test_opt_out_view(self):
        T = self.make_table(cache_ttl=0)
        T[:, "ip.dst|*,"].eval()
        T[:, "ip.dst|*,"].eval()
        assert T.stats["col"] == 2
        assert T.stats["cache_hit"] == 0 and T.stats["cache_miss"] == 0

    def test_degree_scan_invalidated_by_column_write(self):
        backend = EdgeStore(n_tablets=2)
        put(bind(backend), small_incidence())
        Tdeg = DBTable(backend, ("TedgeDeg",))
        a = Tdeg["ip.dst|*,", :].eval()
        r, _, v = a.triples()
        assert dict(zip(r, np.asarray(v, float)))["ip.dst|b"] == 3.0
        put(bind(backend), Assoc("p9,", "ip.dst|b,", "1,"))
        b = Tdeg["ip.dst|*,", :].eval()
        r, _, v = b.triples()
        assert dict(zip(r, np.asarray(v, float)))["ip.dst|b"] == 4.0

    def test_degree_guard_fires_even_when_band_is_hot(self):
        from repro.db import AccidentalDenseError
        T = self.make_table()
        assert T[:, "ip.dst|*,"].eval().nnz == 4     # cached, unguarded
        with pytest.raises(AccidentalDenseError):
            T.with_degree_limit(2.0)[:, "ip.dst|*,"].eval()

    def test_range_band_invalidation(self):
        T = self.make_table()
        assert T["p2,:,p3,", :].eval().nnz == 4
        put(T, Assoc("p3,", "icmp.type|8,", "1,"))
        assert T["p2,:,p3,", :].eval().nnz == 5
        put(T, Assoc("p8,", "icmp.type|8,", "1,"))   # outside the range
        T["p2,:,p3,", :].eval()
        assert T.stats["cache_hit"] == 1


class TestWriterRetry:
    def test_transient_failure_retried_and_applied(self):
        """A block whose first put is killed is re-put with backoff —
        the flush barrier succeeds and no data is lost (Accumulo
        BatchWriter semantics)."""
        db = EdgeStore(n_tablets=2)
        T = bind(db)
        pool = T.writer(fault_injector=FaultInjector(kill_rate=1.0, seed=3,
                                                     max_kills=1),
                        retry_backoff_s=0.01)
        put(T, small_incidence(), sync=False)
        T.flush()                        # no AsyncWriterError raised
        assert db.n_entries == 8
        assert pool.n_retried >= 1
        T.close()

    def test_retries_exhausted_still_propagates(self):
        db = EdgeStore(n_tablets=2)
        T = bind(db)
        T.writer(fault_injector=FaultInjector(kill_rate=1.0, seed=4),
                 max_retries=1, retry_backoff_s=0.01)
        put(T, small_incidence(), sync=False)
        with pytest.raises(AsyncWriterError):
            T.flush()
        assert db.n_entries == 0

    def test_retry_disabled_with_zero_max_retries(self):
        db = EdgeStore(n_tablets=2)
        T = bind(db)
        pool = T.writer(fault_injector=FaultInjector(kill_rate=1.0, seed=5,
                                                     max_kills=1),
                        max_retries=0)
        put(T, small_incidence(), sync=False)
        with pytest.raises(AsyncWriterError):
            T.flush()
        assert pool.n_retried == 0


class TestAdmissionPolicy:
    def burst_writes(self, T, n=8):
        for i in range(n):
            put(T, Assoc(f"q{i},", "tcp.dstport|80,", "1,"))

    def test_full_scan_skipped_on_write_heavy_backend(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        cache = T._cache
        cache.full_scan_wps_limit = 0.5      # 8 writes / 10 s window > 0.5
        self.burst_writes(T)
        T[:, :].eval()
        T[:, :].eval()
        assert T.stats["full"] == 2          # never admitted, rescanned
        assert cache.admission_skips >= 1

    def test_column_band_still_admitted(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        T._cache.full_scan_wps_limit = 0.5
        self.burst_writes(T)
        T[:, "tcp.dstport|*,"].eval()
        T[:, "tcp.dstport|*,"].eval()
        assert T.stats["cache_hit"] == 1     # only 'any'-band is gated

    def test_full_scan_admitted_when_quiet(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        cache = T._cache
        cache.full_scan_wps_limit = 0.5
        self.burst_writes(T)
        real = cache.clock
        cache.clock = lambda: real() + cache.wps_window + 1  # burst ages out
        T[:, :].eval()
        T[:, :].eval()
        assert T.stats["full"] == 1 and T.stats["cache_hit"] == 1
        assert cache.writes_per_s == 0.0


class TestWriterPoolUnit:
    def test_rejects_unknown_backend(self):
        with pytest.raises(TypeError):
            WriterPool(object())

    def test_spill_threshold_coalesces(self):
        db = EdgeStore(n_tablets=2)
        pool = WriterPool(db, spill_rows=50)
        for i in range(10):                      # 10×10 rows, spills at 50
            r = np.asarray([f"p{i:02d}{j}" for j in range(10)])
            c = np.asarray(["ip.src|x"] * 10)
            v = np.asarray(["1"] * 10)
            pool.submit(r, c, v)
        pool.flush()
        assert pool.n_written == 100
        assert db.n_entries == 100
        pool.close()
