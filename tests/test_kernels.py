"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru import rglru_scan
from repro.kernels.segsum import segsum
from repro.kernels.spmm import spgemm_sel, spmm_ell
from repro.kernels.spmv import EllOverflowError, csr_to_ell, spmv_ell
from repro.kernels.wkv6 import wkv6


def key(i=0):
    return jax.random.key(i)


class TestSegsum:
    @pytest.mark.parametrize("nnz,nseg,block_nnz,block_seg", [
        (100, 17, 32, 8),
        (1000, 300, 256, 128),
        (5000, 64, 1024, 64),
        (7, 3, 1024, 1024),       # smaller than one block
    ])
    def test_matches_ref(self, nnz, nseg, block_nnz, block_seg):
        ids = jnp.sort(jax.random.randint(key(1), (nnz,), 0, nseg))
        vals = jax.random.normal(key(2), (nnz,))
        out = segsum(ids, vals, nseg, block_nnz=block_nnz,
                     block_seg=block_seg)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.segsum_ref(ids, vals,
                                                             nseg)),
                                   rtol=1e-5, atol=1e-4)

    def test_unsorted_ids_ok(self):
        ids = jax.random.randint(key(3), (512,), 0, 40)
        vals = jnp.ones((512,))
        out = segsum(ids, vals, 40, block_nnz=128, block_seg=16)
        np.testing.assert_allclose(np.asarray(out).sum(), 512.0)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ids = jnp.sort(jax.random.randint(key(4), (256,), 0, 31))
        vals = jax.random.normal(key(5), (256,)).astype(dtype)
        out = segsum(ids, vals, 31, block_nnz=64, block_seg=32)
        exp = ref.segsum_ref(ids, vals, 31)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-2, atol=2e-2)


class TestSpmvEll:
    @pytest.mark.parametrize("R,C,K,br,bc", [
        (64, 256, 4, 32, 64),
        (100, 500, 6, 32, 128),
        (13, 40, 2, 8, 16),
    ])
    def test_plus_times(self, R, C, K, br, bc):
        rng = np.random.default_rng(R)
        ecols = jnp.asarray(rng.integers(-1, C, (R, K)), jnp.int32)
        evals = jnp.asarray(rng.normal(0, 1, (R, K)).astype(np.float32))
        evals = jnp.where(ecols >= 0, evals, 0.0)
        x = jnp.asarray(rng.normal(0, 1, C).astype(np.float32))
        out = spmv_ell(ecols, evals, x, block_rows=br, block_cols=bc)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.spmv_ell_ref(ecols, evals, x)),
            rtol=1e-4, atol=1e-4)

    def test_csr_to_ell_pack(self):
        row_ptr = np.asarray([0, 2, 2, 5])
        cols = np.asarray([1, 3, 0, 2, 4])
        vals = np.asarray([1., 2., 3., 4., 5.])
        ecols, evals = csr_to_ell(row_ptr, cols, vals, 3, k_max=3)
        assert ecols.shape == (3, 3)
        np.testing.assert_allclose(np.asarray(evals[1]), 0.0)

    def test_csr_to_ell_matches_row_loop(self):
        """The vectorized pack must equal the per-row reference,
        including explicit k_max truncation and empty rows."""
        rng = np.random.default_rng(7)
        n_rows, n_cols, k_max = 50, 80, 4
        counts = rng.integers(0, 9, n_rows)     # some rows exceed k_max
        row_ptr = np.concatenate([[0], np.cumsum(counts)])
        nnz = int(row_ptr[-1])
        cols = rng.integers(0, n_cols, nnz)
        vals = rng.normal(0, 1, nnz)
        ecols, evals = csr_to_ell(row_ptr, cols, vals, n_rows, k_max,
                                  on_overflow="truncate")
        ref_c = np.full((n_rows, k_max), -1, np.int32)
        ref_v = np.zeros((n_rows, k_max), np.float32)
        for r in range(n_rows):
            lo = row_ptr[r]
            hi = min(row_ptr[r + 1], lo + k_max)
            ref_c[r, :hi - lo] = cols[lo:hi]
            ref_v[r, :hi - lo] = vals[lo:hi]
        np.testing.assert_array_equal(np.asarray(ecols), ref_c)
        np.testing.assert_allclose(np.asarray(evals), ref_v, rtol=1e-6)

    def test_csr_to_ell_overflow_raises(self):
        """Silent nnz loss is a wrong query answer: a row with more
        than k_max entries must raise by default, not truncate."""
        row_ptr = np.asarray([0, 5, 6])         # row 0 has 5 nnz
        cols = np.asarray([0, 1, 2, 3, 4, 0])
        vals = np.ones(6)
        with pytest.raises(EllOverflowError) as ei:
            csr_to_ell(row_ptr, cols, vals, 2, k_max=3)
        assert ei.value.n_over == 1
        assert ei.value.worst == 5
        assert ei.value.k_max == 3
        assert "on_overflow='truncate'" in str(ei.value)
        # fits → no raise; explicit truncate opt-in → lossy pack
        csr_to_ell(row_ptr, cols, vals, 2, k_max=5)
        ecols, _ = csr_to_ell(row_ptr, cols, vals, 2, k_max=3,
                              on_overflow="truncate")
        assert int((np.asarray(ecols) >= 0).sum()) == 4
        with pytest.raises(ValueError, match="on_overflow"):
            csr_to_ell(row_ptr, cols, vals, 2, k_max=3, on_overflow="warn")

    @pytest.mark.parametrize("br,bc", [(32, 64), (8, 16)])
    def test_max_times_signed(self, br, bc):
        """max_times over signed values: a zero-initialized accumulator
        would clamp all-negative rows to 0 — the semiring identity is
        -inf (empty rows resolve to the sparse no-entry value 0)."""
        rng = np.random.default_rng(11)
        R, C, K = 40, 96, 3
        ecols = np.asarray(rng.integers(0, C, (R, K)), np.int32)
        ecols[rng.random((R, K)) < 0.3] = -1     # padding slots
        ecols[5] = -1                            # an entirely empty row
        evals = rng.normal(0, 1, (R, K)).astype(np.float32)
        evals[3] = -np.abs(evals[3]) - 0.5       # an all-negative row
        evals[ecols == -1] = 0.0
        x = jnp.asarray(np.abs(rng.normal(0, 1, C)).astype(np.float32) + 0.1)
        ecols_j, evals_j = jnp.asarray(ecols), jnp.asarray(evals)
        out = np.asarray(spmv_ell(ecols_j, evals_j, x, block_rows=br,
                                  block_cols=bc, ring="max_times"))
        expect = np.zeros(R, np.float32)
        xs = np.asarray(x)
        for r in range(R):
            prods = [evals[r, k] * xs[ecols[r, k]]
                     for k in range(K) if ecols[r, k] >= 0]
            expect[r] = max(prods) if prods else 0.0
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)
        assert expect[3] < 0 and out[3] < 0      # negatives not clamped
        assert out[5] == 0.0                     # empty row → 0
        # the jnp oracle agrees with the kernel
        np.testing.assert_allclose(
            np.asarray(ref.spmv_ell_ref(ecols_j, evals_j, x,
                                        ring="max_times")),
            expect, rtol=1e-4, atol=1e-4)


def _rand_ell(rng, R, C, K, empty_rows=()):
    """A random hypersparse ELL block with padding slots and optionally
    some entirely empty rows."""
    ecols = np.asarray(rng.integers(-1, C, (R, K)), np.int32)
    evals = rng.normal(0, 1, (R, K)).astype(np.float32)
    for r in empty_rows:
        ecols[r] = -1
    evals[ecols == -1] = 0.0
    return jnp.asarray(ecols), jnp.asarray(evals)


class TestSpmmEll:
    @pytest.mark.parametrize("R,C,K,B,br,bc", [
        (64, 256, 4, 8, 32, 64),
        (100, 500, 6, 16, 32, 128),
        (13, 40, 2, 3, 8, 16),          # ragged, tiny batch
    ])
    @pytest.mark.parametrize("ring", ["plus_times", "max_times"])
    def test_matches_ref(self, R, C, K, B, br, bc, ring):
        rng = np.random.default_rng(R + B)
        ecols, evals = _rand_ell(rng, R, C, K, empty_rows=(0, R // 2))
        x = jnp.asarray(rng.normal(0, 1, (C, B)).astype(np.float32))
        out = spmm_ell(ecols, evals, x, block_rows=br, block_cols=bc,
                       ring=ring)
        exp = ref.spmm_ell_ref(ecols, evals, x, ring=ring)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)
        # empty rows resolve to the sparse no-entry value, both rings
        np.testing.assert_allclose(np.asarray(out[0]), 0.0)

    def test_b1_degenerates_to_spmv(self):
        """A batch of one is exactly the SpMV loop's unit."""
        rng = np.random.default_rng(5)
        R, C, K = 48, 120, 3
        ecols, evals = _rand_ell(rng, R, C, K)
        x = jnp.asarray(rng.normal(0, 1, C).astype(np.float32))
        for ring in ("plus_times", "max_times"):
            ym = spmm_ell(ecols, evals, x[:, None], block_rows=16,
                          block_cols=32, ring=ring)
            yv = spmv_ell(ecols, evals, x, block_rows=16, block_cols=32,
                          ring=ring)
            np.testing.assert_allclose(np.asarray(ym[:, 0]),
                                       np.asarray(yv),
                                       rtol=1e-5, atol=1e-5)

    def test_max_times_signed_not_clamped(self):
        """All-negative products must survive: the accumulator identity
        is -inf, and cross-tile maxes must not see a 0 floor."""
        rng = np.random.default_rng(3)
        R, C, K, B = 24, 96, 3, 4
        ecols, evals = _rand_ell(rng, R, C, K)
        evals = jnp.where(ecols >= 0, -jnp.abs(evals) - 0.5, 0.0)
        x = jnp.asarray(np.abs(rng.normal(0, 1, (C, B))).astype(
            np.float32) + 0.1)
        out = np.asarray(spmm_ell(ecols, evals, x, block_rows=8,
                                  block_cols=16, ring="max_times"))
        exp = np.asarray(ref.spmm_ell_ref(ecols, evals, x,
                                          ring="max_times"))
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)
        nonempty = np.asarray((ecols >= 0).any(axis=1))
        assert (out[nonempty] < 0).all()

    def test_rejects_1d_x(self):
        ecols = jnp.zeros((4, 2), jnp.int32)
        evals = jnp.zeros((4, 2), jnp.float32)
        with pytest.raises(ValueError, match="n_cols, b"):
            spmm_ell(ecols, evals, jnp.zeros(8), block_rows=4,
                     block_cols=8)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 60), st.integers(1, 120), st.integers(1, 5),
           st.integers(1, 9), st.integers(0, 2 ** 31 - 1),
           st.sampled_from(["plus_times", "max_times"]))
    def test_property_random_hypersparse(self, R, C, K, B, seed, ring):
        """Kernel == oracle over arbitrary hypersparse blocks: any
        shape, any padding pattern, ragged vs block sizes, both rings."""
        rng = np.random.default_rng(seed)
        ecols, evals = _rand_ell(
            rng, R, C, K,
            empty_rows=tuple(rng.integers(0, R, max(R // 7, 1))))
        x = jnp.asarray(rng.normal(0, 1, (C, B)).astype(np.float32))
        out = spmm_ell(ecols, evals, x, block_rows=16, block_cols=32,
                       ring=ring)
        exp = ref.spmm_ell_ref(ecols, evals, x, ring=ring)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-4)


class TestSpgemmSel:
    @pytest.mark.parametrize("R,C,K,B,br", [
        (64, 256, 4, 8, 32),
        (100, 64, 6, 5, 16),
        (13, 40, 2, 3, 8),
    ])
    @pytest.mark.parametrize("ring", ["plus_times", "max_times"])
    def test_matches_ref(self, R, C, K, B, br, ring):
        rng = np.random.default_rng(R * B)
        ecols, evals = _rand_ell(rng, R, C, K, empty_rows=(0,))
        sel = jnp.asarray(rng.choice(C, B, replace=False), jnp.int32)
        out = spgemm_sel(ecols, evals, sel, block_rows=br, ring=ring)
        exp = ref.spgemm_sel_ref(ecols, evals, sel, ring=ring)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-5)

    def test_equals_spmm_with_onehot(self):
        """The masked SpGEMM is SpMM against the one-hot selection
        matrix — without ever materializing it.  Exact under
        plus_times; under max_times only for non-negative payloads
        (dense one-hot zeros enter the max, the sparse mask does not —
        the mask is the GraphBLAS-correct reduction over stored hits)."""
        rng = np.random.default_rng(17)
        R, C, K, B = 40, 80, 3, 6
        ecols, evals = _rand_ell(rng, R, C, K)
        sel_np = rng.choice(C, B, replace=False)
        sel = jnp.asarray(sel_np, jnp.int32)
        onehot = np.zeros((C, B), np.float32)
        onehot[sel_np, np.arange(B)] = 1.0
        ys = spgemm_sel(ecols, evals, sel, block_rows=8)
        ym = spmm_ell(ecols, evals, jnp.asarray(onehot),
                      block_rows=8, block_cols=16)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ym),
                                   rtol=1e-5, atol=1e-5)
        evals_pos = jnp.where(ecols >= 0, jnp.abs(evals), 0.0)
        ys = spgemm_sel(ecols, evals_pos, sel, block_rows=8,
                        ring="max_times")
        ym = spmm_ell(ecols, evals_pos, jnp.asarray(onehot),
                      block_rows=8, block_cols=16, ring="max_times")
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ym),
                                   rtol=1e-5, atol=1e-5)

    def test_max_times_negative_hits_survive(self):
        """A column whose only stored entries are negative must return
        the negative max — the sparse mask never lets a dense zero
        clamp it."""
        ecols = jnp.asarray([[0, 1, -1]], jnp.int32)
        evals = jnp.asarray([[-2.0, -3.0, 0.0]], jnp.float32)
        out = spgemm_sel(ecols, evals, jnp.asarray([0, 1, 5], jnp.int32),
                         block_rows=8, ring="max_times")
        np.testing.assert_allclose(np.asarray(out[0]), [-2.0, -3.0, 0.0])


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,KV,Dh,bq,bk", [
        (128, 4, 4, 32, 32, 32),
        (128, 4, 2, 32, 64, 32),     # GQA
        (256, 8, 1, 64, 64, 64),     # MQA
    ])
    @pytest.mark.parametrize("causal,window", [
        (True, 0), (True, 48), (False, 0)])
    def test_matches_naive(self, S, H, KV, Dh, bq, bk, causal, window):
        B = 2
        q = jax.random.normal(key(1), (B, S, H, Dh))
        k = jax.random.normal(key(2), (B, S, KV, Dh))
        v = jax.random.normal(key(3), (B, S, KV, Dh))
        out = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk)
        exp = ref.flash_attention_ref(q, k, v, causal, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        B, S, H, Dh = 1, 64, 2, 32
        q = jax.random.normal(key(1), (B, S, H, Dh), jnp.bfloat16)
        k = jax.random.normal(key(2), (B, S, H, Dh), jnp.bfloat16)
        v = jax.random.normal(key(3), (B, S, H, Dh), jnp.bfloat16)
        out = flash_attention(q, k, v, block_q=32, block_k=32)
        exp = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestRGLRU:
    @pytest.mark.parametrize("S,C,bt,bc", [
        (64, 128, 16, 64),
        (128, 256, 64, 128),
        (32, 64, 32, 64),
    ])
    def test_matches_scan(self, S, C, bt, bc):
        B = 2
        a = jax.nn.sigmoid(jax.random.normal(key(1), (B, S, C)))
        b = jax.random.normal(key(2), (B, S, C)) * 0.1
        out = rglru_scan(a, b, block_t=bt, block_c=bc)
        exp = ref.rglru_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-4, atol=1e-5)


class TestWKV6:
    @pytest.mark.parametrize("S,H,Dh,chunk", [
        (64, 2, 16, 16),
        (128, 4, 32, 32),
        (96, 1, 8, 32),
    ])
    def test_matches_scan(self, S, H, Dh, chunk):
        B = 2
        r = jax.random.normal(key(1), (B, S, H, Dh))
        k = jax.random.normal(key(2), (B, S, H, Dh))
        v = jax.random.normal(key(3), (B, S, H, Dh))
        w = jax.nn.sigmoid(jax.random.normal(key(4), (B, S, H, Dh))) \
            * 0.5 + 0.45
        u = jax.random.normal(key(5), (H, Dh)) * 0.1
        out = wkv6(r, k, v, w, u, chunk=chunk)
        exp = ref.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-3, atol=1e-3)


class TestModelIntegration:
    """Kernels wired into the model forward paths (inference side)."""

    def test_pallas_attention_in_model(self):
        import dataclasses
        from repro.configs import smoke_config
        from repro.models import init_params, prefill
        from repro.models.config import ShapeConfig
        cfg0 = smoke_config("phi3-mini-3.8b")
        cfgP = dataclasses.replace(cfg0, attention_impl="pallas",
                                   attention_chunk=16)
        params = init_params(cfg0, jax.random.key(0))
        shape = ShapeConfig("p", 32, 2, "prefill")
        from repro.models import inputs as I
        batch = I.make_batch(cfg0, shape)
        l0, _ = prefill(params, batch, cfg0, s_max=36)
        lP, _ = prefill(params, batch, cfgP, s_max=36)
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(lP, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_pallas_rglru_in_model(self):
        import dataclasses
        from repro.configs import smoke_config
        from repro.models import init_params, prefill
        from repro.models.config import ShapeConfig
        from repro.models import inputs as I
        cfg0 = smoke_config("recurrentgemma-9b")
        cfgP = dataclasses.replace(cfg0, rglru_impl="pallas")
        params = init_params(cfg0, jax.random.key(0))
        shape = ShapeConfig("p", 32, 2, "prefill")
        batch = I.make_batch(cfg0, shape)
        l0, c0 = prefill(params, batch, cfg0, s_max=36)
        lP, cP = prefill(params, batch, cfgP, s_max=36)
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(lP, np.float32),
                                   rtol=2e-2, atol=2e-2)
        # recurrent states carried to decode must match too
        h0 = jax.tree.leaves(c0)[0]
        hP = jax.tree.leaves(cP)[0]
        assert h0.shape == hP.shape


class TestSegsumWindowed:
    """§Perf kernel iteration: O(nnz·2·Bseg) windowed segsum."""

    @pytest.mark.parametrize("nnz,nseg,bn,bs", [
        (5000, 300, 512, 512),
        (20000, 5000, 1024, 1024),
        (500, 64, 256, 256),
        (777, 100, 128, 256),        # ragged nnz
    ])
    def test_matches_ref(self, nnz, nseg, bn, bs):
        from repro.kernels.segsum import segsum_windowed
        ids = jnp.sort(jax.random.randint(key(nnz), (nnz,), 0, nseg))
        vals = jax.random.normal(key(nnz + 1), (nnz,))
        out = segsum_windowed(ids, vals, nseg, block_nnz=bn, block_seg=bs)
        exp = ref.segsum_ref(ids, vals, nseg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-5, atol=1e-4)

    def test_sparse_coverage_spill_exact(self):
        """Blocks spanning ≫ 2 tiles exercise the spill correction."""
        from repro.kernels.segsum import segsum_windowed
        ids = jnp.sort(jax.random.randint(key(9), (2048,), 0, 1_000_000))
        vals = jnp.ones((2048,))
        out = segsum_windowed(ids, vals, 1_000_000,
                              block_nnz=256, block_seg=256)
        exp = ref.segsum_ref(ids, vals, 1_000_000)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-3)

    def test_pallas_wkv6_in_model(self):
        import dataclasses
        from repro.configs import smoke_config
        from repro.models import init_params, prefill
        from repro.models.config import ShapeConfig
        from repro.models import inputs as I
        cfg0 = smoke_config("rwkv6-1.6b")
        cfgP = dataclasses.replace(cfg0, rwkv_impl="pallas")
        params = init_params(cfg0, jax.random.key(0))
        shape = ShapeConfig("p", 32, 2, "prefill")
        batch = I.make_batch(cfg0, shape)
        l0, _ = prefill(params, batch, cfg0, s_max=36)
        lP, _ = prefill(params, batch, cfgP, s_max=36)
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(lP, np.float32),
                                   rtol=2e-2, atol=2e-2)
