"""Graceful hypothesis guard (see requirements.txt — hypothesis is a
test dependency, but the suite must degrade, not error, without it).

``from _hyp import given, settings, st`` behaves exactly like the real
hypothesis imports when the package is installed.  When it is missing,
``@given(...)`` marks the test skipped (the importorskip idiom, applied
per-test so the modules' plain unit tests keep running) and ``st.*`` /
``settings`` become inert placeholders so decorators still evaluate at
collection time.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements.txt)")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        """Placeholder for hypothesis.strategies: any strategy factory
        returns None — never drawn from, since @given skips the test."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()
