"""Networked shard backend: scan-protocol agreement with the memory
backend, chunked streaming, registry dispatch, binding consistency,
kill-one-shard failover through the WriterPool retry path, the
cross-shard sync barrier as durability commit point, and a standalone
CLI shard server driven over a real subprocess boundary."""
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.assoc import Assoc
from repro.db import (DB, AsyncWriterError, EdgeStore, LSMStore,
                      MultiInstanceDB, NetMultiInstanceDB, ShardClient,
                      ShardError, ShardServer, WriterPool, put)

from test_lsmstore import degrees, rand_triples, snapshot


@pytest.fixture
def net3():
    """3 memory-backed local shards; always torn down."""
    db = NetMultiInstanceDB(n_instances=3, tablets_per_instance=3)
    yield db
    db.close()


class TestScanAgreement:
    """The net backend is observationally identical to the in-process
    memory backend over identical triples (mirrors the LSM cross-check:
    shard placement may differ, merged scans may not)."""

    @pytest.mark.parametrize("seed", range(5))
    def test_scans_agree_with_memory_backend(self, net3, seed):
        mem = MultiInstanceDB(n_instances=3, tablets_per_instance=3)
        r, c, v = rand_triples(seed, n=250)
        for lo in range(0, 250, 50):        # batched, interleaved
            net3.put_triples(r[lo:lo + 50], c[lo:lo + 50], v[lo:lo + 50])
            mem.put_triples(r[lo:lo + 50], c[lo:lo + 50], v[lo:lo + 50])
        for t in (False, True):
            assert snapshot(net3, t) == snapshot(mem, t)
            lo_k, hi_k = ("p005", "p025") if not t \
                else ("ip.dst|", "ip.src|5")
            assert list(net3.scan_key_range(lo_k, hi_k, transpose=t)) == \
                list(mem.scan_key_range(lo_k, hi_k, transpose=t))
            assert list(net3.scan_prefix("p01" if not t else "ip.dst|",
                                         transpose=t)) == \
                list(mem.scan_prefix("p01" if not t else "ip.dst|",
                                     transpose=t))
            assert list(net3.scan_keys([r[0], r[7], "absent"],
                                       transpose=t)) == \
                list(mem.scan_keys([r[0], r[7], "absent"], transpose=t))
        assert degrees(net3) == degrees(mem)
        assert sorted(net3.keys_with_prefix("ip.dst|")) == \
            sorted(mem.keys_with_prefix("ip.dst|"))
        for key in set(c[:20]):
            assert net3.degree(key) == mem.degree(key)
        assert net3.connections("3") == mem.connections("3")
        assert net3.n_entries == mem.n_entries == len(r)

    def test_put_degree_matches_edgestore(self, tmp_path):
        e = EdgeStore(n_tablets=2)
        srv = ShardServer(EdgeStore(n_tablets=2)).start()
        client = ShardClient(srv.address)
        Edeg = Assoc("ip.dst|a,ip.dst|b,", "degree,degree,",
                     np.asarray([3.0, 4.0]))
        client.put_degree(Edeg)
        e.put_degree(Edeg)
        try:
            assert degrees(client) == degrees(e)
        finally:
            client.close()
            srv.stop()

    def test_chunked_streaming_covers_full_scan(self):
        """Results spanning many chunk frames arrive complete and in
        order (chunk_items far below the key count)."""
        db = NetMultiInstanceDB(n_instances=2, chunk_items=16)
        try:
            r, c, v = rand_triples(9, n=400, n_rows=300, n_cols=40)
            db.put_triples(r, c, v)
            keys = [k for k, _ in db.scan_everything()]
            assert keys == sorted(keys)
            assert set(keys) == set(r.tolist())
        finally:
            db.close()

    def test_abandoned_scan_does_not_poison_pool(self, net3):
        """A generator dropped mid-stream discards its connection; the
        next RPC on the shard still works."""
        r, c, v = rand_triples(3, n=300, n_rows=280)
        net3.put_triples(r, c, v)
        it = net3.instances[0].scan_everything()
        next(it)
        it.close()                          # abandon mid-stream
        assert net3.instances[0].ping()
        assert snapshot(net3)               # full scans still complete


class TestRegistry:
    def test_net_dispatch_local(self):
        T = DB("Tedge", backend="net", n_instances=2)
        try:
            assert isinstance(T.backend, NetMultiInstanceDB)
            assert len(T.backend.instances) == 2
            assert len(T.backend.servers) == 2      # auto-started, owned
        finally:
            T.backend.close()

    def test_net_dispatch_addresses(self):
        srv = ShardServer(EdgeStore(n_tablets=2)).start()
        T = DB("Tedge", backend="net", addresses=[srv.address])
        try:
            assert T.backend.servers == []          # not owned
            assert T.backend.instances[0].ping()
        finally:
            T.backend.close()
            srv.stop()

    def test_remote_addresses_reject_engine_opts(self):
        with pytest.raises(ValueError, match="engine options"):
            NetMultiInstanceDB(addresses=["127.0.0.1:1"],
                               memtable_limit=5)

    def test_unknown_op_is_shard_error(self):
        srv = ShardServer(EdgeStore(n_tablets=1)).start()
        client = ShardClient(srv.address)
        try:
            with pytest.raises(ShardError, match="unknown op"):
                client._rpc("nope")
        finally:
            client.close()
            srv.stop()

    def test_stable_routing_hash(self):
        """Shard placement must agree across producer processes."""
        import zlib
        assert NetMultiInstanceDB.key_hash("p1") == zlib.crc32(b"p1")


class TestBindingOnNet:
    def test_query_after_put_consistency(self, tmp_path):
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="net",
               path=str(tmp_path / "a"), n_instances=2)
        try:
            E = Assoc("p1,p1,p2,p3,",
                      "ip.dst|a,ip.src|b,ip.dst|a,ip.dst|c,", "1,1,1,1,")
            put(T, E, sync=False)
            # query-after-put: the binding read flushes (and syncs) first
            assert T[:, "ip.dst|*,"].eval().nnz == 3
            assert T.degree("ip.dst|a") == 2.0
            assert T["p1,", :].eval().nnz == 2
            assert T["p1,:,p2,", :].eval().nnz == 3
            r, _, v = T.degree_assoc("ip.dst|").triples()
            assert dict(zip(r, np.asarray(v, float)))["ip.dst|c"] == 1.0
            T.close()
        finally:
            T.backend.close()

    def test_scan_cache_invalidation_on_net(self):
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="net",
               n_instances=2)
        try:
            put(T, Assoc("p1,", "ip.dst|a,", "1,"))
            assert T[:, "ip.dst|*,"].eval().nnz == 1
            # direct client put (bypasses the binding) still invalidates
            T.backend.route("x").put(Assoc("p2,", "ip.dst|a,", "1,"))
            assert T[:, "ip.dst|*,"].eval().nnz == 2
            T.close()
        finally:
            T.backend.close()


class TestFailover:
    def test_dead_shard_raises_async_writer_error(self):
        """Kill one shard; blocks routed to it exhaust the WriterPool's
        bounded-backoff retries and surface AsyncWriterError at the
        barrier — with the shard's address in the message."""
        db = NetMultiInstanceDB(n_instances=2)
        pool = WriterPool(db, max_retries=1, retry_backoff_s=0.01)
        try:
            r, c, v = rand_triples(0, n=40)
            pool.submit(r, c, v)
            pool.flush()                    # healthy cluster: all applied
            n0 = pool.n_written
            assert n0 == 40
            dead = db.servers[0]
            dead.stop()
            pool.submit(r, c, v)            # some rows route to shard 0
            with pytest.raises(AsyncWriterError, match=dead.address):
                pool.flush()
        finally:
            db.close()

    def test_restarted_shard_picks_up_retried_block(self, tmp_path):
        """The retry path re-dials per attempt, so a shard that comes
        back before retries exhaust receives the block — no data loss,
        n_retried records the recovery."""
        store = LSMStore(str(tmp_path / "s0"))
        srv = ShardServer(store).start()
        port = srv.port
        db = NetMultiInstanceDB(addresses=[srv.address])
        pool = WriterPool(db, max_retries=8, retry_backoff_s=0.05)
        try:
            srv.stop()                      # shard down before any RPC
            r, c, v = rand_triples(1, n=30)
            pool.submit(r, c, v)

            def revive():
                time.sleep(0.2)
                ShardServer(store, port=port).start()
            t = threading.Thread(target=revive)
            t.start()
            pool.flush()                    # retries until the revival
            t.join()
            assert pool.n_written == 30
            assert pool.n_retried >= 1
            assert db.n_entries == 30
        finally:
            pool.close()
            db.close()

    def test_dead_shard_scan_raises_connection_error(self, net3):
        net3.put_triples(*rand_triples(2, n=30))
        net3.servers[1].stop()
        with pytest.raises(ConnectionError, match="db1"):
            snapshot(net3)


class TestSyncBarrier:
    def test_flush_is_cross_shard_durability_point(self, tmp_path):
        """flush() fans the sync barrier to every shard (WAL fsync);
        abandoning the cluster afterwards loses nothing — reopening the
        shard directories recovers every entry and degree sum."""
        d = str(tmp_path / "m")
        T = DB("Tedge", "TedgeT", "TedgeDeg", backend="net", path=d,
               n_instances=2, cache_ttl=0)
        r, c, v = rand_triples(4, n=120)
        n_put = put(T, Assoc(r, c, v), sync=False)  # Assoc dedups cells
        T.flush()
        before = snapshot(T.backend)
        deg = degrees(T.backend)
        for srv in T.backend.servers:       # crash: no close(), no sync
            assert srv.store.n_syncs >= 1   # the barrier already fsync'd
            srv.stop()
        for inst in T.backend.instances:
            inst.close()

        R = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=d,
               n_instances=2, cache_ttl=0)
        assert snapshot(R.backend) == before
        assert degrees(R.backend) == deg
        assert R.n_entries == n_put

    def test_clean_barrier_skips_rpcs(self, net3):
        """A sync with no outstanding client writes is a pure local
        check — no RPC per shard, so read-path flushes stay cheap."""
        net3.put_triples(*rand_triples(5, n=20))
        net3.sync()
        n0 = sum(i.n_rpcs for i in net3.instances)
        for _ in range(10):
            net3.sync()
        assert sum(i.n_rpcs for i in net3.instances) == n0
        net3.put_triples(*rand_triples(5, n=5))
        net3.sync()
        assert sum(i.n_rpcs for i in net3.instances) > n0


class TestWriterRouting:
    def test_pool_fallback_hash_is_process_stable(self):
        """A backend with instances but no key_hash hook must get the
        crc32 fallback — pin= routing has to agree across producers
        (abs(hash(k)) is salted per process)."""
        import zlib

        class Bare:
            def __init__(self):
                self.instances = [EdgeStore(n_tablets=1, name=f"db{i}")
                                  for i in range(4)]
        b = Bare()
        pool = WriterPool(b)
        try:
            assert pool._key_hash("file-007") == zlib.crc32(b"file-007")
            pool.submit(np.asarray(["p1"]), np.asarray(["c|a"]),
                        np.asarray(["1"]), pin="file-007")
            pool.flush()
            want = zlib.crc32(b"file-007") % 4
            assert [i for i, inst in enumerate(b.instances)
                    if inst.n_entries] == [want]
        finally:
            pool.close()


class TestStandaloneServer:
    @pytest.mark.slow
    def test_cli_shard_server_over_subprocess(self, tmp_path):
        """The real deployment shape: a shard server in its own process
        (LSM-backed), a client binding in this one, SIGTERM shutdown."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.db.netstore", "--port", "0",
             "--path", str(tmp_path / "shard0")],
            env={**os.environ, "PYTHONPATH": src},
            stdout=subprocess.PIPE, text=True)
        try:
            line = proc.stdout.readline()
            assert line.startswith("LISTENING "), line
            addr = line.split()[1]
            T = DB("Tedge", "TedgeT", "TedgeDeg", backend="net",
                   addresses=[addr], cache_ttl=0)
            put(T, Assoc("p1,p2,", "ip.dst|a,ip.dst|b,", "1,1,"),
                sync=False)
            T.flush()                       # commits on the server's WAL
            assert T[:, "ip.dst|*,"].eval().nnz == 2
            assert T.degree("ip.dst|a") == 1.0
            T.close()
            T.backend.close()
        finally:
            proc.send_signal(signal.SIGTERM)
            assert proc.wait(timeout=30) == 0
        # the server-side store is durable past the server's lifetime
        s = LSMStore(str(tmp_path / "shard0"))
        assert s.n_entries == 2
        s.close()
