"""Pipeline: stage semantics, fault tolerance, end-to-end + analytics."""
import glob
import json
import os
import time

import numpy as np
import pytest

from repro import analytics
from repro.core.assoc import Assoc
from repro.db import EdgeStore, MultiInstanceDB
from repro.pipeline import (FaultInjector, PipelineConfig, Runner, Task,
                            TrafficConfig, botnet_truth, run_pipeline)
from repro.pipeline import pcap as P
from repro.pipeline import stages


class TestPcapCodec:
    def test_write_read_roundtrip(self, tmp_path):
        cfg = TrafficConfig(n_hosts=64, pkt_rate=5000.0, seed=1)
        rec = P.synth_packets(cfg, 0.05)
        path = str(tmp_path / "x.pcap")
        P.write_pcap(path, rec)
        back = P.read_pcap(path)
        assert back.shape == rec.shape
        np.testing.assert_array_equal(back["src"], rec["src"])

    def test_gzip_roundtrip(self, tmp_path):
        cfg = TrafficConfig(n_hosts=64, pkt_rate=5000.0, seed=1)
        rec = P.synth_packets(cfg, 0.02)
        path = str(tmp_path / "x.pcap.gz")
        P.write_pcap(path, rec, compress=True)
        assert P.read_pcap(path).shape == rec.shape

    def test_timestamps_sorted(self):
        rec = P.synth_packets(TrafficConfig(seed=2, pkt_rate=2000.0,
                                            n_hosts=32), 0.1)
        ts = rec["ts_sec"].astype(np.float64) + rec["ts_usec"] * 1e-6
        assert (np.diff(ts) >= 0).all()

    def test_tsv_fields(self):
        rec = P.synth_packets(TrafficConfig(seed=3, pkt_rate=1000.0,
                                            n_hosts=32), 0.05)
        tsv = P.records_to_tsv(rec)
        header = tsv.split("\n")[0].split("\t")
        assert header[0] == "id"
        assert set(P.TSV_FIELDS) <= set(header)

    def test_botnet_truth_deterministic(self):
        cfg = TrafficConfig(seed=11)
        assert botnet_truth(cfg) == botnet_truth(cfg)


class TestStages:
    def test_split_preserves_records(self, tmp_path):
        cfg = TrafficConfig(n_hosts=64, pkt_rate=20000.0, seed=1)
        rec = P.synth_packets(cfg, 0.05)
        src = str(tmp_path / "f.pcap")
        P.write_pcap(src, rec)
        res = stages.split(src, split_size=16 * 1024)
        assert len(res.outputs) > 1
        total = sum(P.read_pcap(p).shape[0] for p in res.outputs)
        assert total == rec.shape[0]

    def test_expansion_accounting(self, tmp_path):
        """Uncompress expands (paper: 2 GB → 6 GB per file)."""
        cfg = TrafficConfig(n_hosts=64, pkt_rate=20000.0, seed=1)
        raw = str(tmp_path / "f.pcap.gz")
        gen = stages.generate(raw, cfg, 0.05)
        unc = stages.uncompress(raw)
        assert unc.bytes_out > unc.bytes_in  # decompression expands


class TestRunner:
    def _tasks(self, results, n=8):
        def make(i):
            def fn():
                results.append(i)
                return i
            return fn
        return [Task(f"t{i}", make(i), stage="s") for i in range(n)]

    def test_runs_all(self):
        out = []
        recs = Runner(n_workers=3).run(self._tasks(out))
        assert len(recs) == 8 and sorted(out) == list(range(8))

    def test_dependencies_respected(self):
        order = []
        t1 = Task("a", lambda: order.append("a"))
        t2 = Task("b", lambda: order.append("b"), deps=("a",))
        t3 = Task("c", lambda: order.append("c"), deps=("b",))
        Runner(n_workers=2).run([t3, t1, t2])
        assert order == ["a", "b", "c"]

    def test_fault_injection_retries(self):
        out = []
        fi = FaultInjector(kill_rate=0.5, seed=0, max_kills=5)
        recs = Runner(n_workers=2, fault_injector=fi,
                      max_retries=10).run(self._tasks(out))
        assert len(recs) == 8
        assert fi.kills > 0          # faults actually happened

    def test_permanent_failure_raises(self):
        def boom():
            raise RuntimeError("hard failure")
        with pytest.raises(RuntimeError, match="failed permanently"):
            Runner(n_workers=1, max_retries=1).run(
                [Task("x", boom, stage="s")])

    def test_journal_restart_skips_done(self, tmp_path):
        journal = str(tmp_path / "j.jsonl")
        counter = {"n": 0}

        def work():
            counter["n"] += 1
        tasks = [Task(f"t{i}", work, stage="s") for i in range(4)]
        Runner(n_workers=2, journal_path=journal).run(tasks)
        assert counter["n"] == 4
        # restart: journal says done → zero re-execution
        Runner(n_workers=2, journal_path=journal).run(tasks)
        assert counter["n"] == 4

    def test_straggler_speculation(self):
        """A hung task gets a backup copy; first finisher wins."""
        state = {"calls": 0}

        def sometimes_slow():
            with_lock = state["calls"]
            state["calls"] += 1
            if with_lock == 0:
                time.sleep(3.0)      # straggler on first execution
            return "done"
        fast = [Task(f"f{i}", lambda: time.sleep(0.01), stage="s")
                for i in range(6)]
        slow = Task("slow", sometimes_slow, stage="s")
        r = Runner(n_workers=3, straggler_factor=2.0, straggler_min_s=0.3)
        t0 = time.time()
        recs = r.run(fast + [slow])
        assert "slow" in recs
        assert time.time() - t0 < 2.9   # did not wait for the straggler
        assert state["calls"] >= 2      # speculation happened


class TestEndToEnd:
    def test_pipeline_and_detection(self, tmp_path):
        tcfg = TrafficConfig(n_hosts=128, pkt_rate=100.0, n_bots=10,
                             beacon_period_s=4.0, beacon_jitter_s=0.1,
                             seed=5)
        cfg = PipelineConfig(workdir=str(tmp_path), n_files=1,
                             duration_per_file_s=40.0,
                             split_size=96 * 1024, traffic=tcfg,
                             n_workers=2)
        db = EdgeStore(n_tablets=4)
        stats = run_pipeline(cfg, db)
        assert stats["db_entries"] > 0
        for s in ("uncompress", "split", "parse", "sort", "sparse",
                  "ingest"):
            assert s in stats["stages"], s

        # analytics find the injected C2
        E = Assoc()
        for p in sorted(glob.glob(os.path.join(str(tmp_path), "*.E.npz"))):
            E = E + Assoc.load(p)
        truth = botnet_truth(tcfg)
        rep = analytics.detect_c2(E, top_k=3)
        assert truth["c2"] in list(rep.hosts), \
            f"C2 {truth['c2']} not in {rep.hosts}"

        # the database answers Fig. 2's query
        conns = db.connections(truth["c2"])
        assert len(conns) >= 10
        deg = db.degree(f"ip.dst|{truth['c2']}")
        assert deg > 0

    def test_pipeline_restart_resumes(self, tmp_path):
        tcfg = TrafficConfig(n_hosts=64, pkt_rate=500.0, seed=6)
        cfg = PipelineConfig(workdir=str(tmp_path), n_files=2,
                             duration_per_file_s=1.0, traffic=tcfg,
                             n_workers=2)
        db = EdgeStore(n_tablets=2)
        run_pipeline(cfg, db)
        n1 = db.n_entries
        # rerun with same journal: all tasks skipped, no double ingest
        db2 = EdgeStore(n_tablets=2)
        run_pipeline(cfg, db2)
        assert db2.n_entries == 0


class TestMultiInstance:
    def test_routing_covers_instances(self):
        db = MultiInstanceDB(n_instances=4, tablets_per_instance=2)
        for i in range(32):
            E = Assoc(f"p{i},", "ip.src|1.2.3.4,", "1,")
            db.put(E, file_id=f"file{i}")
        used = sum(1 for inst in db.instances if inst.n_entries > 0)
        assert used >= 3
        assert db.degree("ip.src|1.2.3.4") == 32.0


from _hyp import given, settings, st  # hypothesis, skipping when absent


class TestRunnerProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 12), st.integers(1, 4), st.data())
    def test_random_dag_executes_each_task_once_in_order(self, n, workers,
                                                         data):
        """Property: any random DAG runs every task exactly once, and
        every task starts only after all its dependencies finished."""
        import threading
        deps = {}
        for i in range(n):
            pool = list(range(i))
            k = data.draw(st.integers(0, min(2, len(pool))))
            deps[i] = tuple(f"t{j}" for j in
                            (data.draw(st.permutations(pool))[:k] if pool
                             else []))
        lock = threading.Lock()
        finished = set()
        runs = []

        def make(i):
            def fn():
                with lock:
                    for d in deps[i]:
                        assert int(d[1:]) in finished, \
                            f"t{i} ran before {d}"
                    runs.append(i)
                    finished.add(i)
            return fn

        tasks = [Task(f"t{i}", make(i), deps=deps[i], stage="s")
                 for i in range(n)]
        recs = Runner(n_workers=workers, speculative=False).run(tasks)
        assert len(recs) == n
        assert sorted(runs) == list(range(n))


class TestElasticity:
    def test_set_workers_mid_run(self):
        """Worker pool grows while a run is in flight (elastic scale-up)."""
        import threading
        r = Runner(n_workers=1, speculative=False)
        started = threading.Event()

        def slowish(i):
            def fn():
                started.set()
                time.sleep(0.05)
            return fn
        tasks = [Task(f"t{i}", slowish(i), stage="s") for i in range(12)]

        def grow():
            started.wait(timeout=5)
            r.set_workers(4)
        g = threading.Thread(target=grow)
        g.start()
        t0 = time.time()
        recs = r.run(tasks)
        g.join()
        assert len(recs) == 12
        # 12 × 50ms on 1 worker ≈ 0.6s; elastic growth must beat that
        assert time.time() - t0 < 0.55
