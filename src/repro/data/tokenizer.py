"""Byte-level tokenizer (vocab-embedding friendly, no external deps).

Tokens 0..255 are raw bytes; ids ≥ 256 are reserved specials.  Any
assigned architecture's vocab (32k–256k) embeds the byte range, so one
tokenizer serves every config — production would swap in SentencePiece
behind the same interface.
"""
from __future__ import annotations

import numpy as np

PAD = 256
BOS = 257
EOS = 258
N_SPECIALS = 3


def encode(text: str, add_bos: bool = True, add_eos: bool = False
           ) -> np.ndarray:
    b = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
    parts = []
    if add_bos:
        parts.append(np.asarray([BOS], np.int32))
    parts.append(b)
    if add_eos:
        parts.append(np.asarray([EOS], np.int32))
    return np.concatenate(parts)


def decode(ids: np.ndarray) -> str:
    ids = np.asarray(ids)
    ids = ids[(ids >= 0) & (ids < 256)]
    return ids.astype(np.uint8).tobytes().decode("utf-8", errors="replace")
