"""Pipeline → token-stream bridge with resumable, sharded sampling.

This is the paper-integration point: the D4M pipeline's parsed TSV
packet logs (stage 3 outputs) become the LM training corpus — "train the
anomaly language model on the traffic" is the modern version of the
paper's analytics, and the same six-stage infrastructure feeds it.

Fault-tolerance contract: the sampler state (file cursor, intra-file
offset, RNG key, epoch) is a small dict checkpointed alongside the model
— restore gives exactly-once continuation of the stream.  Sharding:
worker ``i of n`` reads files where ``hash(file) % n == i``, so the
global batch is disjoint across data-parallel hosts.
"""
from __future__ import annotations

import dataclasses
import glob
import os
from typing import Iterator, Optional

import numpy as np

from . import tokenizer as T


@dataclasses.dataclass
class SamplerState:
    file_index: int = 0
    offset: int = 0          # token offset within current file buffer
    epoch: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SamplerState":
        return cls(**d)


class TokenStream:
    """Deterministic, resumable token batches from pipeline TSV files."""

    def __init__(self, pattern: str, seq_len: int, batch: int,
                 shard: int = 0, n_shards: int = 1,
                 state: Optional[SamplerState] = None):
        files = sorted(glob.glob(pattern))
        self.files = [f for i, f in enumerate(files)
                      if i % n_shards == shard]
        if not self.files:
            raise FileNotFoundError(f"no files match {pattern} "
                                    f"(shard {shard}/{n_shards})")
        self.seq_len = seq_len
        self.batch = batch
        self.state = state or SamplerState()
        self._buf: Optional[np.ndarray] = None
        self._buf_index = -1

    def _load(self, idx: int) -> np.ndarray:
        with open(self.files[idx % len(self.files)], "rb") as f:
            text = f.read().decode(errors="replace")
        return T.encode(text, add_bos=True, add_eos=True)

    def _ensure(self):
        if self._buf_index != self.state.file_index:
            self._buf = self._load(self.state.file_index)
            self._buf_index = self.state.file_index

    def next_batch(self) -> dict:
        """Returns {'tokens': (B, S) int32, 'labels': (B, S) int32}."""
        need = self.batch * (self.seq_len + 1)
        chunks = []
        while need > 0:
            self._ensure()
            avail = self._buf.shape[0] - self.state.offset
            take = min(avail, need)
            chunks.append(
                self._buf[self.state.offset:self.state.offset + take])
            self.state.offset += take
            need -= take
            if self.state.offset >= self._buf.shape[0]:
                self.state.offset = 0
                self.state.file_index += 1
                if self.state.file_index >= len(self.files):
                    self.state.file_index = 0
                    self.state.epoch += 1
        flat = np.concatenate(chunks)
        flat = flat.reshape(self.batch, self.seq_len + 1)
        return {"tokens": flat[:, :-1].astype(np.int32),
                "labels": flat[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
