from . import tokenizer
from .stream import SamplerState, TokenStream

__all__ = ["tokenizer", "TokenStream", "SamplerState"]
