"""Transformer / MoE / RG-LRU / RWKV-6 blocks with init + apply.

Every block follows the same contract::

    params = init_<block>(cfg, key)                  # pytree of arrays
    y, new_cache = apply_<block>(params, x, ctx, cfg)

``ctx`` carries positions, decode caches, and mode.  Parameters are
stored float32 (master copy) and cast to ``cfg.dtype`` at use — grads
and optimizer states stay f32 (MaxText convention).

Caches (decode):
* attention blocks — (B, S_max, KV, Dh) K and V rings + write index,
* RG-LRU — (B, Dr) hidden state + (B, conv_w-1, Dr) conv tail +
  a local-attention window cache,
* RWKV — (B, H, Dh, Dh) wkv state + (B, D) token-shift state.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from . import layers as L
from .config import ATTN, LOCAL_ATTN, MoEConfig, ModelConfig, RGLRU, RWKV
from .shard_ctx import constrain

Array = jax.Array


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale or fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


@dataclasses.dataclass
class Ctx:
    positions: Array                  # (B, S) absolute positions
    mode: str = "train"               # train | prefill | decode
    cache: Optional[dict] = None      # per-layer cache pytree (decode)
    enc_out: Optional[Array] = None   # encoder output (cross-attention)
    enc_pos: Optional[Array] = None


def _c(x, cfg):  # compute-dtype cast
    return x.astype(jnp.dtype(cfg.dtype))


# =============================================================================
# Attention block (A = global, L = sliding window)
# =============================================================================

def init_attn(cfg: ModelConfig, key, cross: bool = False) -> dict:
    D = cfg.d_model
    H, KV = cfg.phys_heads, cfg.phys_kv_heads
    Dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln": jnp.zeros((D,), jnp.float32),
        "wq": _dense_init(ks[0], (D, H * Dh)),
        "wk": _dense_init(ks[1], (D, KV * Dh)),
        "wv": _dense_init(ks[2], (D, KV * Dh)),
        "wo": _dense_init(ks[3], (H * Dh, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bk"] = jnp.zeros((KV * Dh,), jnp.float32)
        p["bv"] = jnp.zeros((KV * Dh,), jnp.float32)
    return p


def head_kv_map(cfg: ModelConfig):
    """Physical head → physical kv-head index, preserving the LOGICAL
    GQA grouping for real heads (padded heads map to kv 0, masked)."""
    import numpy as np
    groups = cfg.n_heads // cfg.n_kv_heads
    idx = np.zeros(cfg.phys_heads, np.int32)
    idx[:cfg.n_heads] = np.arange(cfg.n_heads) // groups
    return jnp.asarray(idx)


def head_mask(cfg: ModelConfig, dtype):
    """(H_phys,) 1 for real heads, 0 for padding (hard-masks outputs so
    padded parameters receive zero gradient — math is exactly logical)."""
    if cfg.phys_heads == cfg.n_heads:
        return None
    return (jnp.arange(cfg.phys_heads) < cfg.n_heads).astype(dtype)


def _qkv(p, x, cfg):
    B, S, D = x.shape
    H, KV = cfg.phys_heads, cfg.phys_kv_heads
    Dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, _c(p["wq"], cfg))
    k = jnp.einsum("bsd,dh->bsh", x, _c(p["wk"], cfg))
    v = jnp.einsum("bsd,dh->bsh", x, _c(p["wv"], cfg))
    if "bq" in p:
        q = q + _c(p["bq"], cfg)
        k = k + _c(p["bk"], cfg)
        v = v + _c(p["bv"], cfg)
    # pin head axes to the model axis — sharding propagation loses these
    # through the scan+remat boundary (151 GiB/device without; §Perf)
    q = constrain(q.reshape(B, S, H, Dh), "batch", None, "model", None)
    k = constrain(k.reshape(B, S, KV, Dh), "batch", None, "model", None)
    v = constrain(v.reshape(B, S, KV, Dh), "batch", None, "model", None)
    return q, k, v


class AttnCache(NamedTuple):
    k: Array          # (B, S_alloc, KV, Dh) — ring buffer for windowed attn
    v: Array
    pos: Array        # (B, S_alloc) int32 absolute positions; -1 = empty
    index: Array      # () int32 — next global write position


def init_attn_cache(cfg: ModelConfig, batch: int, s_max: int,
                    window: int = 0) -> AttnCache:
    """Sliding-window layers allocate only ``window`` slots (ring buffer)
    — this is what makes long_500k feasible for SWA/hybrid archs."""
    KV, Dh = cfg.phys_kv_heads, cfg.resolved_head_dim
    s_alloc = min(window, s_max) if window else s_max
    dt = jnp.dtype(cfg.dtype)
    return AttnCache(jnp.zeros((batch, s_alloc, KV, Dh), dt),
                     jnp.zeros((batch, s_alloc, KV, Dh), dt),
                     jnp.full((batch, s_alloc), -1, jnp.int32),
                     jnp.zeros((), jnp.int32))


def apply_attn(p: dict, x: Array, ctx: Ctx, cfg: ModelConfig,
               window: int = 0, rope_on: bool = True):
    """Self-attention sublayer (pre-norm). Returns (residual_out, cache)."""
    h = L.rms_norm(x, _c(p["ln"], cfg), cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    kv_map = head_kv_map(cfg) if cfg.phys_heads != cfg.n_heads else None
    if rope_on:
        q = L.rope(q, ctx.positions, cfg.rope_theta)
        k = L.rope(k, ctx.positions, cfg.rope_theta)
    new_cache = None
    if ctx.mode == "decode":
        cache: AttnCache = ctx.cache
        s_alloc = cache.k.shape[1]
        slot = cache.index % s_alloc                   # ring write
        kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache.pos, ctx.positions.astype(jnp.int32), slot, axis=1)
        new_cache = AttnCache(kc, vc, pos, cache.index + x.shape[1])
        # ring entries carry absolute positions; -1 slots stay masked
        out = L.attention(q, kc, vc, ctx.positions, pos, causal=True,
                          window=window, impl="naive", kv_map=kv_map)
    else:
        out = L.attention(q, k, v, ctx.positions, ctx.positions,
                          causal=True, window=window,
                          impl=cfg.attention_impl, chunk=cfg.attention_chunk,
                          kv_map=kv_map)
        if ctx.mode == "prefill" and ctx.cache is not None:
            cache: AttnCache = ctx.cache
            s_alloc = cache.k.shape[1]
            take = min(s_alloc, x.shape[1])
            # each absolute position p lands at ring slot p % s_alloc, so
            # decode continues the ring seamlessly after prefill
            tail_pos = ctx.positions[:, -take:].astype(jnp.int32)
            slots = tail_pos[0] % s_alloc
            kc = cache.k.at[:, slots].set(k[:, -take:])
            vc = cache.v.at[:, slots].set(v[:, -take:])
            pos = cache.pos.at[:, slots].set(tail_pos)
            new_cache = AttnCache(kc, vc, pos,
                                  jnp.asarray(x.shape[1], jnp.int32))
    B, S = x.shape[:2]
    hm = head_mask(cfg, out.dtype)
    if hm is not None:   # zero padded-head outputs → exact logical math
        out = out * hm[None, None, :, None]
    out = out.reshape(B, S, -1)
    proj = checkpoint_name(
        jnp.einsum("bsh,hd->bsd", out, _c(p["wo"], cfg)), "tp_out")
    return x + proj, new_cache


def apply_cross_attn(p: dict, x: Array, ctx: Ctx, cfg: ModelConfig):
    """Encoder–decoder cross-attention (whisper). No cache mutation:
    encoder K/V are recomputed from enc_out (could be cached; cheap)."""
    B, S, D = x.shape
    H, KV = cfg.phys_heads, cfg.phys_kv_heads
    Dh = cfg.resolved_head_dim
    h = L.rms_norm(x, _c(p["ln"], cfg), cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", h, _c(p["wq"], cfg)).reshape(B, S, H, Dh)
    enc = ctx.enc_out
    k = jnp.einsum("bsd,dh->bsh", enc, _c(p["wk"], cfg)) \
        .reshape(B, enc.shape[1], KV, Dh)
    v = jnp.einsum("bsd,dh->bsh", enc, _c(p["wv"], cfg)) \
        .reshape(B, enc.shape[1], KV, Dh)
    kv_map = head_kv_map(cfg) if cfg.phys_heads != cfg.n_heads else None
    out = L.attention(q, k, v, ctx.positions, ctx.enc_pos, causal=False,
                      impl="naive" if enc.shape[1] <= cfg.attention_chunk
                      else cfg.attention_impl, chunk=cfg.attention_chunk,
                      kv_map=kv_map)
    hm = head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm[None, None, :, None]
    return x + jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1),
                          _c(p["wo"], cfg))


# =============================================================================
# MLP / MoE
# =============================================================================

def init_mlp(cfg: ModelConfig, key) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((D,), jnp.float32),
        "w_gate": _dense_init(ks[0], (D, F)),
        "w_up": _dense_init(ks[1], (D, F)),
        "w_down": _dense_init(ks[2], (F, D)),
    }


def apply_mlp(p: dict, x: Array, cfg: ModelConfig) -> Array:
    h = L.rms_norm(x, _c(p["ln"], cfg), cfg.norm_eps)
    return x + L.swiglu(h, _c(p["w_gate"], cfg), _c(p["w_up"], cfg),
                        _c(p["w_down"], cfg))


def init_moe(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((D,), jnp.float32),
        "router": _dense_init(ks[0], (D, E)),
        "w_gate": _dense_init(ks[1], (E, D, F)),
        "w_up": _dense_init(ks[2], (E, D, F)),
        "w_down": _dense_init(ks[3], (E, F, D)),
    }


def _token_choice_dispatch(probs: Array, k: int, capacity: int):
    """Sort-based token-choice routing (no (T,E,C) mask).

    Returns (slot, keep, gate) each (T·k,): target slot = expert·C + rank,
    keep = rank < C, gate = renormalized top-k prob.
    """
    T, E = probs.shape
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)         # renorm (qwen3)
    flat_e = expert_ids.reshape(-1)                          # (T·k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                                 num_segments=E)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    ranks_sorted = jnp.arange(flat_e.shape[0]) - starts[sorted_e]
    ranks = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
    keep = ranks < capacity
    slot = flat_e * capacity + jnp.minimum(ranks, capacity - 1)
    return slot, keep, gate_vals.reshape(-1)


def apply_moe(p: dict, x: Array, cfg: ModelConfig) -> Array:
    """Mixture-of-experts FFN, token-choice top-k with capacity.

    The dispatch is an *incidence matrix* (token → expert) — the same
    sparse structure as the paper's D4M schema — realized as a sorted
    scatter/gather (segment algebra) rather than a dense (T,E,C) mask.

    Routing is **per sequence** (vmapped over batch): the sort/scatter
    stays local to each data shard.  A global-token argsort forces XLA
    to all-gather the batch and replicate giant scatter-index tensors
    (measured: 92 GiB/device on granite — see EXPERIMENTS.md §Perf).
    """
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    C = int(m.capacity_factor * S * k / E)       # capacity per sequence
    C = max((C + 7) // 8 * 8, 8)
    h = L.rms_norm(x, _c(p["ln"], cfg), cfg.norm_eps)      # (B, S, D)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    if m.router == "expert_choice":
        # experts pick their top-C tokens per sequence
        g, idx = jax.lax.top_k(probs.swapaxes(1, 2), C)      # (B, E, C)
        xe = jnp.take_along_axis(
            h[:, None], idx[..., None], axis=2)              # (B, E, C, D)
        ye = _expert_ffn(p, xe, cfg)
        out = jax.vmap(lambda y, i, gg: jax.ops.segment_sum(
            (y * gg[..., None].astype(y.dtype)).reshape(E * C, D),
            i.reshape(-1), num_segments=S))(ye, idx, g)
    else:
        def route_one(probs_s, h_s):
            """One sequence: (S, E) probs, (S, D) tokens."""
            slot, keep, gate = _token_choice_dispatch(probs_s, k, C)
            tok = jnp.repeat(jnp.arange(S), k)
            safe = jnp.where(keep, slot, E * C)              # dropped → OOB
            xe = jnp.zeros((E * C, D), h_s.dtype).at[safe].set(
                jnp.take(h_s, tok, axis=0), mode="drop")
            return xe.reshape(E, C, D), slot, keep, gate, tok

        xe, slot, keep, gate, tok = jax.vmap(route_one)(probs, h)
        ye = _expert_ffn(p, xe, cfg)                         # (B, E, C, D)

        def combine_one(y, sl, kp, gt, tk):
            contrib = jnp.take(y.reshape(E * C, D),
                               jnp.minimum(sl, E * C - 1), axis=0)
            contrib *= (gt * kp).astype(contrib.dtype)[:, None]
            return jax.ops.segment_sum(contrib, tk, num_segments=S)

        out = jax.vmap(combine_one)(ye, slot, keep, gate, tok)
    out = checkpoint_name(
        constrain(out.astype(x.dtype), "batch", None, None), "tp_out")
    return x + out


def _expert_ffn(p, xe, cfg):
    """(B, E, C, D) → (B, E, C, D) batched expert SwiGLU (EP over E)."""
    xe = constrain(xe, "batch", "model", None, None)
    g = jnp.einsum("becd,edf->becf", xe, _c(p["w_gate"], cfg))
    u = jnp.einsum("becd,edf->becf", xe, _c(p["w_up"], cfg))
    return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                      _c(p["w_down"], cfg))


# =============================================================================
# RG-LRU recurrent block (Griffin / recurrentgemma)
# =============================================================================

def init_rglru(cfg: ModelConfig, key) -> dict:
    D, Dr, W = cfg.d_model, cfg.d_rnn_resolved, cfg.conv_width
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.zeros((D,), jnp.float32),
        "wx": _dense_init(ks[0], (D, Dr)),
        "wg": _dense_init(ks[1], (D, Dr)),
        "conv_k": _dense_init(ks[2], (W, Dr), scale=W ** -0.5),
        "conv_b": jnp.zeros((Dr,), jnp.float32),
        "wa": _dense_init(ks[3], (Dr, Dr)),      # recurrence gate
        "wi": _dense_init(ks[4], (Dr, Dr)),      # input gate
        "lam": jnp.linspace(0.9, 5.0, Dr).astype(jnp.float32),  # Λ
        "wo": _dense_init(ks[5], (Dr, D)),
    }


class RGLRUCache(NamedTuple):
    h: Array          # (B, Dr) hidden state
    conv: Array       # (B, conv_w-1, Dr) conv tail


def init_rglru_cache(cfg: ModelConfig, batch: int) -> RGLRUCache:
    Dr = cfg.d_rnn_resolved
    dt = jnp.dtype(cfg.dtype)
    return RGLRUCache(jnp.zeros((batch, Dr), jnp.float32),
                      jnp.zeros((batch, cfg.conv_width - 1, Dr), dt))


def _rglru_gates(p, xc, cfg):
    """log_a (decay) and gated input for the linear recurrence."""
    c_const = 8.0
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xc, _c(p["wa"], cfg))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xc, _c(p["wi"], cfg))
                       .astype(jnp.float32))
    log_a = -c_const * jax.nn.softplus(p["lam"]) * r          # (..., Dr)
    a = jnp.exp(log_a)
    # sqrt(1-a²) normalization keeps the state scale input-independent
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * \
        (i * xc.astype(jnp.float32))
    return a, b


def apply_rglru(p: dict, x: Array, ctx: Ctx, cfg: ModelConfig):
    """Griffin recurrent block: proj → causal conv → RG-LRU → gated out."""
    B, S, D = x.shape
    h_in = L.rms_norm(x, _c(p["ln"], cfg), cfg.norm_eps)
    xb = jnp.einsum("bsd,de->bse", h_in, _c(p["wx"], cfg))
    gate = jnp.einsum("bsd,de->bse", h_in, _c(p["wg"], cfg))
    W = cfg.conv_width
    new_cache = None
    if ctx.mode == "decode":
        cache: RGLRUCache = ctx.cache
        ext = jnp.concatenate([cache.conv, xb], axis=1)       # (B, W-1+S, Dr)
        conv_in = ext
        new_tail = ext[:, -(W - 1):]
    else:
        conv_in = jnp.pad(xb, ((0, 0), (W - 1, 0), (0, 0)))
        # prefill: keep the last W-1 inputs so decode continues the conv
        new_tail = conv_in[:, -(W - 1):] if W > 1 else \
            jnp.zeros((B, 0, xb.shape[-1]), xb.dtype)
    xc = sum(conv_in[:, i:i + S] * _c(p["conv_k"][i], cfg)
             for i in range(W)) + _c(p["conv_b"], cfg)
    a, b = _rglru_gates(p, xc, cfg)
    if ctx.mode == "decode" and S == 1:
        cache: RGLRUCache = ctx.cache
        h_new = a[:, 0] * cache.h + b[:, 0]                   # (B, Dr)
        states = h_new[:, None]
        new_cache = RGLRUCache(h_new, new_tail)
    elif cfg.rglru_impl == "pallas" and ctx.mode == "prefill":
        # TPU kernel path (interpret on CPU); forward-only, so prefill
        from ..kernels.rglru import rglru_scan as _rglru_kernel
        from ..kernels.ops import default_interpret
        states = _rglru_kernel(a, b, interpret=default_interpret()
                               ).astype(jnp.float32)
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
        states = b_s                                          # h_t, (B,S,Dr)
    if ctx.mode == "prefill" and new_cache is None and S > 1:
        new_cache = RGLRUCache(states[:, -1].astype(jnp.float32), new_tail)
    out = states.astype(x.dtype) * jax.nn.gelu(gate)
    proj = checkpoint_name(
        jnp.einsum("bse,ed->bsd", out, _c(p["wo"], cfg)), "tp_out")
    return x + proj, new_cache


# =============================================================================
# RWKV-6 block (Finch): data-dependent decay time-mix + channel-mix
# =============================================================================

def init_rwkv(cfg: ModelConfig, key) -> dict:
    D, F, Lw = cfg.d_model, cfg.d_ff, cfg.decay_lora
    ks = jax.random.split(key, 12)
    H = cfg.n_heads
    Dh = D // H
    return {
        "ln1": jnp.zeros((D,), jnp.float32),
        # token-shift lerp coefficients for r,k,v,w,g
        "mu": 0.5 * jnp.ones((5, D), jnp.float32),
        "wr": _dense_init(ks[0], (D, D)),
        "wk": _dense_init(ks[1], (D, D)),
        "wv": _dense_init(ks[2], (D, D)),
        "wgate": _dense_init(ks[3], (D, D)),
        # data-dependent decay LoRA: w = exp(-exp(bias + tanh(x A) B))
        "dw_a": _dense_init(ks[4], (D, Lw)),
        "dw_b": _dense_init(ks[5], (Lw, D), scale=0.01),
        "dw_bias": -6.0 * jnp.ones((D,), jnp.float32),
        "u": jnp.zeros((H, Dh), jnp.float32),                # bonus
        "ln_x": jnp.zeros((D,), jnp.float32),                # per-head norm
        "wo": _dense_init(ks[6], (D, D)),
        # channel mix
        "ln2": jnp.zeros((D,), jnp.float32),
        "mu_c": 0.5 * jnp.ones((2, D), jnp.float32),
        "ck": _dense_init(ks[7], (D, F)),
        "cv": _dense_init(ks[8], (F, D)),
        "cr": _dense_init(ks[9], (D, D)),
    }


class RWKVCache(NamedTuple):
    wkv: Array       # (B, H, Dh, Dh) state (k-major)
    shift1: Array    # (B, D) last token (time-mix shift)
    shift2: Array    # (B, D) last token (channel-mix shift)


def init_rwkv_cache(cfg: ModelConfig, batch: int) -> RWKVCache:
    D, H = cfg.d_model, cfg.n_heads
    Dh = D // H
    dt = jnp.dtype(cfg.dtype)
    return RWKVCache(jnp.zeros((batch, H, Dh, Dh), jnp.float32),
                     jnp.zeros((batch, D), dt), jnp.zeros((batch, D), dt))


def wkv_scan(r, k, v, w, u, state0):
    """Reference WKV recurrence (also the decode step).

    r,k,v: (B,S,H,Dh); w: (B,S,H,Dh) decay in (0,1); u: (H,Dh) bonus.
    state: (B,H,Dh_k,Dh_v).  out_t = r_t · (state + u⊙k_t ⊗ v_t).
    """
    def step(state, xs):
        r_t, k_t, v_t, w_t = xs            # (B,H,Dh)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         state + u[None, :, :, None] * kv)
        state = w_t[..., None] * state + kv
        return state, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1), state     # (B,S,H,Dh), final state


def _wkv_unrolled(r, k, v, w, u, state0):
    """Python-unrolled wkv_scan (small S only; calibration path)."""
    outs = []
    state = state0
    for t in range(r.shape[1]):
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        outs.append(jnp.einsum("bhk,bhkv->bhv", r[:, t],
                               state + u[None, :, :, None] * kv))
        state = w[:, t][..., None] * state + kv
    return jnp.stack(outs, axis=1), state


def wkv_chunked(r, k, v, w, u, state0, chunk: int = 32):
    """Chunked-parallel WKV (matmul form — the MXU-friendly lowering).

    Splits S into chunks of C; within a chunk the causal interaction is a
    strict-lower-triangular (C×C) matmul pair; across chunks the state is
    carried by a scan.  Matches :func:`wkv_scan` to fp32 tolerance.

    Numerics: intra-chunk scores factor as
    ``(r_t ⊙ Πw_{<t}) · (k_s ⊘ Πw_{≤s})`` — the second factor grows like
    exp(|Σ log w|) over a chunk, so the decode path clips log-decay
    (see apply_rwkv) and C stays ≤ 32 to keep it inside f32 range.
    """
    B, S, H, Dh = r.shape
    C = min(chunk, S)
    assert S % C == 0
    n = S // C

    def reshape(t):  # (B,S,H,Dh) → (n,B,H,C,Dh)
        return t.reshape(B, n, C, H, Dh).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-38))                    # (n,B,H,C,Dh)
    cum = jnp.cumsum(logw, axis=3)                            # inclusive Πw_{≤t}
    q_eff = rc * jnp.exp(cum - logw)                          # r_t ⊙ Πw_{<t}
    k_in = kc * jnp.exp(-cum)                                 # k_s ⊘ Πw_{≤s}
    total = jnp.exp(cum[:, :, :, -1:, :])                     # full-chunk decay
    k_out = kc * jnp.exp(cum[:, :, :, -1:, :] - cum)          # decay s→chunk end
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)

    def step(state, xs):
        rq, kq, vq, qe, ki, ko, tot = xs
        # inter-chunk: queries read the carried state through decay-in
        inter = jnp.einsum("bhck,bhkv->bhcv", qe, state)
        # intra-chunk strict-causal attention
        scores = jnp.einsum("bhck,bhsk->bhcs", qe, ki) * tri
        intra = jnp.einsum("bhcs,bhsv->bhcv", scores, vq)
        # diagonal bonus: r_t · (u ⊙ k_t) v_t
        diag = jnp.einsum("bhck,hk->bhc", rq * kq, u)[..., None] * vq
        out = inter + intra + diag
        # state: decay across the chunk + end-decayed contributions
        state = state * tot.swapaxes(-1, -2) + \
            jnp.einsum("bhsk,bhsv->bhkv", ko, vq)
        return state, out

    xs = (rc, kc, vc, q_eff, k_in, k_out, total)
    state, outs = jax.lax.scan(step, state0, xs)
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dh)
    return outs, state


def _ddlerp(x, xprev, mu):
    return x + (xprev - x) * mu


def apply_rwkv(p: dict, x: Array, ctx: Ctx, cfg: ModelConfig):
    """RWKV-6 time-mix + channel-mix (pre-norm residual pair)."""
    B, S, D = x.shape
    H = cfg.n_heads
    Dh = D // H
    cache: Optional[RWKVCache] = ctx.cache
    # ---- time mix ----
    h = L.rms_norm(x, _c(p["ln1"], cfg), cfg.norm_eps)
    if ctx.mode == "decode" and cache is not None:
        prev = jnp.concatenate([cache.shift1[:, None], h[:, :-1]], axis=1)
    else:
        prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu = _c(p["mu"], cfg)
    xr, xk, xv, xw, xg = (_ddlerp(h, prev, mu[i]) for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, _c(p["wr"], cfg)).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", xk, _c(p["wk"], cfg)).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", xv, _c(p["wv"], cfg)).reshape(B, S, H, Dh)
    g = jnp.einsum("bsd,de->bse", xg, _c(p["wgate"], cfg))
    dw = p["dw_bias"] + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", xw.astype(jnp.float32),
                            p["dw_a"])), p["dw_b"])
    # clip keeps the chunked form's exp(±Σ log w) inside f32 range
    w = jnp.exp(-jnp.exp(jnp.minimum(dw, 0.5))).reshape(B, S, H, Dh)
    state0 = cache.wkv if (ctx.mode == "decode" and cache is not None) \
        else jnp.zeros((B, H, Dh, Dh), jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if cfg.rwkv_impl == "unrolled" and ctx.mode != "decode":
        # python-unrolled time loop: scan-free HLO for cost-analysis
        # calibration (XLA while bodies are counted once — see §Roofline)
        out, state = _wkv_unrolled(rf, kf, vf, w.astype(jnp.float32),
                                   p["u"], state0)
    elif cfg.rwkv_impl == "pallas" and ctx.mode == "prefill" and \
            S % cfg.rwkv_chunk == 0 and S >= cfg.rwkv_chunk:
        # TPU kernel path (interpret on CPU); forward-only → prefill.
        # The kernel starts from a zero state; the final state for the
        # decode hand-off is recovered with one chunked pass... the
        # kernel does not return state, so recompute it cheaply:
        from ..kernels.wkv6 import wkv6 as _wkv_kernel
        from ..kernels.ops import default_interpret
        out = _wkv_kernel(rf, kf, vf, w.astype(jnp.float32), p["u"],
                          chunk=cfg.rwkv_chunk,
                          interpret=default_interpret())
        _, state = wkv_chunked(rf, kf, vf, w.astype(jnp.float32),
                               p["u"], state0, chunk=cfg.rwkv_chunk)
    elif ctx.mode == "decode" or cfg.rwkv_impl == "scan" or \
            S % cfg.rwkv_chunk not in (0,) or S < cfg.rwkv_chunk:
        out, state = wkv_scan(rf, kf, vf, w.astype(jnp.float32),
                              p["u"], state0)
    else:
        out, state = wkv_chunked(rf, kf, vf, w.astype(jnp.float32),
                                 p["u"], state0, chunk=cfg.rwkv_chunk)
    out = out.reshape(B, S, D)
    out = L.rms_norm(out.astype(x.dtype), _c(p["ln_x"], cfg), cfg.norm_eps)
    out = out * jax.nn.silu(g)
    x = x + jnp.einsum("bsd,de->bse", out, _c(p["wo"], cfg))
    # ---- channel mix ----
    h2 = L.rms_norm(x, _c(p["ln2"], cfg), cfg.norm_eps)
    if ctx.mode == "decode" and cache is not None:
        prev2 = jnp.concatenate([cache.shift2[:, None], h2[:, :-1]], axis=1)
    else:
        prev2 = jnp.pad(h2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu_c = _c(p["mu_c"], cfg)
    xk2 = _ddlerp(h2, prev2, mu_c[0])
    xr2 = _ddlerp(h2, prev2, mu_c[1])
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk2, _c(p["ck"], cfg))))
    vv = jnp.einsum("bsf,fd->bsd", kk, _c(p["cv"], cfg))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr2, _c(p["cr"], cfg)))
    x = x + rr * vv
    new_cache = None
    if ctx.mode in ("decode", "prefill"):
        new_cache = RWKVCache(state, h[:, -1], h2[:, -1])
    return x, new_cache
