"""Activation-sharding context: model code asks, trainer provides.

Model code is mesh-agnostic; the trainer/dry-run installs an
``activation_sharding(mesh)`` context and layers call
``constrain(x, ...)`` with *logical* axes:

* ``"batch"`` → all data-parallel mesh axes (pod, data),
* ``"model"`` → the tensor-parallel axis (dropped automatically if the
  dim isn't divisible by the axis size, e.g. kv=8 heads on model=16),
* ``None``    → replicated.

Without an installed context, constrain() is a no-op, so single-device
smoke tests and pure-CPU runs are untouched.  These constraints pin the
head/hidden axes of attention and MLP intermediates to the model axis —
without them XLA's sharding propagation loses the head sharding through
the scan+checkpoint boundary and replicates O(S·S) attention buffers
per device (measured: 151 GiB/device → see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Optional[Mesh], profile: str = "2d"):
    prev = (getattr(_TLS, "mesh", None), getattr(_TLS, "profile", "2d"))
    _TLS.mesh = mesh
    _TLS.profile = profile
    try:
        yield
    finally:
        _TLS.mesh, _TLS.profile = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_TLS, "mesh", None)


def current_profile() -> str:
    return getattr(_TLS, "profile", "2d")


def constrain_heads(x: jax.Array) -> jax.Array:
    """(B, S, H, Dh): shard H over model if divisible, else shard Dh —
    replicated heads cost tp× attention flops (measured, calibrate.py)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    tp = mesh.shape.get("model", 1)
    h, dh = x.shape[-2], x.shape[-1]
    if h % tp == 0:
        return constrain(x, "batch", None, "model", None)
    if dh % tp == 0:
        return constrain(x, "batch", None, None, "model")
    return constrain(x, "batch", None, None, None)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Apply a logical sharding constraint if a mesh context is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    profile = current_profile()
    if profile == "zero3":
        batch_axes = tuple(mesh.axis_names)
        logical = tuple(None if ax == "model" else ax for ax in logical)
    else:
        batch_axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    if len(batch_axes) == 1:
        batch_axes = batch_axes[0]
    spec = []
    for dim, ax in zip(x.shape, logical):
        if ax == "batch":
            n = 1
            for a in (batch_axes if isinstance(batch_axes, tuple)
                      else (batch_axes,)):
                n *= mesh.shape[a]
            spec.append(batch_axes if dim % n == 0 else None)
        elif ax == "model":
            tp = mesh.shape.get("model", 1)
            spec.append("model" if dim % tp == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
