"""Model assembly: init, forward, loss, prefill/decode — all 10 archs.

Layers are grouped by the config's block ``pattern`` and executed with
``lax.scan`` over stacked per-group parameters (one trace per period —
the only way a 94-layer MoE lowers in reasonable time, and the structure
MaxText uses in production).  Hybrids (e.g. Griffin's R,R,L period) scan
over full periods; leftover tail layers run unrolled.

Modes:
* ``train``   — full-sequence forward, loss over shifted labels.
* ``prefill`` — full-sequence forward building decode caches.
* ``decode``  — single-token step consuming/updating caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import blocks as B
from . import layers as L
from .config import ATTN, LOCAL_ATTN, ModelConfig, RGLRU, RWKV

Array = jax.Array


# =============================================================================
# Parameter construction
# =============================================================================

def _init_layer(ltype: str, cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    if ltype in (ATTN, LOCAL_ATTN):
        p = {"attn": B.init_attn(cfg, ks[0])}
        if cfg.cross_attention:
            p["cross"] = B.init_attn(cfg, ks[2])
        p["mlp"] = B.init_moe(cfg, ks[1]) if cfg.moe else \
            B.init_mlp(cfg, ks[1])
        return p
    if ltype == RGLRU:
        return {"rglru": B.init_rglru(cfg, ks[0]),
                "mlp": B.init_mlp(cfg, ks[1])}
    if ltype == RWKV:
        return {"rwkv": B.init_rwkv(cfg, ks[0])}
    raise ValueError(ltype)


def init_params(cfg: ModelConfig, key) -> dict:
    period = cfg.pattern
    n_full = cfg.n_layers // len(period)
    tail_types = cfg.layer_types()[n_full * len(period):]
    keys = jax.random.split(key, 8)

    params: dict = {
        "embed": jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                                   jnp.float32) * cfg.d_model ** -0.5,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.padded_vocab),
            jnp.float32) * cfg.d_model ** -0.5

    if n_full:
        group = {}
        gkeys = jax.random.split(keys[2], n_full)
        for slot, ltype in enumerate(period):
            group[f"slot{slot}"] = jax.vmap(
                lambda k, lt=ltype: _init_layer(lt, cfg, k))(
                    jax.vmap(lambda k, s=slot: jax.random.fold_in(k, s))(
                        gkeys))
        params["groups"] = group
    if tail_types:
        params["tail"] = {
            f"layer{i}": _init_layer(lt, cfg,
                                     jax.random.fold_in(keys[3], i))
            for i, lt in enumerate(tail_types)}

    if cfg.is_encdec:
        ekeys = jax.random.split(keys[4], 2)
        enc_cfg = cfg  # same dims; encoder is non-causal, gelu-style MLP
        params["encoder"] = {
            "layers": jax.vmap(lambda k: {
                "attn": B.init_attn(enc_cfg, jax.random.fold_in(k, 0)),
                "mlp": B.init_mlp(enc_cfg, jax.random.fold_in(k, 1)),
            })(jax.random.split(ekeys[0], cfg.encoder_layers)),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    if cfg.frontend == "vision":
        # stub projection from precomputed patch embeds to d_model
        params["img_proj"] = jax.random.normal(
            keys[5], (cfg.d_model, cfg.d_model), jnp.float32) \
            * cfg.d_model ** -0.5
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# =============================================================================
# Forward
# =============================================================================

def _apply_layer(ltype: str, p: dict, x: Array, ctx: B.Ctx,
                 cfg: ModelConfig):
    if ltype in (ATTN, LOCAL_ATTN):
        window = cfg.window if ltype == LOCAL_ATTN else 0
        x, cache = B.apply_attn(p["attn"], x, ctx, cfg, window=window)
        if cfg.cross_attention:
            x = B.apply_cross_attn(p["cross"], x, ctx, cfg)
        x = B.apply_moe(p["mlp"], x, cfg) if cfg.moe else \
            B.apply_mlp(p["mlp"], x, cfg)
        return x, cache
    if ltype == RGLRU:
        x, cache = B.apply_rglru(p["rglru"], x, ctx, cfg)
        return B.apply_mlp(p["mlp"], x, cfg), cache
    if ltype == RWKV:
        return B.apply_rwkv(p["rwkv"], x, ctx, cfg)
    raise ValueError(ltype)


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """Decode caches, grouped exactly like the params (for the scan)."""
    def cache_for(ltype):
        if ltype == ATTN:
            return B.init_attn_cache(cfg, batch, s_max)
        if ltype == LOCAL_ATTN:
            return B.init_attn_cache(cfg, batch, s_max, window=cfg.window)
        if ltype == RGLRU:
            return B.init_rglru_cache(cfg, batch)
        if ltype == RWKV:
            return B.init_rwkv_cache(cfg, batch)
        raise ValueError(ltype)

    period = cfg.pattern
    n_full = cfg.n_layers // len(period)
    tail_types = cfg.layer_types()[n_full * len(period):]
    cache: dict = {}
    if n_full:
        cache["groups"] = {
            f"slot{i}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_full,) + a.shape).copy(),
                cache_for(lt))
            for i, lt in enumerate(period)}
    if tail_types:
        cache["tail"] = {f"layer{i}": cache_for(lt)
                         for i, lt in enumerate(tail_types)}
    return cache


def _run_layers(params, x, ctx: B.Ctx, cfg: ModelConfig, caches=None):
    """Scan the period groups, then the tail. Returns (x, new_caches)."""
    period = cfg.pattern
    n_full = cfg.n_layers // len(period)
    tail_types = cfg.layer_types()[n_full * len(period):]
    new_caches: dict = {}

    def group_body(x, slice_):
        gp, gc = slice_
        new_gc = {}
        for i, lt in enumerate(period):
            sub_ctx = B.Ctx(ctx.positions, ctx.mode,
                            None if gc is None else gc[f"slot{i}"],
                            ctx.enc_out, ctx.enc_pos)
            x, c = _apply_layer(lt, gp[f"slot{i}"], x, sub_ctx, cfg)
            if c is not None:
                new_gc[f"slot{i}"] = c
        return x, (new_gc if new_gc else None)

    if cfg.remat == "block":
        group_body = jax.checkpoint(group_body)
    elif cfg.remat == "block_save_coll":
        # remat, but KEEP tensor-parallel collective outputs: the backward
        # replay then skips re-running the all-reduces (§Perf: collective
        # passes 3→2 at the cost of one saved (B,S,D) tensor per sublayer)
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.save_only_these_names("tp_out"))

    if n_full:
        gp = params["groups"]
        gc = caches["groups"] if caches else None
        if cfg.scan_layers:
            def scan_body(x, slice_):
                return group_body(x, slice_)
            x, out_c = jax.lax.scan(scan_body, x, (gp, gc))
            if out_c is not None:
                new_caches["groups"] = out_c
        else:
            out_cs = []
            for li in range(n_full):
                sl = jax.tree.map(lambda a: a[li], (gp, gc))
                x, c = group_body(x, sl)
                out_cs.append(c)
            if out_cs and out_cs[0] is not None:
                new_caches["groups"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *out_cs)

    for i, lt in enumerate(tail_types):
        tp = params["tail"][f"layer{i}"]
        tc = caches["tail"][f"layer{i}"] if caches else None
        sub_ctx = B.Ctx(ctx.positions, ctx.mode, tc, ctx.enc_out,
                        ctx.enc_pos)
        x, c = _apply_layer(lt, tp, x, sub_ctx, cfg)
        if c is not None:
            new_caches.setdefault("tail", {})[f"layer{i}"] = c
    return x, (new_caches if new_caches else None)


def _encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """Whisper-style encoder over stub frame embeddings (non-causal)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                           x.shape[:2])

    def body(x, lp):
        ctx = B.Ctx(pos, "train")
        h = L.rms_norm(x, lp["attn"]["ln"].astype(x.dtype), cfg.norm_eps)
        q, k, v = B._qkv(lp["attn"], h, cfg)
        kv_map = B.head_kv_map(cfg) if cfg.phys_heads != cfg.n_heads \
            else None
        # encoder seq (1500 frames) is short — naive attention is fine
        out = L.attention(q, k, v, pos, pos, causal=False, impl="naive",
                          kv_map=kv_map)
        hm = B.head_mask(cfg, out.dtype)
        if hm is not None:
            out = out * hm[None, None, :, None]
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(*x.shape[:2], -1),
                           lp["attn"]["wo"].astype(x.dtype))
        x = B.apply_mlp(lp["mlp"], x, cfg)
        del ctx
        return x, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    else:
        for li in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[li],
                                        params["encoder"]["layers"]))
    return L.rms_norm(x, params["encoder"]["final_norm"].astype(x.dtype),
                      cfg.norm_eps)


def _embed_inputs(params, batch: dict, cfg: ModelConfig, mode: str):
    """Token embedding + modality prefixes. Returns (x, positions,
    enc_out, enc_pos, label_offset)."""
    tokens = batch["tokens"]
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * (cfg.d_model ** 0.5)
    offset = 0
    enc_out = enc_pos = None
    if cfg.frontend == "vision" and "img_embeds" in batch:
        img = jnp.einsum("bnd,de->bne", batch["img_embeds"].astype(dt),
                         params["img_proj"].astype(dt))
        x = jnp.concatenate([img, x], axis=1)
        offset = img.shape[1]
    if cfg.is_encdec and "frames" in batch:
        enc_out = _encode(params, batch["frames"], cfg)
    elif cfg.is_encdec and "enc_out" in batch:
        enc_out = batch["enc_out"].astype(dt)   # decode: encoder ran once
    if enc_out is not None:
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32),
            enc_out.shape[:2])
    if "positions" in batch:
        positions = batch["positions"]
        if offset:
            positions = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(offset, dtype=jnp.int32),
                                  (x.shape[0], offset)),
                 positions + offset], axis=1)
    else:
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
    return x, positions, enc_out, enc_pos, offset


def forward(params, batch: dict, cfg: ModelConfig, mode: str = "train",
            caches=None):
    """Returns (logits or hidden, new_caches)."""
    x, positions, enc_out, enc_pos, offset = _embed_inputs(
        params, batch, cfg, mode)
    ctx = B.Ctx(positions, mode, None, enc_out, enc_pos)
    x, new_caches = _run_layers(params, x, ctx, cfg, caches)
    x = L.rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    if offset:  # drop modality prefix before the LM head
        x = x[:, offset:]
    return x, new_caches


def _head_matrix(params, cfg):
    return (params["embed"].T if cfg.tie_embeddings else params["head"])


def logits_from_hidden(params, x, cfg):
    w = _head_matrix(params, cfg).astype(x.dtype)
    out = jnp.einsum("bsd,dv->bsv", x, w,
                     preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        out = jnp.tanh(out / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.padded_vocab != cfg.vocab:   # mask vocab-padding columns
        pad_mask = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e9)
        out = out + pad_mask
    return out


def loss_fn(params, batch: dict, cfg: ModelConfig) -> Array:
    """Next-token cross-entropy (labels = batch['labels'])."""
    x, _ = forward(params, batch, cfg, mode="train")
    labels = batch["labels"]
    if cfg.loss_chunk:
        w = _head_matrix(params, cfg).astype(x.dtype)
        return L.chunked_cross_entropy(x, w, labels, cfg.loss_chunk,
                                       valid_vocab=cfg.vocab)
    logits = logits_from_hidden(params, x, cfg)
    return L.cross_entropy(logits, labels)


def prefill(params, batch: dict, cfg: ModelConfig, s_max: int):
    """Run the prompt, build decode caches. Returns (last_logits, caches)."""
    caches = init_cache(cfg, batch["tokens"].shape[0], s_max)
    x, new_caches = forward(params, batch, cfg, mode="prefill",
                            caches=caches)
    logits = logits_from_hidden(params, x[:, -1:], cfg)
    return logits, new_caches


def decode_step(params, caches, batch: dict, cfg: ModelConfig):
    """One decode step: batch['tokens'] is (B, 1); returns (logits, caches)."""
    x, new_caches = forward(params, batch, cfg, mode="decode",
                            caches=caches)
    return logits_from_hidden(params, x, cfg), new_caches
