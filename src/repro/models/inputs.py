"""Input specifications per (architecture × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.  ``make_batch`` materializes small concrete batches for
smoke tests.

Applicability rules (DESIGN.md §Arch-applicability):
* ``long_500k`` only for sub-quadratic archs (SSM / hybrid / SWA);
* enc-dec (whisper) skips ``long_500k`` (not sub-quadratic) and supplies
  precomputed ``enc_out`` for decode shapes;
* ``[audio]``/``[vlm]`` stubs provide frame/patch embeddings directly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


class SkipCell(Exception):
    """Raised when an (arch × shape) cell is architecturally undefined."""


def check_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip-reason string, or None if the cell runs."""
    if shape.name == "long_500k":
        if cfg.is_encdec:
            return ("enc-dec: source is 30s/1500 frames; 500k-token decode "
                    "is architecturally undefined")
        if not cfg.sub_quadratic:
            return ("pure full-attention arch: 500k KV cache is the "
                    "subject of a different paper (per assignment, skipped)")
    return None


def _batch_dims(cfg: ModelConfig, shape: ShapeConfig,
                data_shards: int = 1) -> int:
    b = shape.global_batch
    assert b % data_shards == 0 or data_shards == 1
    return b


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the lowered step function of this cell."""
    reason = check_applicable(cfg, shape)
    if reason:
        raise SkipCell(reason)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        spec = {"tokens": SDS((B, S), i32), "labels": SDS((B, S), i32)}
        if cfg.frontend == "vision":
            spec["img_embeds"] = SDS((B, cfg.n_img_tokens, D), f32)
        if cfg.is_encdec:
            spec["frames"] = SDS((B, cfg.encoder_seq, D), f32)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": SDS((B, S), i32)}
        if cfg.frontend == "vision":
            spec["img_embeds"] = SDS((B, cfg.n_img_tokens, D), f32)
        if cfg.is_encdec:
            spec["frames"] = SDS((B, cfg.encoder_seq, D), f32)
        return spec
    # decode: one new token against caches of length seq_len
    spec = {"tokens": SDS((B, 1), i32), "positions": SDS((B, 1), i32)}
    if cfg.is_encdec:
        spec["enc_out"] = SDS((B, cfg.encoder_seq, D), f32)
    return spec


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract decode caches for this cell (no allocation)."""
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, shape.global_batch,
                          shape.seq_len))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete small batch (smoke tests) matching input_specs."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in input_specs(cfg, shape).items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab if k in ("tokens", "labels") else shape.seq_len
            out[k] = jnp.asarray(
                rng.integers(0, hi, size=s.shape), s.dtype)
        else:
            out[k] = jnp.asarray(
                rng.normal(0, 1, size=s.shape).astype(np.float32), s.dtype)
    return out
