"""repro.models — 10-architecture model zoo (pure JAX, scan-over-layers)."""
from . import blocks, inputs, layers, model
from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                     TRAIN_4K, ModelConfig, MoEConfig, ShapeConfig,
                     shape_by_name)
from .model import (abstract_params, decode_step, forward, init_cache,
                    init_params, loss_fn, prefill)

__all__ = [
    "ModelConfig", "MoEConfig", "ShapeConfig", "ALL_SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "shape_by_name",
    "init_params", "abstract_params", "forward", "loss_fn", "prefill",
    "decode_step", "init_cache", "layers", "blocks", "model", "inputs",
]
