"""Core neural layers — pure JAX, shard_map/pjit friendly.

Attention implementations:

* ``naive``   — materializes (S, S) scores; only for tiny smoke tests.
* ``chunked`` — two-level blocked online-softmax (flash-style) in pure
  jax.lax: outer scan over Q blocks, inner scan over KV blocks.  This is
  the default lowering for the dry-run: O(Bq·Ck) score tiles instead of
  O(S²), XLA counts its FLOPs, and it maps 1:1 onto the Pallas kernel
  (repro.kernels.flash_attention) used on real TPUs.
* ``chunked_tri`` — statically-unrolled triangular schedule (skips
  fully-masked KV blocks; ~2× FLOP reduction for causal, window/S for
  sliding-window).  The §Perf hillclimb measures exactly this delta.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from .shard_ctx import constrain

Array = jax.Array
NEG_INF = -1e30


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    out = out.astype(dt)
    if out.ndim == 3:
        # keep activations batch-sharded: without this, SPMD re-shards
        # (B,S,D) to batch-replicated/D-sharded to match the FSDP weight
        # layout, replicating the whole batch on every device (§Perf)
        out = constrain(out, "batch", None, None)
    return out


def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding. x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _expand_kv(k: Array, n_heads: int, kv_map: Array = None) -> Array:
    """GQA: repeat KV heads to match query heads. (B,S,KV,Dh)→(B,S,H,Dh).
    ``kv_map`` (head-padded archs) gives an explicit head→kv index that
    preserves the logical grouping (see blocks.head_kv_map)."""
    b, s, kv, dh = k.shape
    if kv_map is not None:
        return jnp.take(k, kv_map, axis=2)
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def _mask_bias(q_pos: Array, k_pos: Array, causal: bool,
               window: int = 0) -> Array:
    """(…,Sq,Sk) additive bias: 0 where visible, -inf where masked.
    k_pos < 0 marks invalid (unwritten ring-buffer) cache slots."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = (k_pos >= 0)[..., None, :]
    if causal:
        ok &= d >= 0
    if window:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_naive(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                    causal: bool = True, window: int = 0,
                    kv_map: Array = None) -> Array:
    """Reference attention. q: (B,Sq,H,Dh) k,v: (B,Sk,KV,Dh)."""
    h = q.shape[2]
    k = _expand_kv(k, h, kv_map)
    v = _expand_kv(v, h, kv_map)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores += _mask_bias(q_pos, k_pos, causal, window)[:, None]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _online_block(q_blk, k_blk, v_blk, bias, carry):
    """One online-softmax update. q_blk:(B,Bq,H,Dh), k/v:(B,Ck,H,Dh),
    bias:(B,Bq,Ck) or broadcastable; carry=(m,l,acc)."""
    m, l, acc = carry
    scale = q_blk.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias[:, None]                       # (B,H,Bq,Ck)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
        preferred_element_type=jnp.float32)
    return (m_new, l_new, acc_new)


def attention_chunked(q: Array, k: Array, v: Array, q_pos: Array,
                      k_pos: Array, causal: bool = True, window: int = 0,
                      chunk: int = 1024, triangular: bool = False,
                      kv_map: Array = None) -> Array:
    """Blocked online-softmax attention (flash-style, pure lax).

    ``triangular=True`` statically skips KV blocks that are fully masked
    (causal upper triangle / outside the sliding window) — the outer Q
    loop unrolls so each Q block's inner scan has static length.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    k = constrain(_expand_kv(k, h, kv_map), "batch", None, "model", None)
    v = constrain(_expand_kv(v, h, kv_map), "batch", None, "model", None)
    bq = min(chunk, sq)
    ck = min(chunk, sk)
    # pad ragged edges; padded K slots get k_pos = -1 (always masked) and
    # padded Q rows are sliced off the output.
    sq0 = sq
    if sq % bq:
        pad = bq - sq % bq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
        sq += pad
    if sk % ck:
        pad = ck - sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        sk += pad
    n_q, n_k = sq // bq, sk // ck

    kb = k.reshape(b, n_k, ck, h, dh)
    vb = v.reshape(b, n_k, ck, h, dh)
    kp = k_pos.reshape(*k_pos.shape[:-1], n_k, ck)

    def q_block(qi_static, q_blk, qp_blk, lo, hi):
        """Process one Q block against KV blocks [lo, hi)."""
        m0 = jnp.full((b, h, q_blk.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_blk.shape[1]), jnp.float32)
        a0 = jnp.zeros((b, h, q_blk.shape[1], dh), jnp.float32)

        # checkpoint: the backward recomputes the (Bq,Ck) probability tile
        # from q/k instead of saving it per step — the flash-attention
        # backward contract (O(Bq·Dh) residuals instead of O(Bq·Ck)).
        @jax.checkpoint
        def body(carry, j):
            k_blk = kb[:, j]
            v_blk = vb[:, j]
            bias = _mask_bias(qp_blk, kp[:, j], causal, window)
            return _online_block(q_blk, k_blk, v_blk, bias, carry), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), jnp.arange(lo, hi))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2).astype(q.dtype)     # (B,Bq,H,Dh)

    qb = q.reshape(b, n_q, bq, h, dh)
    qp = q_pos.reshape(*q_pos.shape[:-1], n_q, bq)

    if triangular:
        outs = []
        for i in range(n_q):
            if causal and window:
                lo = max(0, (i * bq - window) // ck)
            else:
                lo = 0
            hi = min(i * bq // ck + 1, n_k) if causal else n_k
            outs.append(q_block(i, qb[:, i], qp[:, i], lo, hi))
        return jnp.concatenate(outs, axis=1)[:, :sq0]

    def outer(_, i):
        return None, q_block(None, qb[:, i], qp[:, i], 0, n_k)

    _, outs = jax.lax.scan(outer, None, jnp.arange(n_q))
    # outs: (n_q, B, Bq, H, Dh) → (B, S, H, Dh)
    return outs.swapaxes(0, 1).reshape(b, sq, h, dh)[:, :sq0]


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
              impl="chunked", chunk=1024, kv_map=None):
    if impl == "pallas" and _pallas_attention_ok(q, k, chunk, kv_map):
        # TPU fast path (interpret=True on CPU). Forward-only: the Pallas
        # primitive has no VJP — training uses the chunked lowering.
        from ..kernels.flash_attention import flash_attention
        from ..kernels.ops import default_interpret
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=min(chunk, 256),
                               block_k=min(chunk, 256),
                               interpret=default_interpret())
    if impl == "naive" or q.shape[1] <= chunk:
        return attention_naive(q, k, v, q_pos, k_pos, causal, window,
                               kv_map=kv_map)
    if impl in ("chunked", "pallas"):
        return attention_chunked(q, k, v, q_pos, k_pos, causal, window,
                                 chunk=chunk, triangular=False,
                                 kv_map=kv_map)
    if impl == "chunked_tri":
        return attention_chunked(q, k, v, q_pos, k_pos, causal, window,
                                 chunk=chunk, triangular=True, kv_map=kv_map)
    raise ValueError(f"unknown attention impl {impl!r}")


def _pallas_attention_ok(q, k, chunk, kv_map) -> bool:
    """Kernel preconditions: no GQA remap table, block-divisible seqs,
    fresh contiguous positions (the kernel derives positions from block
    indices — ring-buffer decode uses the naive path)."""
    bq = min(chunk, 256, q.shape[1])
    bk = min(chunk, 256, k.shape[1])
    return (kv_map is None and q.shape[1] > 1
            and q.shape[1] % bq == 0 and k.shape[1] % bk == 0
            and q.shape[2] % k.shape[2] == 0)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = constrain(jnp.einsum("bsd,df->bsf", x, w_gate),
                  "batch", None, "model")
    u = constrain(jnp.einsum("bsd,df->bsf", x, w_up),
                  "batch", None, "model")
    # tag: output of the TP-contracted matmul (all-reduce point) — the
    # block_save_coll remat policy keeps this, skipping collective replay
    return checkpoint_name(
        jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down), "tp_out")


def gelu_mlp(x: Array, w_in: Array, b_in: Array, w_out: Array,
             b_out: Array) -> Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_in) + b_in)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out


def cross_entropy(logits: Array, labels: Array,
                  ignore_id: int = -100) -> Array:
    """Token-mean CE. logits: (B,S,V) any float dtype; labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(x: Array, w_head: Array, labels: Array,
                          n_chunks: int, ignore_id: int = -100,
                          valid_vocab: int = 0) -> Array:
    """Cross-entropy without materializing full (B,S,V) logits: the
    sequence axis is processed in chunks through the LM head.  A §Perf
    memory-term optimization (see EXPERIMENTS.md)."""
    b, s, d = x.shape
    cs = s // n_chunks
    assert s % n_chunks == 0
    v = w_head.shape[-1]
    pad_mask = jnp.where(jnp.arange(v) < valid_vocab, 0.0, -1e9) \
        if valid_vocab and valid_vocab != v else None

    def body(carry, i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * cs, cs, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", xs, w_head,
                            preferred_element_type=jnp.float32)
        if pad_mask is not None:
            logits = logits + pad_mask
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls != ignore_id).astype(jnp.float32)
        nll_sum, n_tok = carry
        return (nll_sum + jnp.sum((lse - gold) * mask),
                n_tok + jnp.sum(mask)), None

    (nll, n), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                               jnp.arange(n_chunks))
    return nll / jnp.maximum(n, 1.0)
