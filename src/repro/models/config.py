"""Model configuration covering the 10 assigned architecture families.

One :class:`ModelConfig` schema spans dense / GQA / SWA transformers,
MoE, hybrid (RG-LRU + local attention), RWKV-6, encoder–decoder, and
stub-fronted audio/VLM backbones.  Block composition is declared by
``pattern`` — a per-layer block-type string — so hybrids like
recurrentgemma's (R, R, A) period fall out of config, not code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# block types
ATTN = "A"        # global attention
LOCAL_ATTN = "L"  # local / sliding-window attention
RGLRU = "R"       # Griffin RG-LRU recurrent block
RWKV = "W"        # RWKV-6 time-mix block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    router: str = "token_choice"    # "token_choice" | "expert_choice"
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # block composition: period string over {A,L,R,W}; tiled to n_layers.
    pattern: str = ATTN
    head_dim: Optional[int] = None          # default d_model // n_heads
    window: int = 4096                      # for L blocks
    moe: Optional[MoEConfig] = None
    # enc-dec (whisper): if set, n_layers applies to decoder; encoder below
    encoder_layers: int = 0
    encoder_seq: int = 1500                 # whisper conv-frontend output
    cross_attention: bool = False
    # modality frontend stubs
    frontend: Optional[str] = None          # None | "audio" | "vision"
    n_img_tokens: int = 576                 # vision prefix length
    # head padding: physical head counts padded up so they divide the
    # tensor-parallel axis (Megatron-style). Padded heads' outputs are
    # hard-masked to zero, so the math is exactly the logical config —
    # without it, heads replicate on every device (16× attention flops,
    # measured via launch/calibrate.py).
    head_pad: int = 0               # physical n_heads (0 = no padding)
    kv_pad: int = 0                 # physical n_kv_heads
    # misc arch details
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    # rglru specifics
    d_rnn: Optional[int] = None             # default d_model
    conv_width: int = 4
    rglru_impl: str = "scan"                # "scan" | "pallas" (prefill)
    # rwkv specifics
    decay_lora: int = 64
    rwkv_impl: str = "chunked"              # "scan" | "chunked"
    rwkv_chunk: int = 32
    # dtypes
    dtype: str = "bfloat16"
    serve_param_dtype: str = "float32"     # "bfloat16": serving weights
    # implementation knobs (perf-relevant; see EXPERIMENTS.md §Perf)
    attention_impl: str = "chunked"         # "naive" | "chunked" | "pallas"
    attention_chunk: int = 1024
    remat: str = "block"                    # "none" | "block" | "full"
    scan_layers: bool = True
    loss_chunk: int = 0                     # 0 = unchunked cross-entropy

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def phys_heads(self) -> int:
        return self.head_pad or self.n_heads

    @property
    def phys_kv_heads(self) -> int:
        return self.kv_pad or self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Physical vocab padded to a multiple of 256 (Megatron-style) so
        the embedding/head shard evenly over the model axis; padded logit
        columns are masked to -inf before the loss/sampling."""
        return (self.vocab + 255) // 256 * 256

    @property
    def d_rnn_resolved(self) -> int:
        return self.d_rnn or self.d_model

    def layer_types(self) -> Tuple[str, ...]:
        """Tile ``pattern`` over n_layers: e.g. 'RRL' × 38 layers →
        R,R,L,R,R,L,...,R,R (truncated final period)."""
        reps = (self.n_layers + len(self.pattern) - 1) // len(self.pattern)
        return tuple((self.pattern * reps)[: self.n_layers])

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally (long_500k eligible)."""
        return ATTN not in self.layer_types()

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        H, KV, Dh = (self.phys_heads, self.phys_kv_heads,
                     self.resolved_head_dim)             # physical storage
        total = V * D                                   # embedding
        if not self.tie_embeddings:
            total += D * V                              # lm head
        per_type = {}
        attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
        if self.qkv_bias:
            attn += (H + 2 * KV) * Dh
        mlp = 3 * D * F if self.moe is None else (
            D * self.moe.n_experts
            + self.moe.n_experts * 3 * D * self.moe.d_expert)
        per_type[ATTN] = per_type[LOCAL_ATTN] = attn + mlp + 2 * D
        Dr = self.d_rnn_resolved
        per_type[RGLRU] = (2 * D * Dr + self.conv_width * Dr + 3 * Dr
                           + Dr * D + 2 * D) + mlp
        per_type[RWKV] = (6 * D + 4 * D * D + 2 * D * self.decay_lora
                          + self.decay_lora * D + D
                          + 2 * D) + (2 * D * F + D * D)
        for t in self.layer_types():
            total += per_type[t]
        if self.is_encdec:
            enc_attn = attn + 3 * D * F + 2 * D
            total += self.encoder_layers * enc_attn
            total += self.n_layers * (attn + 2 * D)     # cross-attn blocks
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        expert_p = self.moe.n_experts * 3 * self.d_model * self.moe.d_expert
        active_p = self.moe.top_k * 3 * self.d_model * self.moe.d_expert
        n_moe_layers = sum(1 for t in self.layer_types()
                           if t in (ATTN, LOCAL_ATTN))
        return full - n_moe_layers * (expert_p - active_p)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
