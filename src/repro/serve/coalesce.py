"""Request coalescing: N concurrent gateway queries → one batched eval.

The batched analytics layer (``repro.core.expr.eval_batch``) turns N
same-table queries into one union tablet scan and one device SpMM
launch — but HTTP requests arrive on independent threads, each holding
its own expression.  :class:`QueryCoalescer` is the meeting point: the
first arrival in an empty window becomes the *leader*, sleeps
``window`` seconds (default 3 ms — enough for a concurrent burst, below
human-visible latency), then evaluates everything that accumulated as
ONE ``eval_batch`` call and distributes the per-member results.
Followers just wait on their event; they never touch the planner.

Error semantics stay per-request: when the batch eval raises (e.g. one
member trips the degree guard), the leader falls back to member-by-
member evaluation so each request gets its *own* result or error —
one poisoned query cannot fail its neighbors.

``window <= 0`` disables coalescing (every request evaluates solo) —
the knob surfaces as ``Gateway(coalesce_window=...)``.
"""
from __future__ import annotations

import threading
import time

from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label

_M_BATCHES = _REGISTRY.counter(
    "repro_coalesce_batches_total", "Multi-member batch evals",
    labels=("coalescer",))
_M_COALESCED = _REGISTRY.counter(
    "repro_coalesce_coalesced_total", "Requests served by a batched eval",
    labels=("coalescer",))
_M_SOLO = _REGISTRY.counter(
    "repro_coalesce_solo_total",
    "Single-member windows (plus every request while disabled)",
    labels=("coalescer",))


class _Pending:
    __slots__ = ("expr", "result", "error", "done")

    def __init__(self, expr):
        self.expr = expr
        self.result = None
        self.error = None
        self.done = threading.Event()


class QueryCoalescer:
    """Leader-based window batching over ``eval_batch``.

    Stats: ``n_batches`` counts multi-member batch evals, ``n_coalesced``
    the requests served by them, ``n_solo`` the single-member windows
    (plus every request while disabled), ``max_batch`` the largest batch
    seen — the bench/CI signal that coalescing actually engaged.
    """

    def __init__(self, window: float = 0.003, clock=time.monotonic,
                 sleep=time.sleep):
        self.window = window
        self.clock = clock
        self._sleep = sleep
        self._lock = threading.Lock()
        self._pending: list = []
        self.metrics_label = _obj_label("coalescer")
        lab = dict(coalescer=self.metrics_label)
        self._m_batches = _M_BATCHES.labels(**lab)
        self._m_coalesced = _M_COALESCED.labels(**lab)
        self._m_solo = _M_SOLO.labels(**lab)
        self.max_batch = 0

    # registry-backed counter reads (compat: pre-obs attribute shapes)
    @property
    def n_batches(self) -> int:
        return self._m_batches.value

    @property
    def n_coalesced(self) -> int:
        return self._m_coalesced.value

    @property
    def n_solo(self) -> int:
        return self._m_solo.value

    def eval(self, expr):
        """Evaluate a deferred expression, batched with any concurrent
        callers inside one window.  Blocks until this request's result
        (or error) is ready."""
        if self.window <= 0:
            self._m_solo.inc()
            return expr.eval()
        p = _Pending(expr)
        with self._lock:
            is_leader = not self._pending
            self._pending.append(p)
        if is_leader:
            self._sleep(self.window)
            with self._lock:
                batch, self._pending = self._pending, []
            self._run(batch)
        p.done.wait()
        if p.error is not None:
            raise p.error
        return p.result

    def _run(self, batch: list) -> None:
        from ..core.expr import eval_batch
        if len(batch) >= 2:
            self._m_batches.inc()
            self._m_coalesced.inc(len(batch))
        else:
            self._m_solo.inc()
        with self._lock:
            self.max_batch = max(self.max_batch, len(batch))
        try:
            results = eval_batch([p.expr for p in batch])
            for p, r in zip(batch, results):
                p.result = r
        except Exception:
            # per-request error semantics: re-evaluate member by member
            # (already-computed members return their cached value)
            for p in batch:
                try:
                    p.result = p.expr.eval()
                except Exception as e:      # noqa: BLE001 — delivered
                    p.error = e             # to the request thread
        finally:
            for p in batch:
                p.done.set()

    def stats(self) -> dict:
        with self._lock:
            return {"window_s": self.window, "n_batches": self.n_batches,
                    "n_coalesced": self.n_coalesced, "n_solo": self.n_solo,
                    "max_batch": self.max_batch}
