"""Route table: thin JSON endpoints over ``DBTable`` + ``LazyAssoc``.

Every handler is a pure function ``(gateway, request) -> payload`` —
the HTTP plumbing (auth, rate limiting, error mapping, serialization)
lives in ``repro.serve.app``; the handlers only speak the D4M binding
and the analytics report types.  Each route declares a *cost* in
rate-limit tokens: a degree lookup is 1, a multi-band C2 sweep is 8 —
so a tenant's ``rate`` budget is spent proportionally to the tablet
work a request fans out.

Error surface (mapped by the app):

* bad/missing parameters → 400
* :class:`~repro.db.binding.AccidentalDenseError` (the degree guard
  refusing a super-node column band) → **413 Payload Too Large** — the
  result *would* be too large, re-issue with a tighter selector;
* admission refusal (trailing write rate makes a full scan
  inadmissible) and rate-limit rejections → **429** + ``Retry-After``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..analytics import detect_c2, fit_degree_table, scan_report
from ..analytics.powerlaw import degree_histogram
from ..analytics.serialize import to_jsonable
from ..core import keys as K


class HTTPError(Exception):
    def __init__(self, status: int, message: str,
                 headers: Optional[dict] = None):
        super().__init__(message)
        self.status = status
        self.headers = headers or {}


@dataclasses.dataclass
class Request:
    method: str
    path: str
    params: Dict[str, str]
    tenant: object = None           # Tenant, set after auth
    body: Optional[dict] = None     # decoded JSON for POSTs


@dataclasses.dataclass(frozen=True)
class Route:
    handler: Callable
    cost: float = 1.0
    stream: bool = False            # SSE: handler returns an iterator
    pattern: str = ""               # the route's registered pattern —
                                    # the bounded-cardinality metric label


# (method, pattern) → Route; "{id}"-style segments match any one segment
ROUTES: Dict[Tuple[str, str], Route] = {}


def route(method: str, pattern: str, cost: float = 1.0,
          stream: bool = False):
    def deco(fn):
        ROUTES[(method, pattern)] = Route(fn, cost=cost, stream=stream,
                                          pattern=pattern)
        return fn
    return deco


def match(method: str, path: str):
    """(Route, path_args) for the first pattern whose segments match."""
    segs = [s for s in path.split("/") if s]
    for (m, pattern), rt in ROUTES.items():
        if m != method:
            continue
        psegs = [s for s in pattern.split("/") if s]
        if len(psegs) != len(segs):
            continue
        args = {}
        for p, s in zip(psegs, segs):
            if p.startswith("{") and p.endswith("}"):
                args[p[1:-1]] = s
            elif p != s:
                break
        else:
            return rt, args
    return None, {}


# -- parameter helpers -----------------------------------------------------

def _int(req: Request, name: str, default: int,
         lo: int = 1, hi: int = 1_000_000) -> int:
    raw = req.params.get(name)
    if raw is None:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise HTTPError(400, f"{name} must be an integer, got {raw!r}")
    if not lo <= v <= hi:
        raise HTTPError(400, f"{name} must be in [{lo}, {hi}]")
    return v


def _require(req: Request, name: str) -> str:
    v = req.params.get(name)
    if v is None:
        raise HTTPError(400, f"missing required parameter {name!r}")
    return v


# -- query endpoints (cheap, interactive) ----------------------------------

@route("GET", "/v1/topk", cost=1.0)
def topk(gw, req: Request) -> dict:
    """Top-K talkers straight from the combiner-maintained degree table
    (TedgeDeg) — never touches the edge tables.  Expressed as a lazy
    TedgeDeg scan through the gateway's coalescer: concurrent topk
    requests inside one window share a single batched eval."""
    prefix = req.params.get("prefix", "ip.dst|")
    k = _int(req, "k", 10, hi=10_000)
    if gw.deg_table is not None:
        deg = gw.coalescer.eval(gw.deg_table[K.StartsWith(prefix), :])
    else:
        deg = gw.table.degree_assoc(prefix)
    r, _, v = deg.triples()
    v = np.asarray(v, np.float64)
    order = np.argsort(v)[::-1][:k]
    return {"prefix": prefix, "k": k,
            "hosts": [{"key": str(r[i]), "degree": float(v[i])}
                      for i in order]}


@route("GET", "/v1/degree", cost=2.0)
def degree_fit(gw, req: Request) -> dict:
    """Degree distribution: log-binned histogram + rank-size power-law
    fit over the TedgeDeg band under ``prefix``."""
    import jax.numpy as jnp
    prefix = req.params.get("prefix", "ip.dst|")
    bins = _int(req, "bins", 32, hi=512)
    deg = gw.table.degree_assoc(prefix)
    if deg.nnz == 0:
        return {"prefix": prefix, "n": 0, "fit": None, "histogram": None}
    d = jnp.asarray(np.asarray(deg.triples()[2], np.float32))
    fit = fit_degree_table(gw.table, prefix).to_dict()
    if not req.params.get("resid"):
        fit.pop("resid")            # O(n) payload, opt-in only
    centers, counts = degree_histogram(d, n_bins=bins)
    return {"prefix": prefix, "n": int(deg.nnz), "fit": fit,
            "histogram": {"centers": to_jsonable(centers),
                          "counts": to_jsonable(counts)}}


@route("GET", "/v1/c2", cost=8.0)
def c2(gw, req: Request) -> dict:
    """Fused C2 detector over the live table (four pushed-down column-
    band scans + device scoring)."""
    top_k = _int(req, "top_k", 10, hi=1000)
    rep = detect_c2(gw.table, sep=req.params.get("sep", "|"), top_k=top_k)
    return {"top_k": top_k, "report": rep.to_dict()}


@route("GET", "/v1/scanners", cost=8.0)
def scanners(gw, req: Request) -> dict:
    min_fanout = _int(req, "min_fanout", 32, hi=1_000_000)
    rep = scan_report(gw.table, sep=req.params.get("sep", "|"),
                      min_fanout=min_fanout)
    return {"report": rep.to_dict()}


# -- admission-limited scans -----------------------------------------------

def _selector(req: Request):
    """One of keys= / prefix= / start=&stop= — or None for a full axis."""
    if "keys" in req.params:
        return req.params["keys"]               # 'a,b,c,' grammar
    if "prefix" in req.params:
        return K.StartsWith(req.params["prefix"])
    if "start" in req.params or "stop" in req.params:
        return K.KeyRange(_require(req, "start"), _require(req, "stop"))
    return None


@route("GET", "/v1/scan", cost=4.0)
def scan(gw, req: Request) -> dict:
    """Subrange / prefix scan returning raw triples.

    ``axis=row`` scans Tedge, ``axis=col`` the transpose table (and
    runs the accidental-densification guard → 413).  With no selector
    the scan is full-table and subject to write-rate admission → 429.
    ``max_cells`` truncates the payload (default 10 000) — ``truncated``
    says whether more existed.

    Evaluation goes through the gateway's coalescer: concurrent scans
    arriving within one window batch into a single union tablet scan
    (``eval_batch``) — 8 concurrent column readers cost one scan.
    """
    axis = req.params.get("axis", "row")
    if axis not in ("row", "col"):
        raise HTTPError(400, f"axis must be 'row' or 'col', got {axis!r}")
    sel = _selector(req)
    max_cells = _int(req, "max_cells", 10_000, hi=1_000_000)
    if sel is None:
        gw.check_admission()        # full-table work needs admission
        lazy = gw.table[:, :]
    elif axis == "row":
        lazy = gw.table[sel, :]
    else:
        lazy = gw.table[:, sel]
    A = gw.coalescer.eval(lazy)
    r, c, v = A.triples()
    n = int(r.shape[0])
    cut = min(n, max_cells)
    return {"axis": axis, "nnz": n, "truncated": n > cut,
            "triples": [[str(r[i]), str(c[i]), str(v[i])]
                        for i in range(cut)]}


# -- async jobs ------------------------------------------------------------

def _job_fns(gw, params: dict) -> Dict[str, Callable[[], dict]]:
    """Job kinds → zero-arg closures returning JSON-serializable dicts.
    Long analytics only — cheap queries belong on the request path."""

    def pagerank() -> dict:
        from ..analytics.distributed import pagerank_table
        n_top = int(params.get("top_k", 20))
        keys, ranks = pagerank_table(
            gw.table, num_iters=int(params.get("num_iters", 20)))
        ranks = np.asarray(ranks)
        order = np.argsort(ranks)[::-1][:n_top]
        return {"nodes": [{"key": str(keys[i]), "rank": float(ranks[i])}
                          for i in order],
                "n_nodes": int(ranks.shape[0])}

    def degree_fit_full() -> dict:
        fit = fit_degree_table(gw.table, params.get("prefix", "ip.dst|"))
        return {"fit": fit.to_dict()}

    def c2_sweep() -> dict:
        rep = detect_c2(gw.table, top_k=int(params.get("top_k", 10)))
        return {"report": rep.to_dict()}

    def scan_sweep() -> dict:
        rep = scan_report(gw.table,
                          min_fanout=int(params.get("min_fanout", 32)))
        return {"report": rep.to_dict()}

    def root_cause_job() -> dict:
        sa = _stream_analytics(gw)
        try:
            start = float(params["start"])
            stop = float(params["stop"])
        except (KeyError, ValueError):
            raise HTTPError(400, "root_cause needs numeric "
                                 "params.start and params.stop")
        seeds = params.get("seeds")
        rep = sa.root_cause(start, stop, seeds=seeds,
                            top_k=int(params.get("top_k", 5)),
                            num_iters=int(params.get("num_iters", 30)))
        return {"report": rep.to_dict()}

    return {"pagerank": pagerank, "degree_fit": degree_fit_full,
            "c2": c2_sweep, "scanners": scan_sweep,
            "root_cause": root_cause_job}


@route("POST", "/v1/jobs", cost=2.0)
def submit_job(gw, req: Request) -> dict:
    """Enqueue a long analytic.  Identical (kind, params) submissions
    arriving while a matching job is still queued coalesce onto one
    execution per queue drain — each caller keeps its own job id."""
    import json
    body = req.body or {}
    kind = body.get("kind")
    params = body.get("params") or {}
    fns = _job_fns(gw, params)
    if kind not in fns:
        raise HTTPError(400, f"unknown job kind {kind!r}; "
                             f"one of {sorted(fns)}")
    bkey = json.dumps({"kind": kind, "params": params}, sort_keys=True)
    job = gw.jobs.submit(kind, fns[kind], req.tenant, batch_key=bkey)
    return job.describe()


@route("GET", "/v1/jobs/{id}", cost=0.1)
def job_status(gw, req: Request, id: str) -> dict:
    return gw.jobs.get(id).describe()


@route("GET", "/v1/jobs/{id}/result", cost=0.5)
def job_result(gw, req: Request, id: str) -> dict:
    job = gw.jobs.get(id)
    if job.status in ("queued", "running"):
        # 202: accepted, not ready — poll the status endpoint
        raise HTTPError(202, f"job {id} is {job.status}")
    if job.status == "failed":
        raise HTTPError(500, f"job {id} failed: {job.error}")
    return {"job": job.id, "kind": job.kind, "result": job.result}


# -- streaming temporal analytics (repro.stream) ---------------------------

def _stream_analytics(gw):
    sa = getattr(gw, "stream_analytics", None)
    if sa is None:
        raise HTTPError(404, "streaming analytics not enabled on this "
                             "gateway (boot with --stream)")
    return sa


@route("GET", "/v1/windows", cost=0.5)
def windows(gw, req: Request) -> dict:
    """Closed rollup-window summaries for one level, oldest first.
    ``level`` is second|minute|hour; ``since`` filters on window start
    (epoch seconds); summaries are the rollup's WindowSummary reports
    (counts, unique src/dst, top destination, power-law fit)."""
    sa = _stream_analytics(gw)
    level = req.params.get("level", "second")
    if level not in dict(sa.rollup.levels):
        raise HTTPError(400, f"unknown level {level!r}; one of "
                             f"{sorted(dict(sa.rollup.levels))}")
    since = req.params.get("since")
    try:
        since_f = float(since) if since is not None else None
    except ValueError:
        raise HTTPError(400, f"since must be a number, got {since!r}")
    items = sa.rollup.summaries(
        level=level, limit=_int(req, "limit", 100, hi=10_000),
        since=since_f)
    return {"level": level, "n": len(items),
            "windows": [w.to_dict() for w in items]}


@route("GET", "/v1/alerts", cost=0.5)
def alerts(gw, req: Request) -> dict:
    """Recent detector alerts, oldest first.  ``kind`` filters to one
    of spc|c2|scan|ddos; ``since`` on window start."""
    sa = _stream_analytics(gw)
    since = req.params.get("since")
    try:
        since_f = float(since) if since is not None else None
    except ValueError:
        raise HTTPError(400, f"since must be a number, got {since!r}")
    items = sa.bank.alerts(limit=_int(req, "limit", 100, hi=10_000),
                           kind=req.params.get("kind"), since=since_f)
    return {"n": len(items), "alerts": [a.to_dict() for a in items]}


@route("GET", "/v1/stream/alerts", cost=1.0, stream=True)
def stream_alerts(gw, req: Request):
    """SSE live feed of detector alerts (one ``data: <json>`` frame per
    AlertReport).  ``n`` bounds the number of events; ``replay`` resends
    that many recent alerts first."""
    _stream_analytics(gw)
    n = req.params.get("n")
    return gw.alert_publisher.events(
        max_events=int(n) if n is not None else None,
        replay=_int(req, "replay", 0, lo=0, hi=10_000))


# -- observability ---------------------------------------------------------

@route("GET", "/v1/stats", cost=0.1)
def stats(gw, req: Request) -> dict:
    """The unified counter snapshot: table (routes/cache/writers/backend)
    + rate limiter + job queue + the stream's latest windowed sample."""
    from ..core.expr import launch_counts
    sa = getattr(gw, "stream_analytics", None)
    return {"table": to_jsonable(gw.table.stats()),
            "ratelimit": gw.limiter.stats(),
            "jobs": gw.jobs.stats(),
            "coalesce": gw.coalescer.stats(),
            "kernel_launches": launch_counts(),
            "trace": gw.tracer.stats(),
            "stream": gw.publisher.latest(),
            "streaming": to_jsonable(sa.stats()) if sa is not None
            else None}


@route("GET", "/v1/trace/{id}", cost=0.1)
def trace_tree(gw, req: Request, id: str) -> dict:
    """The span tree one traced request left behind: request with
    ``?trace=1`` (or an ``X-Trace-Id`` header), read the ``X-Trace-Id``
    response header, fetch it here.  404 once the trace ages out of the
    tracer's bounded ring."""
    tree = gw.tracer.tree(id)
    if tree is None:
        raise HTTPError(404, f"unknown trace {id!r} (never sampled, or "
                             f"evicted from the ring)")
    return {"trace": id, "tree": tree}


@route("GET", "/v1/debug/slow", cost=0.1)
def slow_log(gw, req: Request) -> dict:
    """The slow-query log: the N slowest requests over the tracer's
    threshold, slowest first — traced entries carry their full span
    tree, untraced ones are tree-less but still present."""
    return {"threshold_s": gw.tracer.slow_threshold_s,
            "slow": gw.tracer.slow()}


@route("GET", "/v1/stream/stats", cost=1.0, stream=True)
def stream_stats(gw, req: Request):
    """SSE live stream of windowed ingest/query counters.  ``n`` bounds
    the number of events (handy for curl/tests); ``replay`` resends that
    many recent samples first."""
    n = req.params.get("n")
    return gw.publisher.events(
        max_events=int(n) if n is not None else None,
        replay=_int(req, "replay", 0, lo=0, hi=10_000))
