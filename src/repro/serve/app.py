"""The gateway: threaded HTTP front door over one ``DBTable``.

Topology (arXiv:2309.02464's operational shape): one gateway process
binds a ``DB()`` backend — in-process memory, durable LSM, or a net
shard cluster — and serves many concurrent analyst requests while
ingest keeps flowing through the same backend's
:class:`~repro.db.writer.WriterPool`.  The concurrency contract that
makes this work:

* every reader thread takes the binding's *read barrier*
  (``WriterPool.drain``) — a snapshot wait on the spill sequence, so a
  reader waits only for writes that preceded its request, never behind
  ingest still arriving (readers are not serialized behind the write
  barrier);
* hot bands are served from the shared per-backend
  :class:`~repro.db.binding.ScanCache` (write-path invalidation keeps
  them coherent; many readers share one cache);
* request threads come from :class:`ThreadingHTTPServer` (one per
  connection, daemon) — long analytics are pushed to the bounded
  :class:`~repro.serve.jobs.JobQueue` instead of pinning them.

Request pipeline: authenticate (401) → rate-limit at the route's cost
(429 + Retry-After) → dispatch; the degree guard surfaces as 413 and
write-rate admission refusals as 429 (see ``repro.serve.routes``).

Run standalone::

    python -m repro.serve --backend net --n-instances 4 \\
        --token s3cret:analytics:50 --port 8080

"""
from __future__ import annotations

import json
import random
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlsplit

from ..db.binding import AccidentalDenseError, DBTable
from ..db.writer import AsyncWriterError
from ..obs.metrics import REGISTRY
from ..obs.trace import Tracer
from .auth import AuthError, TokenAuth
from .coalesce import QueryCoalescer
from .jobs import JobQueue, QueueFull, UnknownJob
from .ratelimit import RateLimited, RateLimiter
from .routes import HTTPError, Request, match
from .stream import AlertPublisher, StatsPublisher

_PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# HTTP metric families, labeled by registered route *pattern* (bounded
# cardinality — "/v1/jobs/{id}", never the raw path) and status.  The
# gateway pins each child it uses in _http_children (families hold
# children weakly).
_M_HTTP = REGISTRY.counter(
    "repro_http_requests_total", "Gateway requests by route and status",
    labels=("route", "status"))
_M_HTTP_SECONDS = REGISTRY.histogram(
    "repro_http_request_seconds",
    "Gateway request wall time by route (SSE: setup only)",
    labels=("route",))


class Gateway:
    """Auth + rate limiting + routes + jobs + stream over one table."""

    def __init__(self, table: DBTable, auth: TokenAuth,
                 degree_limit: Optional[float] = None,
                 n_job_workers: int = 2, max_queued_jobs: int = 64,
                 job_result_ttl: float = 600.0,
                 stats_interval: float = 1.0,
                 coalesce_window: float = 0.003,
                 stream_analytics=None,
                 trace_sample: float = 0.0,
                 slow_threshold_s: float = 0.25):
        # the serving view always runs the densification guard: an
        # interactive endpoint must 413, never OOM the gateway
        if degree_limit is not None:
            table = table.with_degree_limit(degree_limit)
        self.table = table
        self.auth = auth
        self.limiter = RateLimiter()
        # request tracing: ?trace=1 / X-Trace-Id always trace; otherwise
        # trace_sample (probability, default 0.0) decides — the untraced
        # hot path costs one ContextVar read per instrumented site.  The
        # tracer doubles as the slow-query log (/v1/debug/slow).
        self.trace_sample = float(trace_sample)
        self.tracer = Tracer(slow_threshold_s=slow_threshold_s)
        self._http_children: dict = {}      # (route, status) pins
        self._http_lock = threading.Lock()
        self.jobs = JobQueue(n_workers=n_job_workers,
                             max_queued=max_queued_jobs,
                             result_ttl=job_result_ttl)
        # concurrent hot-path queries (topk, column scans) arriving
        # within this window evaluate as ONE eval_batch — a union
        # tablet scan + one device launch instead of N (<= 0 disables)
        self.coalescer = QueryCoalescer(window=coalesce_window)
        # a degree-table view sharing the main view's counters/cache,
        # so /v1/topk expresses as a *batchable* lazy TedgeDeg scan
        if self.table._is_degree:
            self.deg_table: Optional[DBTable] = self.table
        elif "TedgeDeg" in self.table.tables:
            dt = DBTable(self.table.backend, ("TedgeDeg",),
                         name=self.table.name,
                         cache_ttl=self.table.cache_ttl)
            dt.stats = self.table.stats
            self.deg_table = dt
        else:
            self.deg_table = None
        self.publisher = StatsPublisher(table, interval=stats_interval)
        # streaming temporal analytics (repro.stream): rollup rides the
        # table's WriterPool ingest tap, alerts fan out over SSE
        self.stream_analytics = stream_analytics
        self.alert_publisher: Optional[AlertPublisher] = None
        if stream_analytics is not None:
            self.alert_publisher = AlertPublisher()
            stream_analytics.on_alert(self.alert_publisher.on_alert)
            if getattr(stream_analytics, "_table", None) is None:
                stream_analytics.attach(self.table)
            stream_analytics.start()
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self.address: Optional[str] = None

    # -- cluster-state admission (tenant-blind; see ratelimit.py) ----------
    def check_admission(self) -> None:
        if not self.table.admit_full_scan():
            cache = getattr(self.table.backend, "_scan_cache", None)
            window = cache.wps_window if cache is not None else 10.0
            raise HTTPError(
                429,
                f"full scan inadmissible: trailing write rate "
                f"{self.table.write_rate:.1f}/s exceeds the backend's "
                f"full-scan limit; retry when ingest slows",
                headers={"Retry-After": f"{window:g}"})

    # -- lifecycle ---------------------------------------------------------
    def start(self, host: str = "127.0.0.1", port: int = 0) -> str:
        """Bind and serve in a background thread; returns ``host:port``
        (``port=0`` picks an ephemeral port)."""
        gw = self

        class Handler(_GatewayHandler):
            gateway = gw

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        # never join request threads on close: a live SSE stream would
        # stall shutdown until its client went away
        self._httpd.block_on_close = False
        self.address = f"{host}:{self._httpd.server_address[1]}"
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"gateway/{self.address}", daemon=True)
        self._thread.start()
        return self.address

    def stop(self) -> None:
        """Stop streaming, fail queued jobs fast, close the listener."""
        self.publisher.close()      # ends SSE generators first
        if self.alert_publisher is not None:
            self.alert_publisher.close()
        if self.stream_analytics is not None:
            self.stream_analytics.close()
        self.jobs.close()
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    # -- dispatch (called from request threads) ----------------------------
    def handle(self, req: Request, authorization: Optional[str],
               headers=None):
        """(status, payload, resp_headers) — payload is a dict, an SSE
        iterator, or a str (plain-text endpoints like /metrics).  Wraps
        :meth:`_handle` with the observability shell: per-request trace
        root (opt-in), HTTP counters/latency by route pattern, and the
        untraced slow-query note.  ``headers`` is the incoming header
        mapping (for ``X-Trace-Id``)."""
        if req.method == "GET" and req.path == "/metrics":
            # the scrape endpoint: unauthenticated, unmetered, untraced —
            # a Prometheus target can't carry tenant tokens
            return 200, REGISTRY.render(), {
                "Content-Type": _PROM_CONTENT_TYPE}
        incoming = headers.get("X-Trace-Id") if headers is not None else None
        traced = (req.params.get("trace") == "1" or bool(incoming)
                  or (self.trace_sample > 0.0
                      and random.random() < self.trace_sample))
        wall0 = time.time()
        t0 = time.perf_counter()
        status = 500
        root = None
        try:
            if traced:
                root = self.tracer.start(f"{req.method} {req.path}",
                                         trace_id=incoming,
                                         method=req.method, path=req.path)
                with root:
                    status, out, hdrs = self._handle(req, authorization)
                hdrs = dict(hdrs)
                hdrs["X-Trace-Id"] = root.trace_id
                return status, out, hdrs
            status, out, hdrs = self._handle(req, authorization)
            return status, out, hdrs
        except Exception as e:
            status = getattr(e, "status", 500)
            if root is not None:
                # best-effort: the error response still names its trace
                eh = getattr(e, "headers", None)
                if isinstance(eh, dict):
                    eh.setdefault("X-Trace-Id", root.trace_id)
            raise
        finally:
            dur = time.perf_counter() - t0
            pattern = getattr(req, "route_pattern", req.path)
            self._observe_http(pattern, status, dur)
            if not traced:
                # sampling must never hide a slow query entirely
                self.tracer.note_slow(f"{req.method} {req.path}", wall0,
                                      dur, route=pattern, status=status)

    def _observe_http(self, pattern: str, status: int, dur: float) -> None:
        key = (pattern, str(status))
        with self._http_lock:
            pair = self._http_children.get(key)
            if pair is None:
                pair = (_M_HTTP.labels(route=pattern, status=str(status)),
                        _M_HTTP_SECONDS.labels(route=pattern))
                self._http_children[key] = pair
        counter, hist = pair
        counter.inc()
        hist.observe(dur)

    def _handle(self, req: Request, authorization: Optional[str]):
        """The pre-obs dispatch: route match → auth → rate limit →
        handler, with all error mapping."""
        if req.method == "GET" and req.path == "/healthz":
            req.route_pattern = "/healthz"
            return 200, {"ok": True}, {}
        rt, args = match(req.method, req.path)
        if rt is None:
            raise HTTPError(404, f"no route for {req.method} {req.path}")
        req.route_pattern = rt.pattern      # bounded metric label
        req.tenant = self.auth.authenticate(authorization)
        try:
            self.limiter.acquire(req.tenant, rt.cost)
        except RateLimited as e:
            raise HTTPError(429, str(e),
                            headers={"Retry-After": f"{e.retry_after:.3f}"})
        try:
            out = rt.handler(self, req, **args)
        except AccidentalDenseError as e:
            # the degree guard: this column band would densify; the
            # query is refused, not the tenant — no Retry-After
            raise HTTPError(413, f"query refused by degree guard: {e}")
        except QueueFull as e:
            raise HTTPError(503, str(e), headers={"Retry-After": "5"})
        except UnknownJob as e:
            raise HTTPError(404, f"unknown job {e.args[0]!r}")
        except AsyncWriterError as e:
            raise HTTPError(500, f"backend writer failed: {e}")
        return 200, out, {}


class _GatewayHandler(BaseHTTPRequestHandler):
    gateway: Gateway = None         # bound by Gateway.start
    protocol_version = "HTTP/1.1"

    # -- plumbing ----------------------------------------------------------
    def log_message(self, fmt, *args):      # quiet; stats cover requests
        pass

    def _request(self) -> Request:
        parts = urlsplit(self.path)
        params = {k: v[0] for k, v in parse_qs(parts.query).items()}
        body = None
        if self.command == "POST":
            n = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(n) if n else b""
            if raw:
                try:
                    body = json.loads(raw)
                except json.JSONDecodeError as e:
                    raise HTTPError(400, f"bad JSON body: {e}")
        return Request(self.command, parts.path, params, body=body)

    def _send_json(self, status: int, payload: dict,
                   headers: Optional[dict] = None) -> None:
        data = json.dumps(payload).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _send_text(self, status: int, text: str,
                   headers: Optional[dict] = None) -> None:
        data = text.encode("utf-8")
        headers = dict(headers or {})
        ctype = headers.pop("Content-Type", "text/plain; charset=utf-8")
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        for k, v in headers.items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _send_sse(self, frames) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            for frame in frames:
                self.wfile.write(frame)
                self.wfile.flush()
        except (BrokenPipeError, ConnectionError, OSError):
            pass                    # client went away mid-stream
        finally:
            self.close_connection = True

    def _dispatch(self) -> None:
        try:
            req = self._request()
            status, out, headers = self.gateway.handle(
                req, self.headers.get("Authorization"),
                headers=self.headers)
            if hasattr(out, "__next__"):        # SSE iterator
                self._send_sse(out)
                return
            if isinstance(out, str):            # plain text (/metrics)
                self._send_text(status, out, headers)
                return
            self._send_json(status, out, headers)
        except (HTTPError, AuthError, RateLimited) as e:
            status = getattr(e, "status", 500)
            headers = getattr(e, "headers", {})
            self._send_json(status, {"error": str(e), "status": status},
                            headers)
        except (BrokenPipeError, ConnectionError):
            pass
        except Exception as e:      # never kill the request thread silently
            try:
                self._send_json(500, {"error": f"{type(e).__name__}: {e}",
                                      "status": 500})
            except OSError:
                pass

    def do_GET(self) -> None:
        self._dispatch()

    def do_POST(self) -> None:
        self._dispatch()


# ---------------------------------------------------------------------------
# Synthetic demo traffic + CLI.
# ---------------------------------------------------------------------------

def synthetic_incidence(seed: int = 0, duration: float = 60.0,
                        n_hosts: int = 128, n_bots: int = 8):
    """A small synthetic traffic capture as an incidence Assoc — the
    pipeline's generator, shared by the CLI's ``--demo-rows``, the
    gateway tests, and ``bench_serving``."""
    from ..core.schema import parse_tsv, val2col
    from ..pipeline import TrafficConfig
    from ..pipeline.pcap import records_to_tsv, synth_packets
    tcfg = TrafficConfig(n_hosts=n_hosts, pkt_rate=120.0, n_bots=n_bots,
                         beacon_period_s=5.0, beacon_jitter_s=0.1,
                         seed=seed)
    return val2col(parse_tsv(records_to_tsv(synth_packets(tcfg, duration))))


def main(argv=None) -> None:
    """``python -m repro.serve`` — boot a gateway over a fresh or
    existing backend; prints ``LISTENING host:port`` once bound."""
    import argparse
    import signal

    from ..db import DB

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--backend", default="memory",
                   choices=("memory", "lsm", "net"))
    p.add_argument("--n-instances", type=int, default=1)
    p.add_argument("--path", default=None,
                   help="store directory (lsm, or durable net shards)")
    p.add_argument("--token", action="append", default=[],
                   metavar="TOKEN:TENANT[:RATE[:BURST]]",
                   help="register a tenant token (repeatable)")
    p.add_argument("--degree-limit", type=float, default=None)
    p.add_argument("--stats-interval", type=float, default=1.0)
    p.add_argument("--job-workers", type=int, default=2)
    p.add_argument("--coalesce-window", type=float, default=0.003,
                   help="seconds concurrent hot-path queries wait to "
                        "batch into one eval (0 disables)")
    p.add_argument("--trace-sample", type=float, default=0.0,
                   help="probability of tracing a request that didn't "
                        "ask (?trace=1 and X-Trace-Id always trace)")
    p.add_argument("--slow-threshold", type=float, default=0.25,
                   help="seconds above which a request enters the "
                        "slow-query log (/v1/debug/slow)")
    p.add_argument("--demo-rows", type=int, default=0,
                   help="ingest ~this many synthetic traffic edges at "
                        "boot (demo/smoke)")
    p.add_argument("--stream", action="store_true",
                   help="enable streaming temporal analytics: rollups "
                        "on the ingest tap, online detectors, "
                        "/v1/windows + /v1/alerts + SSE alert feed")
    args = p.parse_args(argv)
    if not args.token:
        p.error("at least one --token TOKEN:TENANT is required")

    T = DB("Tedge", "TedgeT", "TedgeDeg", backend=args.backend,
           n_instances=args.n_instances, path=args.path)
    sa = None
    if args.stream:
        from ..stream import StreamAnalytics
        # attach before any demo ingest so the rollup sees every block
        sa = StreamAnalytics().attach(T)
    if args.demo_rows:
        E = synthetic_incidence(duration=max(args.demo_rows / 480.0, 5.0))
        T.put(E, sync=False)
        T.flush()
    gw = Gateway(T, TokenAuth.from_specs(args.token),
                 degree_limit=args.degree_limit,
                 n_job_workers=args.job_workers,
                 stats_interval=args.stats_interval,
                 coalesce_window=args.coalesce_window,
                 stream_analytics=sa,
                 trace_sample=args.trace_sample,
                 slow_threshold_s=args.slow_threshold)
    addr = gw.start(host=args.host, port=args.port)
    print(f"LISTENING {addr}", flush=True)

    done = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: done.set())
    done.wait()
    gw.stop()
    T.close()
    close = getattr(T.backend, "close", None)
    if close is not None:
        close()
