"""``python -m repro.serve`` — run the analytics gateway standalone."""
from .app import main

if __name__ == "__main__":
    main()
