"""repro.serve — the multi-tenant analytics serving gateway.

The front door over any ``DB()`` backend (memory | lsm | net):
authenticated JSON query endpoints, per-tenant token-bucket rate
limiting plus write-rate admission control, a bounded background job
queue for long analytics, and a live SSE stats stream.  Stdlib-only
(``http.server`` threads), matching the netstore's no-new-deps framing
style.  See docs/api.md "Serving gateway".
"""
from .app import Gateway, main, synthetic_incidence
from .auth import AuthError, Tenant, TokenAuth
from .coalesce import QueryCoalescer
from .jobs import JobQueue, QueueFull, UnknownJob
from .ratelimit import RateLimited, RateLimiter, TokenBucket
from .routes import HTTPError, Request, ROUTES
from .stream import AlertPublisher, EventPublisher, StatsPublisher

__all__ = ["Gateway", "main", "synthetic_incidence", "QueryCoalescer",
           "TokenAuth", "Tenant", "AuthError",
           "RateLimiter", "TokenBucket", "RateLimited",
           "JobQueue", "QueueFull", "UnknownJob",
           "EventPublisher", "StatsPublisher", "AlertPublisher",
           "HTTPError", "Request", "ROUTES"]
