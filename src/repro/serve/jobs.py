"""Background job queue for long analytics.

PageRank over the whole graph or a full-table power-law fit can take
longer than an interactive HTTP request should hold a connection, and
running them on gateway request threads would starve the cheap query
endpoints.  Jobs decouple the two: ``POST /v1/jobs`` enqueues, a small
bounded worker pool executes, and the client polls
``GET /v1/jobs/<id>`` until ``done`` then fetches the result.

Bounds, because a serving tier must fail fast rather than buffer
unboundedly:

* ``max_queued`` — total queued jobs; beyond it submission raises
  :class:`QueueFull` → HTTP 503 (the cluster is saturated, retry later);
* per-tenant ``max_jobs`` (from :class:`~repro.serve.auth.Tenant`) —
  one tenant cannot occupy the whole queue;
* ``result_ttl`` — finished jobs are dropped after this many seconds
  (first-poll-after-expiry sweeps them), bounding result memory.

Results must already be JSON-serializable — job functions return
``to_dict()``-style payloads (see ``repro.serve.routes``).
"""
from __future__ import annotations

import queue
import secrets
import threading
import time
import weakref
from typing import Callable, Dict, Optional

from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from .auth import Tenant

_M_SUBMITTED = _REGISTRY.counter(
    "repro_jobs_submitted_total", "Jobs accepted into the queue",
    labels=("jobs",))
_M_COMPLETED = _REGISTRY.counter(
    "repro_jobs_completed_total", "Jobs finished successfully",
    labels=("jobs",))
_M_FAILED = _REGISTRY.counter(
    "repro_jobs_failed_total", "Jobs that raised or were shut down",
    labels=("jobs",))
_M_JOB_COALESCED = _REGISTRY.counter(
    "repro_jobs_coalesced_total",
    "Submissions that rode a queued primary via batch_key",
    labels=("jobs",))
_M_JOB_DEPTH = _REGISTRY.gauge(
    "repro_jobs_queue_depth", "Queued + running jobs", labels=("jobs",))


class QueueFull(Exception):
    """The job queue is at capacity; mapped to HTTP 503."""
    status = 503


class UnknownJob(KeyError):
    """No such job id (or its result already expired); HTTP 404."""
    status = 404


class Job:
    __slots__ = ("id", "kind", "tenant", "status", "result", "error",
                 "submitted_at", "started_at", "finished_at",
                 "batch_key", "followers")

    def __init__(self, kind: str, tenant: str, clock=time.monotonic):
        self.id = secrets.token_hex(8)
        self.kind = kind
        self.tenant = tenant
        self.status = "queued"          # queued | running | done | failed
        self.result = None
        self.error: Optional[str] = None
        self.submitted_at = clock()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.batch_key: Optional[str] = None
        # jobs coalesced onto this one while it was queued: they share
        # its execution and receive copies of its result/status
        self.followers: list = []

    def describe(self) -> dict:
        out = {"job": self.id, "kind": self.kind, "tenant": self.tenant,
               "status": self.status}
        if self.error is not None:
            out["error"] = self.error
        return out


class JobQueue:
    """Bounded worker threads draining a FIFO of analytics jobs."""

    def __init__(self, n_workers: int = 2, max_queued: int = 64,
                 result_ttl: float = 600.0, clock=time.monotonic):
        self.max_queued = max_queued
        self.result_ttl = result_ttl
        self.clock = clock
        self._q: "queue.Queue" = queue.Queue()
        self._jobs: Dict[str, Job] = {}
        self._coalesce: Dict[str, Job] = {}     # batch_key → queued primary
        self._lock = threading.Lock()
        self.metrics_label = _obj_label("jobs")
        lab = dict(jobs=self.metrics_label)
        self._m_submitted = _M_SUBMITTED.labels(**lab)
        self._m_completed = _M_COMPLETED.labels(**lab)
        self._m_failed = _M_FAILED.labels(**lab)
        self._m_coalesced = _M_JOB_COALESCED.labels(**lab)
        self._m_depth = _M_JOB_DEPTH.labels(**lab)
        ref = weakref.ref(self)
        self._m_depth.set_function(lambda: ref().live_jobs)
        self._closed = threading.Event()
        self._workers = [
            threading.Thread(target=self._work, name=f"gateway-job/{i}",
                             daemon=True)
            for i in range(max(n_workers, 1))]
        for w in self._workers:
            w.start()

    @property
    def n_coalesced(self) -> int:
        """Registry-backed compat shape for the pre-obs attribute."""
        return self._m_coalesced.value

    @property
    def live_jobs(self) -> int:
        with self._lock:
            return sum(1 for j in self._jobs.values()
                       if j.status in ("queued", "running"))

    # -- submission / polling ----------------------------------------------
    def submit(self, kind: str, fn: Callable[[], dict],
               tenant: Tenant, batch_key: Optional[str] = None) -> Job:
        """Enqueue ``fn``; raises :class:`QueueFull` when the global or
        per-tenant bound is hit.

        With a ``batch_key``, identical work coalesces per queue drain:
        if a job with the same key is still *queued*, the new submission
        becomes a follower — its own :class:`Job` id (per-tenant bounds
        still apply), but no second execution; the worker copies the
        primary's result/status to every follower when it finishes.
        Running or finished jobs never absorb followers (their snapshot
        may predate the new request's writes).
        """
        with self._lock:
            self._sweep_locked()
            live = [j for j in self._jobs.values()
                    if j.status in ("queued", "running")]
            if len(live) >= self.max_queued:
                raise QueueFull(f"job queue full ({self.max_queued} live)")
            mine = sum(1 for j in live if j.tenant == tenant.name)
            if mine >= tenant.max_jobs:
                raise QueueFull(
                    f"tenant {tenant.name!r} at its job bound "
                    f"({tenant.max_jobs})")
            job = Job(kind, tenant.name, clock=self.clock)
            self._jobs[job.id] = job
            if batch_key is not None:
                primary = self._coalesce.get(batch_key)
                if primary is not None and primary.status == "queued":
                    primary.followers.append(job)
                    self._m_coalesced.inc()
                    self._m_submitted.inc()
                    return job          # rides the primary's execution
                job.batch_key = batch_key
                self._coalesce[batch_key] = job
        self._m_submitted.inc()
        self._q.put((job, fn))
        return job

    def get(self, job_id: str) -> Job:
        with self._lock:
            self._sweep_locked()
            job = self._jobs.get(job_id)
        if job is None:
            raise UnknownJob(job_id)
        return job

    def _sweep_locked(self) -> None:
        now = self.clock()
        dead = [jid for jid, j in self._jobs.items()
                if j.finished_at is not None
                and now - j.finished_at > self.result_ttl]
        for jid in dead:
            del self._jobs[jid]

    # -- execution ---------------------------------------------------------
    def _work(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            job, fn = item
            with self._lock:
                # the drain point: no further followers may attach —
                # later identical submissions start a fresh primary
                if job.batch_key is not None:
                    self._coalesce.pop(job.batch_key, None)
                group = [job] + job.followers
            if self._closed.is_set():
                for j in group:
                    j.status = "failed"
                    j.error = "gateway shutting down"
                    j.finished_at = self.clock()
                self._m_failed.inc(len(group))
                continue
            for j in group:
                j.status = "running"
                j.started_at = self.clock()
            try:
                result = fn()
                for j in group:
                    j.result = result
                    j.status = "done"
                self._m_completed.inc(len(group))
            except Exception as e:      # surfaced via the status poll
                for j in group:
                    j.error = f"{type(e).__name__}: {e}"
                    j.status = "failed"
                self._m_failed.inc(len(group))
            finally:
                now = self.clock()
                for j in group:
                    j.finished_at = now

    def close(self) -> None:
        """Stop the workers; queued-but-unstarted jobs fail fast."""
        self._closed.set()
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join(timeout=5)

    def stats(self) -> dict:
        with self._lock:
            by_status: Dict[str, int] = {}
            for j in self._jobs.values():
                by_status[j.status] = by_status.get(j.status, 0) + 1
        return {"by_status": by_status, "n_workers": len(self._workers),
                "max_queued": self.max_queued,
                "n_coalesced": self.n_coalesced}
