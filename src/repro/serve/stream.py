"""Live SSE event streams — stats ticks and streaming-detector alerts.

:class:`EventPublisher` is the shared fan-out core: a bounded replay
buffer plus a condition variable that N subscribers wait on, so N open
``/v1/stream/*`` responses cost one producer, not N pollers hammering
the counters.  Server-Sent Events is the transport (stdlib-friendly: a
long-lived ``text/event-stream`` response of ``data: <json>`` frames),
matching the no-new-deps framing style of the netstore: a browser
``EventSource``, ``curl``, or the test suite's ``http.client`` all
consume it directly.

Two producers ride it:

* :class:`StatsPublisher` — a sampler thread polls the table's merged
  ``stats()`` snapshot (a read-mostly counter read — no barriers, no
  scans, no RPCs) every ``interval`` seconds and publishes *windowed
  deltas*: rows written and cache hits/misses in the last window, the
  cache's trailing write rate, writer queue depth.
* :class:`AlertPublisher` — push-driven: registered as a
  ``DetectorBank`` alert callback, it publishes each
  :class:`~repro.stream.detectors.AlertReport` the moment the detector
  pass raises it (``GET /v1/stream/alerts``).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Iterator, Optional


class EventPublisher:
    """Bounded-replay event fan-out: producers call :meth:`publish`,
    subscribers iterate :meth:`events` for SSE frames.  ``history``
    events are retained so a new subscriber can replay recent ones
    (``?replay=N``)."""

    def __init__(self, history: int = 120):
        self._samples: deque = deque(maxlen=history)
        self._cond = threading.Condition()
        self._seq = 0
        self._stopped = threading.Event()

    def publish(self, sample: dict) -> None:
        with self._cond:
            self._seq += 1
            self._samples.append((self._seq, sample))
            self._cond.notify_all()

    # -- subscription ------------------------------------------------------
    def events(self, max_events: Optional[int] = None,
               replay: int = 0, timeout: float = 30.0) -> Iterator[bytes]:
        """Yield SSE frames (``data: <json>\\n\\n`` as bytes).  Stops
        after ``max_events`` frames (None = until :meth:`close`), or
        after ``timeout`` seconds pass with no new event — a dead
        producer must not pin response threads forever."""
        sent = 0
        with self._cond:
            backlog = list(self._samples)[-replay:] if replay > 0 else []
            last_seq = self._seq if not backlog else backlog[0][0] - 1
        for seq, sample in backlog:
            yield self._frame(sample)
            last_seq = seq
            sent += 1
            if max_events is not None and sent >= max_events:
                return
        while not self._stopped.is_set():
            with self._cond:
                if self._seq <= last_seq and \
                        not self._cond.wait(timeout=timeout):
                    return              # producer stalled; end the stream
                fresh = [(s, x) for s, x in self._samples if s > last_seq]
            for seq, sample in fresh:
                yield self._frame(sample)
                last_seq = seq
                sent += 1
                if max_events is not None and sent >= max_events:
                    return

    @staticmethod
    def _frame(sample: dict) -> bytes:
        return f"data: {json.dumps(sample)}\n\n".encode()

    def latest(self) -> Optional[dict]:
        with self._cond:
            return self._samples[-1][1] if self._samples else None

    def close(self) -> None:
        self._stopped.set()
        with self._cond:
            self._cond.notify_all()


class StatsPublisher(EventPublisher):
    """Samples ``table.stats()`` on a timer; fans ticks out to SSE
    subscribers (``GET /v1/stream/stats?replay=N``)."""

    def __init__(self, table, interval: float = 1.0, history: int = 120):
        super().__init__(history=history)
        self.table = table
        self.interval = interval
        self._prev: Optional[dict] = None
        self._thread = threading.Thread(
            target=self._run, name="gateway-stats", daemon=True)
        self._thread.start()

    # -- sampling ----------------------------------------------------------
    def _run(self) -> None:
        while not self._stopped.wait(self.interval):
            self._tick()

    def _tick(self) -> dict:
        snap = self.table.stats()
        prev = self._prev or snap
        self._prev = snap
        w, pw = snap["writers"], prev["writers"]
        c, pc = snap["cache"], prev["cache"]
        sample = {
            "t": round(time.time(), 3),
            "interval_s": self.interval,
            "rows_written_window": w["n_written"] - pw["n_written"],
            "writes_per_s": round(c["writes_per_s"], 3),
            "queue_depth": w["queue_depth"],
            "pending_rows": w["pending"],
            "n_retried": w["n_retried"],
            "cache_hits_window": c["hits"] - pc["hits"],
            "cache_misses_window": c["misses"] - pc["misses"],
            "cache_entries": c["entries"],
            "admission_skips": c["admission_skips"],
            "n_entries_written_total": w["n_written"],
        }
        self.publish(sample)
        return sample

    def close(self) -> None:
        super().close()
        self._thread.join(timeout=5)


class AlertPublisher(EventPublisher):
    """Push-driven alert feed: hand :meth:`on_alert` to a
    ``DetectorBank``/``StreamAnalytics`` callback slot and every alert
    becomes an SSE frame on ``/v1/stream/alerts``."""

    def __init__(self, history: int = 256):
        super().__init__(history=history)

    def on_alert(self, alert) -> None:
        """DetectorBank callback — ``alert`` is an AlertReport."""
        self.publish(alert.to_dict())
