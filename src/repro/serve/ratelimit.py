"""Per-tenant token buckets and scan admission control.

Two distinct "no" signals, deliberately kept separate:

* **rate limiting** (:class:`RateLimiter`) — *per-tenant* budget
  enforcement.  Each tenant gets a token bucket sized by its
  :class:`~repro.serve.auth.Tenant` ``rate``/``burst``; route costs are
  weighted (a C2 sweep debits more than a degree lookup).  Exceeding the
  budget raises :class:`RateLimited` → HTTP 429 with ``Retry-After`` set
  to when the bucket next covers the request.  One tenant's rejections
  never touch another tenant's bucket — the isolation property
  ``tests/test_gateway.py`` asserts under concurrent load.

* **admission control** — *cluster-state* backpressure, tenant-blind.
  Full-table work is refused while the trailing write rate exceeds the
  scan cache's ``full_scan_wps_limit``
  (:meth:`repro.db.binding.DBTable.admit_full_scan`): the scan would be
  stale before finishing and its cache entry evicted by the next write.
  Also 429, with a ``Retry-After`` of the cache's sampling window.

Buckets are continuous-refill (no background timer thread): each
``acquire`` settles elapsed time into the balance under the bucket lock.
"""
from __future__ import annotations

import threading
import time
from typing import Dict

from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from .auth import Tenant

_M_ALLOWED = _REGISTRY.counter(
    "repro_ratelimit_allowed_total", "Requests admitted by token buckets",
    labels=("limiter",))
_M_REJECTED = _REGISTRY.counter(
    "repro_ratelimit_rejected_total",
    "Requests rejected over budget (HTTP 429)", labels=("limiter",))


class RateLimited(Exception):
    """Budget exceeded; the gateway maps this to 429 + Retry-After."""
    status = 429

    def __init__(self, message: str, retry_after: float):
        super().__init__(message)
        self.retry_after = retry_after


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` cap."""

    def __init__(self, rate: float, burst: float, clock=time.monotonic):
        self.rate = max(rate, 1e-9)
        self.burst = max(burst, 1e-9)
        self.clock = clock
        self._tokens = self.burst
        self._stamp = clock()
        self._lock = threading.Lock()

    def try_acquire(self, cost: float = 1.0) -> float:
        """Debit ``cost`` tokens.  Returns 0.0 on success, else the
        seconds until the bucket will cover the request (the caller's
        ``Retry-After``).  A cost above ``burst`` can never succeed —
        reported as the time to fill the whole bucket."""
        now = self.clock()
        with self._lock:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._stamp) * self.rate)
            self._stamp = now
            if self._tokens >= cost:
                self._tokens -= cost
                return 0.0
            return (min(cost, self.burst) - self._tokens) / self.rate

    @property
    def tokens(self) -> float:
        with self._lock:
            now = self.clock()
            return min(self.burst,
                       self._tokens + (now - self._stamp) * self.rate)


class RateLimiter:
    """One bucket per tenant, created lazily from the tenant's budgets."""

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = threading.Lock()
        self.metrics_label = _obj_label("limiter")
        self._m_allowed = _M_ALLOWED.labels(limiter=self.metrics_label)
        self._m_rejected = _M_REJECTED.labels(limiter=self.metrics_label)

    # registry-backed counter reads (compat: pre-obs attribute shapes)
    @property
    def n_allowed(self) -> int:
        return self._m_allowed.value

    @property
    def n_rejected(self) -> int:
        return self._m_rejected.value

    def _bucket(self, tenant: Tenant) -> TokenBucket:
        b = self._buckets.get(tenant.name)
        if b is None:
            with self._lock:
                b = self._buckets.get(tenant.name)
                if b is None:
                    b = TokenBucket(tenant.rate, tenant.burst,
                                    clock=self.clock)
                    self._buckets[tenant.name] = b
        return b

    def acquire(self, tenant: Tenant, cost: float = 1.0) -> None:
        retry = self._bucket(tenant).try_acquire(cost)
        if retry > 0.0:
            self._m_rejected.inc()
            raise RateLimited(
                f"tenant {tenant.name!r} over budget "
                f"(rate={tenant.rate:g}/s, cost={cost:g})", retry)
        self._m_allowed.inc()

    def stats(self) -> dict:
        return {"n_allowed": self.n_allowed, "n_rejected": self.n_rejected,
                "tenants": sorted(self._buckets)}
