"""Tenant authentication for the serving gateway.

Bearer-token auth against a static tenant registry — the operational
model of a shared analytics cluster: operators mint one token per tenant
(an analyst team, a dashboard, an ingest monitor) and attach a rate
budget to it.  Stdlib only; tokens compare with
:func:`hmac.compare_digest` so lookup time never leaks prefix matches.

No token refresh or asymmetric signing here on purpose: the gateway sits
behind the cluster perimeter (same trust domain as the shard servers,
which speak an unauthenticated framed protocol); the token's job is
*tenancy attribution* for rate limiting and auditing, not cryptographic
identity.
"""
from __future__ import annotations

import dataclasses
import hmac
from typing import Dict, Iterable, Optional


class AuthError(Exception):
    """Missing/unknown credentials; the gateway maps this to HTTP 401."""
    status = 401


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One tenant's identity and budgets.

    ``rate``/``burst`` parameterize the tenant's token bucket
    (requests/s sustained, instantaneous burst); ``max_jobs`` bounds the
    tenant's concurrently *queued or running* background jobs.  Route
    costs are weighted, so ``rate=5`` sustains 5 cheap queries/s but
    fewer heavy scans (see ``repro.serve.routes``).
    """
    name: str
    rate: float = 10.0
    burst: float = 20.0
    max_jobs: int = 4


class TokenAuth:
    """Static token → :class:`Tenant` registry.

    ``tokens`` maps each secret token to a :class:`Tenant` (or a bare
    tenant name, which gets default budgets).  ``authenticate`` accepts
    the ``Authorization`` header value — ``Bearer <token>`` or the raw
    token — and returns the tenant or raises :class:`AuthError`.
    """

    def __init__(self, tokens: Dict[str, "Tenant | str"]):
        self._tenants: Dict[str, Tenant] = {}
        for tok, tenant in tokens.items():
            if isinstance(tenant, str):
                tenant = Tenant(tenant)
            self._tenants[tok] = tenant

    def authenticate(self, authorization: Optional[str]) -> Tenant:
        if not authorization:
            raise AuthError("missing Authorization header")
        token = authorization.strip()
        if token.lower().startswith("bearer "):
            token = token[7:].strip()
        # constant-shape scan: compare against every registered token so
        # timing doesn't reveal whether a prefix matched
        found = None
        for known, tenant in self._tenants.items():
            if hmac.compare_digest(token, known):
                found = tenant
        if found is None:
            raise AuthError("unknown token")
        return found

    @property
    def tenants(self) -> Iterable[Tenant]:
        return list(self._tenants.values())

    @classmethod
    def from_specs(cls, specs: Iterable[str]) -> "TokenAuth":
        """Build from CLI specs ``token:tenant[:rate[:burst]]`` — e.g.
        ``--token s3cret:analytics:50:100``."""
        tokens: Dict[str, Tenant] = {}
        for spec in specs:
            parts = spec.split(":")
            if len(parts) < 2:
                raise ValueError(
                    f"bad token spec {spec!r}: want token:tenant[:rate[:burst]]")
            tok, name = parts[0], parts[1]
            rate = float(parts[2]) if len(parts) > 2 else 10.0
            burst = float(parts[3]) if len(parts) > 3 else max(2 * rate, 1.0)
            tokens[tok] = Tenant(name, rate=rate, burst=burst)
        return cls(tokens)
