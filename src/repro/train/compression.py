"""Quantized cross-pod gradient reduction (shard_map explicit collective).

Under pjit, gradient reductions are XLA-inserted and their dtype follows
the gradient dtype (the ``grad_dtype="bfloat16"`` knob).  Going below
bf16 needs an *explicit* collective — int8 values summed in int8 would
overflow, so the compressed reduction quantizes per-leaf against a
psum-shared absmax, accumulates in int32, and dequantizes:

    scale = psum_max(|g|) / 127
    g_hat = dequant( psum( round(g / scale) : int32 ) ) / n_pods

Wire bytes per hop: 1 B/element (plus one scalar) — 4× less than f32,
2× less than bf16.  Quantization error is bounded by scale/2 per pod
(tested).  Intended for the DCN ``pod`` axis where bandwidth is ~8×
scarcer than ICI; apply via ``compressed_pod_mean`` inside a shard_map
region that owns the pod axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quantized_mean(g: jax.Array, axis: str) -> jax.Array:
    """Mean of ``g`` across ``axis`` with int8 wire format."""
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32)


def compressed_pod_mean(grads, mesh: Mesh, axis: str = "pod"):
    """Average a gradient pytree across the pod axis in int8.

    Leaves must be replicated (or identically sharded) along ``axis``;
    other mesh axes pass through untouched.
    """
    if axis not in mesh.axis_names:
        return grads

    def one(g):
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=P(*(None,) * g.ndim),
            out_specs=P(*(None,) * g.ndim),
            check_rep=False)
        def _reduce(x):
            return _quantized_mean(x, axis)
        return _reduce(g)

    return jax.tree.map(one, grads)
