"""Sharding rules: parameter/activation PartitionSpecs over the mesh.

Mesh axes (see launch/mesh.py):
* ``pod``   — data-parallel across pods (DCN); gradients cross it once
  per step (reduce-scatter/all-gather pair).
* ``data``  — FSDP within a pod: parameters sharded at rest on one axis,
  all-gathered at use; batch sharded here too.
* ``model`` — tensor parallel: attention heads / FFN hidden / MoE experts
  / vocab.

Rules are keyed on parameter leaf names; stacked layer dims (from the
scan grouping) are detected by ndim and get a leading ``None``.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name → spec for the *parameter's own* dims (no layer stacking).
# convention: ("fsdp", "tp") where fsdp="data", tp="model".
_RULES: dict[str, tuple] = {
    # embedding / head
    "embed": ("model", "data"),          # (V, D): vocab TP, d FSDP
    "head": ("data", "model"),           # (D, V)
    "img_proj": ("data", "model"),
    # attention
    "wq": ("data", "model"),             # (D, H·Dh): heads TP
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),             # (H·Dh, D)
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # dense mlp
    "w_gate": ("data", "model"),         # (D, F)
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),         # (F, D)
    # moe (expert dim first) — overridden by ndim check below
    "router": ("data", None),            # (D, E) router replicated on E
    # rglru
    "wx": ("data", "model"), "wg": ("data", "model"),
    "conv_k": (None, "model"), "conv_b": ("model",),
    "wa": ("model", None), "wi": ("model", None),
    "lam": ("model",),
    # rwkv
    "wr": ("data", "model"), "wgate": ("data", "model"),
    "dw_a": ("data", None), "dw_b": (None, "data"),
    "dw_bias": (None,), "u": (None, None), "mu": (None, None),
    "mu_c": (None, None),
    "ck": ("data", "model"), "cv": ("model", "data"),
    "cr": ("data", "model"),
    # norms
    "ln": (None,), "ln1": (None,), "ln2": (None,), "ln_x": (None,),
    "final_norm": (None,),
}

# MoE expert tensors: (E, D, F) / (E, F, D).  Expert-parallel over model
# when E divides the axis; otherwise hybrid: experts replicated, the
# expert FFN hidden dim tensor-parallel (granite: 40 experts on tp=16).
_MOE_3D = {
    "w_gate": (("model", "data", None), (None, "data", "model")),
    "w_up": (("model", "data", None), (None, "data", "model")),
    "w_down": (("model", None, "data"), (None, "model", "data")),
}


def _leaf_spec(name: str, leaf, moe_ctx: bool, tp: int = 1,
               fsdp: int = 0) -> P:
    base: Optional[tuple] = None
    if moe_ctx and name in _MOE_3D:
        ep, hybrid = _MOE_3D[name]
        n_experts = leaf.shape[-3]
        base = ep if n_experts % tp == 0 else hybrid
    elif name in _RULES:
        base = _RULES[name]
    ndim = leaf.ndim
    if base is None:
        base = (None,) * ndim
    extra = ndim - len(base)          # leading stacked-layer dims → None
    if extra < 0:
        base = base[-ndim:] if ndim else ()
        extra = 0
    spec = list((None,) * extra + tuple(base))
    # drop any axis that doesn't divide the dim (vocab remainders etc.)
    for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
        if ax == "model" and dim % tp != 0:
            spec[i] = None
        if ax == "data" and fsdp and dim % fsdp != 0:
            spec[i] = None
    return P(*spec)


def _zero3_spec(leaf, n_total: int) -> P:
    """ZeRO-3 profile: shard the largest divisible dim over ALL mesh
    axes combined; everything else replicated.  No tensor parallelism —
    the right scheme for small-dense models where TP all-reduces dwarf
    the matmuls (§Perf, h2o-danube hillclimb)."""
    if leaf.ndim == 0:
        return P()
    dims = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
    for i in dims:
        if leaf.shape[i] % n_total == 0:
            spec = [None] * leaf.ndim
            spec[i] = "__all__"        # resolved to the caller's axis tuple
            return P(*spec)
    return P(*([None] * leaf.ndim))


def _with_pod_fsdp(spec: P, mesh) -> P:
    """Map the FSDP axis "data" → ("pod", "data"): parameters shard
    across pods too (DCN-FSDP), halving at-rest param/optimizer memory
    per pod at the cost of cross-pod gathers (the qwen3-235B memory
    answer, §Perf)."""
    if "pod" not in mesh.axis_names:
        return spec
    return P(*[("pod", "data") if ax == "data" else ax for ax in spec])


def param_specs(params, mesh: Optional[Mesh] = None,
                profile: str = "2d") -> dict:
    """PartitionSpec pytree matching ``params`` (works on abstract trees).

    Walks the tree structurally: a dict containing a ``router`` key is a
    MoE block, so its expert tensors (w_gate/w_up/w_down with a leading
    expert dim) take the expert-parallel rules — this disambiguates them
    from scan-stacked dense MLP tensors of the same name and rank.

    ``profile="zero3"``: ignore the TP rules and shard every parameter
    over all mesh axes combined (pure FSDP / ZeRO-3).
    """
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    fsdp = mesh.shape.get("data", 1) if mesh is not None else 1

    if profile == "zero3":
        axes = tuple(mesh.axis_names)
        n_total = int(np.prod([mesh.shape[a] for a in axes]))

        def z(node, name=""):
            if isinstance(node, dict):
                return {k: z(v, k) for k, v in node.items()}
            spec = _zero3_spec(node, n_total)
            return P(*[axes if s == "__all__" else s for s in spec])
        return z(params)

    pod = mesh.shape.get("pod", 1) if mesh is not None else 1

    def walk2(node, name="", moe_ctx=False):
        if isinstance(node, dict):
            is_moe = "router" in node
            return {k: walk2(v, k, is_moe or moe_ctx)
                    for k, v in node.items()}
        spec = _leaf_spec(name, node, moe_ctx, tp=tp,
                          fsdp=fsdp * pod if profile == "2d_podfsdp"
                          else fsdp)
        if profile == "2d_podfsdp" and mesh is not None:
            spec = _with_pod_fsdp(spec, mesh)
        return spec

    return walk2(params)


def param_shardings(params, mesh: Mesh, profile: str = "2d"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, profile))


def batch_axes(mesh: Mesh, profile: str = "2d"):
    """The mesh-axis name(s) the batch dim shards over (pod × data;
    zero3: every axis — the whole mesh is data-parallel)."""
    if profile == "zero3":
        return tuple(mesh.axis_names)
    axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


# kept for callers that want a full P for a rank-1 batch-dim tensor
def batch_spec(mesh: Mesh):
    return batch_axes(mesh)


def batch_shardings(batch_like, mesh: Mesh, profile: str = "2d"):
    ba = batch_axes(mesh, profile)
    n_data = 1
    for a in (ba if isinstance(ba, tuple) else (ba,)):
        n_data *= mesh.shape[a]

    def spec(x):
        # small batches (e.g. long_500k B=1) replicate across data axes
        axis = ba if x.shape[0] % n_data == 0 else None
        return NamedSharding(mesh, P(axis, *(None,) * (x.ndim - 1)))
    return jax.tree.map(spec, batch_like)


def activation_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh), None, None)


def cache_shardings(cache_like, mesh: Mesh):
    """Decode caches: batch over (pod, data); KV-head / channel axes over
    model; grouped caches carry a leading layer-stack dim (replicated).

    Built structurally from the cache tree's types (AttnCache /
    RGLRUCache / RWKVCache), so it works on eval_shape output too.
    """
    from ..models import blocks as B
    ba = batch_axes(mesh)
    n_data = 1
    for a in (ba if isinstance(ba, tuple) else (ba,)):
        n_data *= mesh.shape[a]

    def named(*spec):
        return NamedSharding(mesh, P(*spec))

    def bspec(c, lead):
        """Batch axis spec — replicate when B doesn't tile (long_500k)."""
        b_dim = jax.tree.leaves(c)[0].shape[len(lead)]
        return ba if b_dim % n_data == 0 else None

    def attn(c, lead):
        bs = bspec(c, lead)
        tp = mesh.shape.get("model", 1)
        kv, dh = c.k.shape[-2], c.k.shape[-1]
        if kv % tp == 0:            # GQA: shard KV heads
            kspec = (None, "model", None)
        elif dh % tp == 0:          # MQA: shard head_dim instead
            kspec = (None, None, "model")
        else:
            kspec = (None, None, None)
        return B.AttnCache(
            k=named(*lead, bs, *kspec),
            v=named(*lead, bs, *kspec),
            pos=named(*lead, bs, None),
            index=named(*lead))

    def rglru(c, lead):
        bs = bspec(c, lead)
        return B.RGLRUCache(h=named(*lead, bs, "model"),
                            conv=named(*lead, bs, None, "model"))

    def rwkv(c, lead):
        bs = bspec(c, lead)
        return B.RWKVCache(wkv=named(*lead, bs, "model", None, None),
                           shift1=named(*lead, bs, None),
                           shift2=named(*lead, bs, None))

    def one(c, stacked):
        lead = (None,) if stacked else ()
        if isinstance(c, B.AttnCache):
            return attn(c, lead)
        if isinstance(c, B.RGLRUCache):
            return rglru(c, lead)
        if isinstance(c, B.RWKVCache):
            return rwkv(c, lead)
        raise TypeError(type(c))

    out: dict = {}
    if "groups" in cache_like:
        out["groups"] = {k: one(v, True)
                         for k, v in cache_like["groups"].items()}
    if "tail" in cache_like:
        out["tail"] = {k: one(v, False)
                       for k, v in cache_like["tail"].items()}
    return out
