"""AdamW (sharded) + distributed-optimization knobs.

States inherit the parameters' FSDP sharding automatically (same tree
structure ⇒ same PartitionSpecs).  Two collective-term optimizations are
first-class and measured in EXPERIMENTS.md §Perf:

* ``grad_dtype="bfloat16"`` — casts gradients before the (XLA-inserted)
  cross-replica reduction: halves all-reduce bytes on the wire.
* ``grad_accum`` — micro-batching: k sequential grad evaluations per
  update amortize the parameter all-gather/grad-reduce over k× compute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    grad_dtype: Optional[str] = None     # "bfloat16" halves reduce bytes
    gather_dtype: Optional[str] = None   # "bfloat16" halves FSDP gathers
    grad_accum: int = 1


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step.astype(jnp.float32) /
                       jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = _schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p)
        return p, m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
