"""train_step / serve-step factories with sharding constraints.

``make_train_step`` builds the jit-able update: loss → grad →
(optional bf16 grad cast, the §Perf collective optimization) → AdamW.
Gradient accumulation runs micro-batches under ``lax.scan`` so the
lowered HLO contains one fused update per optimizer step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models.config import ModelConfig
from . import sharding as S
from .optimizer import OptConfig, adamw_init, adamw_update


def make_loss_fn(cfg: ModelConfig, mesh=None, opt: Optional[OptConfig] = None,
                 profile: str = "2d"):
    from ..models.shard_ctx import activation_sharding

    def loss_fn(params, batch):
        if opt is not None and opt.gather_dtype:
            # cast the f32 master shards BEFORE use: the FSDP all-gather
            # then moves bf16 — halves gather wire bytes (§Perf)
            gd = jnp.dtype(opt.gather_dtype)
            params = jax.tree.map(
                lambda p: p.astype(gd) if p.dtype == jnp.float32 else p,
                params)
        if mesh is not None:
            ba = S.batch_axes(mesh, profile)
            batch = {k: jax.lax.with_sharding_constraint(
                v, NamedSharding(mesh, P(ba, *(None,) * (v.ndim - 1))))
                for k, v in batch.items()}
        with activation_sharding(mesh, profile):
            return M.loss_fn(params, batch, cfg)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig, mesh=None,
                    profile: str = "2d"):
    loss_fn = make_loss_fn(cfg, mesh, opt, profile)

    def train_step(params, opt_state, batch):
        if opt.grad_accum > 1:
            # micro-batch over the leading batch axis
            def micro(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_sum + l,
                        jax.tree.map(jnp.add, gsum, g)), None

            def split(x):
                b = x.shape[0]
                k = opt.grad_accum
                return x.reshape(k, b // k, *x.shape[1:])
            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zeros), mbs)
            loss = loss / opt.grad_accum
            grads = jax.tree.map(lambda g: g / opt.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if opt.grad_dtype:
            # cast before the cross-replica reduction — halves the wire
            # bytes of the gradient all-reduce (§Perf)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(opt.grad_dtype)), grads)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, s_max: int, mesh=None):
    from ..models.shard_ctx import activation_sharding

    def prefill_step(params, batch):
        with activation_sharding(mesh):
            return M.prefill(params, batch, cfg, s_max=s_max)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None):
    from ..models.shard_ctx import activation_sharding

    def decode_step(params, caches, batch):
        with activation_sharding(mesh):
            return M.decode_step(params, caches, batch, cfg)
    return decode_step


def init_train_state(cfg: ModelConfig, key):
    params = M.init_params(cfg, key)
    return params, adamw_init(params)


def abstract_train_state(cfg: ModelConfig):
    """ShapeDtypeStruct (params, opt_state) — dry-run path, no allocation."""
    params = M.abstract_params(cfg)
    opt_state = jax.eval_shape(adamw_init, params)
    return params, opt_state
