from . import sharding
from .compression import compressed_pod_mean
from .optimizer import OptConfig, adamw_init, adamw_update
from .trainer import (abstract_train_state, init_train_state,
                      make_decode_step, make_loss_fn, make_prefill_step,
                      make_train_step)

__all__ = [
    "sharding", "compressed_pod_mean", "OptConfig", "adamw_init", "adamw_update",
    "make_train_step", "make_loss_fn", "make_prefill_step",
    "make_decode_step", "init_train_state", "abstract_train_state",
]
