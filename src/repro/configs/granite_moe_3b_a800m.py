"""granite-moe-3b-a800m — fine-grained MoE, top-8 routing.

[hf:ibm-granite/granite-3.0-1b-a400m-base (family); hf]
32L d_model=1536 24H (GQA kv=8) vocab=49155; MoE 40 experts top-8 with
d_expert=512 (the assignment lists both "40e" and "32 experts"; we take
the explicit 40e field and note the discrepancy in DESIGN.md).
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    pattern="A", tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
    # H=24 doesn't divide tp=16 → pad to 32 physical heads (masked;
    # math exactly the 24-head model — see launch/calibrate.py)
    head_pad=32,
)
