"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 (channel mix)
vocab=65536; 32 heads of dim 64 for the wkv state.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    pattern="W",
)
