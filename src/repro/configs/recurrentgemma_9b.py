"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000; block period (R, R, L) with window 2048.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000,
    pattern="RRL", window=2048,
    rope_theta=10_000.0, logit_softcap=30.0,
    tie_embeddings=True,          # Gemma family ties embeddings
)
