"""Architecture registry: ``get_config(arch)`` / ``smoke_config(arch)``.

One module per assigned architecture (exact public configs, sources in
each file); ``smoke_config`` returns a reduced same-family config for
CPU smoke tests (small dims, few layers/experts — full configs are only
exercised abstractly via the dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig, MoEConfig

ARCHS = (
    "recurrentgemma_9b",
    "h2o_danube_1_8b",
    "qwen2_5_14b",
    "phi3_mini_3_8b",
    "internlm2_20b",
    "whisper_large_v3",
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "rwkv6_1_6b",
    "phi_3_vision_4_2b",
)

# accept dashed ids from the assignment table too
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "recurrentgemma-9b": "recurrentgemma_9b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2.5-14b": "qwen2_5_14b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "internlm2-20b": "internlm2_20b",
    "whisper-large-v3": "whisper_large_v3",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
})


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(arch)}", __package__)
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: ~1M params, CPU-friendly."""
    cfg = get_config(arch)
    n_layers = max(2 * len(cfg.pattern) + (1 if len(cfg.pattern) > 1 else 0),
                   2)
    moe = None
    if cfg.moe is not None:
        # ample capacity: capacity drops are data-dependent and would
        # desynchronize teacher-forcing vs decode in consistency tests
        moe = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                        capacity_factor=4.0, router=cfg.moe.router)
    kv = max(1, 4 * cfg.n_kv_heads // cfg.n_heads)
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64, n_heads=4, n_kv_heads=kv,
        head_dim=16, d_ff=128, vocab=256, moe=moe, window=16,
        encoder_layers=2 if cfg.is_encdec else 0, encoder_seq=24,
        n_img_tokens=8, d_rnn=64, decay_lora=8, attention_chunk=16,
        head_pad=0, kv_pad=0,
        rwkv_chunk=8, dtype="float32")
