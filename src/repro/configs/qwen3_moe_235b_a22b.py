"""qwen3-moe-235b-a22b — 128-expert MoE, top-8, GQA kv=4, head_dim 128.

[hf:Qwen/Qwen3-30B-A3B (family); hf]  94L d_model=4096 64H (GQA kv=4)
vocab=151936; MoE 128 experts top-8, d_expert=1536.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    pattern="A", rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
)
