"""phi-3-vision-4.2b — phi3-mini backbone + CLIP patch-embedding stub.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d_model=3072 32H
(kv=32) d_ff=8192 vocab=32064; 576 image-prefix tokens supplied as
precomputed patch embeddings (CLIP frontend is a stub per assignment).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    pattern="A", frontend="vision", n_img_tokens=576,
)
