"""whisper-large-v3 — encoder-decoder audio backbone (conv frontend stub).

[arXiv:2212.04356; unverified]  32 encoder + 32 decoder layers,
d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866; encoder consumes
1500 precomputed frame embeddings (the conv frontend is a stub per the
assignment).  Adaptations: RoPE replaces whisper's learned positions
(documented in DESIGN.md) which also defines decode_32k extrapolation.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    pattern="A",
    encoder_layers=32, encoder_seq=1500,
    cross_attention=True, frontend="audio",
    # H=20 doesn't divide tp=16 → pad to 32 physical heads (outputs of
    # padded heads hard-masked; math exactly the 20-head model). 16×
    # attention-flop replication without this (launch/calibrate.py).
    head_pad=32, kv_pad=32,
)
