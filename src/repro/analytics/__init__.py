"""repro.analytics — network analytics over associative arrays."""
from .anomaly import C2Report, C2Scores, ScanReport, c2_scores, \
    detect_c2, scan_detect, scan_hits, scan_report
from .dimensional import field_correlation, field_names, field_stats, \
    top_correlated_pairs
from .powerlaw import PowerLawFit, background_scores, degree_histogram, \
    fit_degree_table, fit_rank_size
from .serialize import to_jsonable
from . import distributed

__all__ = [
    "detect_c2", "c2_scores", "scan_detect", "scan_hits", "scan_report",
    "C2Report", "C2Scores", "ScanReport",
    "field_stats", "field_names", "field_correlation",
    "top_correlated_pairs",
    "fit_rank_size", "fit_degree_table", "degree_histogram",
    "background_scores", "PowerLawFit",
    "to_jsonable",
    "distributed",
]
