"""Device-parallel sparse analytics over the mesh (shard_map).

The paper scales its analytics with data-parallel map over files; on the
TPU mesh the same work is *device*-parallel: the incidence/adjacency
payload is row-sharded (packet/source blocks) across the ``data`` axis
and each device reduces its shard, combining with ``psum`` — degree
tables, SpMV, and PageRank become collective segment reductions.

Shards are padded to equal nnz (COO dead-entry convention: row == nrows
contributes nothing), so ``shard_map`` sees uniform blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.sparse import COO


def shard_coo(m: COO, n_shards: int) -> COO:
    """Split nnz into equal row-contiguous shards (pad with dead entries
    at row == nrows). Returns a COO whose leading dim stacks shards."""
    nnz = m.nnz
    per = -(-nnz // n_shards)
    pad = per * n_shards - nnz
    rows = jnp.pad(m.rows, (0, pad), constant_values=m.shape[0])
    cols = jnp.pad(m.cols, (0, pad))
    vals = jnp.pad(m.vals, (0, pad))
    return COO(rows.reshape(n_shards, per), cols.reshape(n_shards, per),
               vals.reshape(n_shards, per), m.shape)


def degree_sharded(m: COO, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Column degrees of a COO, nnz-sharded over ``axis`` with psum."""
    n_shards = mesh.shape[axis]
    sh = shard_coo(m, n_shards)
    n_cols = m.shape[1]
    n_rows = m.shape[0]

    spec = P(axis, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec, spec, spec),
        out_specs=P(),
        check_rep=False)
    def _deg(rows, cols, vals):
        rows, cols, vals = rows[0], cols[0], vals[0]
        live = (rows < n_rows).astype(vals.dtype)
        local = jax.ops.segment_sum(live, cols, num_segments=n_cols)
        return jax.lax.psum(local, axis)

    return _deg(sh.rows, sh.cols, sh.vals)


def spmv_t_sharded(m: COO, x: jax.Array, mesh: Mesh,
                   axis: str = "data") -> jax.Array:
    """y[j] = Σ_i m[i,j]·x[i], nnz-sharded with psum (PageRank inner op)."""
    n_shards = mesh.shape[axis]
    sh = shard_coo(m, n_shards)
    n_rows, n_cols = m.shape
    spec = P(axis, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec, spec, spec, P()),
        out_specs=P(), check_rep=False)
    def _spmv(rows, cols, vals, xv):
        rows, cols, vals = rows[0], cols[0], vals[0]
        safe = jnp.minimum(rows, n_rows - 1)
        live = (rows < n_rows).astype(vals.dtype)
        prods = vals * live * xv[safe]
        local = jax.ops.segment_sum(prods, cols, num_segments=n_cols)
        return jax.lax.psum(local, axis)

    return _spmv(sh.rows, sh.cols, sh.vals, x)


def pagerank_sharded(adj: COO, mesh: Mesh, num_iters: int = 20,
                     damping: float = 0.85, axis: str = "data",
                     personalize: jax.Array | None = None) -> jax.Array:
    """PageRank with the SpMV inner loop distributed over the mesh.

    ``personalize`` (n,) replaces the uniform restart distribution: the
    random surfer teleports to those nodes instead of anywhere, and
    dangling mass is redistributed the same way — personalized PageRank
    (the MicroRCA root-cause localization primitive)."""
    n = adj.shape[0]
    if personalize is None:
        p = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        p = jnp.maximum(personalize.astype(jnp.float32), 0.0)
        p = p / jnp.maximum(jnp.sum(p), 1e-30)
    out_deg_w = spmv_weighted_rowsum(adj, mesh, axis)
    inv_deg = jnp.where(out_deg_w > 0, 1.0 / jnp.maximum(out_deg_w, 1e-30),
                        0.0)
    rank = p
    for _ in range(num_iters):
        contrib = rank * inv_deg
        spread = spmv_t_sharded(adj, contrib, mesh, axis)
        dangling = jnp.sum(jnp.where(out_deg_w > 0, 0.0, rank))
        rank = (1 - damping) * p + damping * (spread + dangling * p)
    return rank


def pagerank_table(T, mesh: Mesh | None = None, num_iters: int = 20,
                   src_field: str = "ip.src", dst_field: str = "ip.dst",
                   sep: str = "|", axis: str = "data",
                   personalize: dict | None = None, reverse: bool = False,
                   damping: float = 0.85) -> tuple[np.ndarray, jax.Array]:
    """PageRank served straight from the database binding.

    Queries the src/dst column blocks through the :class:`DBTable`
    selection grammar (pushed-down transpose-table scans), builds the
    host adjacency, then runs the mesh-sharded PageRank on the device
    payload.  Returns ``(node_keys, ranks)`` aligned by index.

    ``T`` may equally be an in-memory incidence :class:`Assoc` (a
    streaming window slice) — anything speaking the selection grammar.
    ``personalize`` maps host keys to restart weights (personalized
    PageRank); ``reverse`` transposes the adjacency first, so mass flows
    from a seed *victim* back to the hosts feeding it traffic — the
    MicroRCA root-cause direction.
    """
    from ..core import graph

    E = T[:, f"{src_field}{sep}*,"] + T[:, f"{dst_field}{sep}*,"]
    adj = graph.square(graph.adjacency(
        E, src_field=src_field, dst_field=dst_field, sep=sep))
    if adj.nnz == 0:
        return np.empty((0,), dtype=str), jnp.zeros((0,), jnp.float32)
    if reverse:
        adj = adj.T
    p = None
    if personalize is not None:
        w = np.zeros(adj.row.shape[0], np.float32)
        pos = np.searchsorted(adj.row, list(personalize))
        for k, i in zip(personalize, pos):
            if i < adj.row.shape[0] and adj.row[i] == k:
                w[i] = float(personalize[k])
        if w.sum() <= 0:            # no seed present — uniform restart
            p = None
        else:
            p = jnp.asarray(w)
    if mesh is None:
        mesh = Mesh(np.asarray(jax.devices()), (axis,))
    ranks = pagerank_sharded(adj.device_coo(jnp.float32), mesh,
                             num_iters=num_iters, axis=axis,
                             personalize=p, damping=damping)
    return adj.row, ranks


def spmv_weighted_rowsum(m: COO, mesh: Mesh, axis: str = "data"
                         ) -> jax.Array:
    """Row sums (weighted out-degree), sharded."""
    n_shards = mesh.shape[axis]
    sh = shard_coo(m, n_shards)
    n_rows = m.shape[0]
    spec = P(axis, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec, spec, spec),
        out_specs=P(), check_rep=False)
    def _rs(rows, cols, vals):
        rows, vals = rows[0], vals[0]
        safe = jnp.minimum(rows, n_rows - 1)
        live = (rows < n_rows).astype(vals.dtype)
        local = jax.ops.segment_sum(vals * live, safe,
                                    num_segments=n_rows)
        return jax.lax.psum(local, axis)

    return _rs(sh.rows, sh.cols, sh.vals)
