"""Big-data dimensional analysis (paper ref [25]).

Field-level structural statistics over the exploded schema: per-field
cardinality, entropy, and cross-field correlation strength.  These are
the "know your data before you model it" diagnostics the paper's group
runs first on any new capture.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core.assoc import Assoc, StartsWith


def field_names(E: Assoc, sep: str = "|") -> List[str]:
    return sorted({c.split(sep, 1)[0] for c in E.col})


def field_stats(E: Assoc, sep: str = "|") -> Dict[str, dict]:
    """Cardinality + Shannon entropy per field of an incidence matrix."""
    out: Dict[str, dict] = {}
    for f in field_names(E, sep):
        block = E[:, StartsWith(f + sep)]
        counts = np.asarray(block.sum(0).triples()[2], np.float64)
        p = counts / counts.sum()
        out[f] = {
            "cardinality": int(block.shape[1]),
            "entropy_bits": float(-(p * np.log2(p)).sum()),
            "total": float(counts.sum()),
        }
    return out


def field_correlation(E: Assoc, f1: str, f2: str, sep: str = "|") -> Assoc:
    """Cross-field correlation array  E_f1' * E_f2 — e.g. which source
    talks on which port.  This is the workhorse join of the D4M style."""
    A = E[:, StartsWith(f1 + sep)]
    B = E[:, StartsWith(f2 + sep)]
    return A.T * B


def top_correlated_pairs(E: Assoc, sep: str = "|",
                         top_k: int = 5) -> List[Tuple[str, str, float]]:
    """Rank field pairs by normalized co-occurrence mass — a quick map of
    which header dimensions carry joint structure."""
    fields = field_names(E, sep)
    out = []
    for i, f1 in enumerate(fields):
        for f2 in fields[i + 1:]:
            C = field_correlation(E, f1, f2, sep)
            if C.nnz == 0:
                continue
            v = np.asarray(C.triples()[2], np.float64)
            # concentration: fraction of mass on the top cell
            out.append((f1, f2, float(v.max() / v.sum())))
    out.sort(key=lambda t: -t[2])
    return out[:top_k]
