"""Botnet / C2 detection on the ingested incidence matrix.

Three detectors, all expressed in associative-array algebra (host side)
with jit'd JAX scoring (device side) — the paper's §III-A analytic menu:

* **fan-in outliers** — unique-source in-degree far above the power-law
  background (C2 servers aggregate many bots).
* **beacon regularity** — per-destination contact pattern across time
  buckets with anomalously low coefficient-of-variation (periodic,
  machine-driven traffic: the injected beacons).
* **port concentration** — destinations whose traffic is concentrated on
  one unusual port (C2 channels ride fixed ports).

``detect_c2`` fuses the three scores; validated against
``pipeline.botnet_truth`` in the test suite.

Detectors accept any object speaking the Assoc selection grammar: an
in-memory :class:`Assoc`, a deferred :class:`~repro.core.expr.LazyAssoc`,
or a live :class:`~repro.db.binding.DBTable` — in the last case each
``E[:, StartsWith(...)]`` block below becomes a pushed-down transpose-
table scan that reads only that column band from the database.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.assoc import Assoc, StartsWith
from ..core.expr import LazyAssoc
from . import powerlaw
from .serialize import JsonReportMixin

Queryable = Union[Assoc, LazyAssoc, "DBTable"]  # anything with E[r, c]


class C2Report(NamedTuple):
    hosts: np.ndarray          # candidate dst IPs, best first
    scores: np.ndarray
    fanin: np.ndarray
    regularity: np.ndarray
    port_conc: np.ndarray

    # JSON report path (numpy/jax fields coerced; see analytics.serialize)
    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


class C2Scores(NamedTuple):
    """The full (unsorted) per-destination score table — what
    :func:`c2_scores` computes over any Queryable, including an
    in-memory windowed sub-Assoc.  :func:`detect_c2` is a sort + top-k
    view of this; the streaming beacon detector thresholds it per
    window instead of rescanning a table."""
    hosts: np.ndarray          # every dst key seen (stripped of prefix)
    scores: np.ndarray
    fanin: np.ndarray
    regularity: np.ndarray
    port_conc: np.ndarray

    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


class ScanReport(NamedTuple):
    """``scan_detect`` hits plus the threshold they cleared — the
    JSON-serializable shape the gateway's ``/v1/scanners`` route ships."""
    hosts: np.ndarray          # scanner src IPs
    min_fanout: int

    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


def _strip(keys: np.ndarray, prefix: str) -> np.ndarray:
    n = len(prefix)
    return np.asarray([k[n:] for k in keys], dtype=str)


def _keymap(sub: np.ndarray, target: np.ndarray) -> np.ndarray:
    """Positions of ``sub`` keys in sorted ``target``; -1 when absent."""
    if target.shape[0] == 0 or sub.shape[0] == 0:
        return np.full(sub.shape[0], -1, np.int64)
    pos = np.clip(np.searchsorted(target, sub), 0, target.shape[0] - 1)
    return np.where(target[pos] == sub, pos, -1).astype(np.int64)


@jax.jit
def _fuse(fanin, regularity, port_conc, total_pkts):
    """Product fusion: a C2 host must exhibit *all three* fingerprints
    (fan-in, periodicity, port concentration); any single strong signal
    in the power-law background is not enough.  Port concentration is
    squared — it is the most discriminative feature (C2 at 0.7-0.9 vs
    about 0.17 for mixed-service background hosts; see the sensitivity
    ablation in EXPERIMENTS.md)."""
    del total_pkts   # significance damping measured net-negative (ablation)
    return jnp.log1p(fanin) * regularity * port_conc * port_conc


def c2_scores(E: Queryable, sep: str = "|") -> C2Scores:
    """The fused detector's scoring core over *any* Queryable — a live
    :class:`DBTable`, a deferred :class:`LazyAssoc`, or an in-memory
    windowed sub-:class:`Assoc` (the streaming path: the rollup hands a
    window slice straight to this, no table rescan).  Returns the whole
    score table, unsorted."""
    Edst = E[:, StartsWith(f"ip.dst{sep}")]
    Esrc = E[:, StartsWith(f"ip.src{sep}")]
    Etime = E[:, StartsWith(f"frame.time{sep}")]
    Eport = E[:, StartsWith(f"tcp.dstport{sep}")]

    # unique-source fan-in: (src × dst) support, column sums of spones
    SD = Esrc.T * Edst                       # src × dst packet counts
    fanin_a = SD.logical().sum(0)            # 1 × dst: distinct sources
    dst_keys = _strip(fanin_a.col, f"ip.dst{sep}")
    fanin = np.zeros(dst_keys.shape[0])
    _, c, v = fanin_a.triples()
    fanin[np.searchsorted(fanin_a.col, c)] = np.asarray(v, np.float64)

    # source-uniformity: bots all contact the C2 a similar number of
    # times (duration/period each), while a popular host's sources have
    # heavy-tailed counts — CV over per-source counts separates them
    # even when beacons are too slow for time-bucket regularity.
    src_uniform = np.zeros(dst_keys.shape[0])
    r_sd, c_sd, v_sd = SD.triples()
    v_sd = np.asarray(v_sd, np.float64)
    if r_sd.shape[0]:
        uniq_d, inv_d = np.unique(c_sd, return_inverse=True)
        cnt = np.bincount(inv_d)
        s1 = np.bincount(inv_d, weights=v_sd)
        s2 = np.bincount(inv_d, weights=v_sd * v_sd)
        mean = s1 / cnt
        var = np.maximum(s2 / cnt - mean ** 2, 0.0)
        cv_s = np.sqrt(var) / np.maximum(mean, 1e-9)
        pos = _keymap(_strip(uniq_d, f"ip.dst{sep}"), dst_keys)
        ok = pos >= 0
        # only meaningful with several sources and repeated contacts
        score_s = np.exp(-cv_s) * (cnt >= 4) * (mean >= 2)
        src_uniform[pos[ok]] = score_s[ok]

    # beacon regularity: dst × time-bucket contact counts
    DT = Edst.T * Etime                      # dst × seconds
    dt_rows = _strip(DT.row, f"ip.dst{sep}")
    support = np.zeros(dst_keys.shape[0])
    cv = np.ones(dst_keys.shape[0]) * 10.0   # high CV = irregular
    r, c, v = DT.triples()
    v = np.asarray(v, np.float64)
    if r.shape[0]:
        uniq, inv = np.unique(r, return_inverse=True)
        cnt = np.bincount(inv)
        s1 = np.bincount(inv, weights=v)
        s2 = np.bincount(inv, weights=v * v)
        mean = s1 / cnt
        var = np.maximum(s2 / cnt - mean ** 2, 0.0)
        cv_u = np.sqrt(var) / np.maximum(mean, 1e-9)
        pos = _keymap(_strip(uniq, f"ip.dst{sep}"), dst_keys)
        ok = pos >= 0
        support[pos[ok]] = cnt[ok]
        cv[pos[ok]] = cv_u[ok]
    # regular = contacted in many buckets with near-constant rate; slow
    # beacons (period ≫ bucket) are caught by source-uniformity instead
    total_buckets = max(len(DT.col), 1)
    regularity = np.maximum((support / total_buckets) * np.exp(-cv),
                            src_uniform)

    # port concentration: dst × port counts, Herfindahl index
    DP = Edst.T * Eport
    conc = np.zeros(dst_keys.shape[0])
    total_pkts = np.zeros(dst_keys.shape[0])
    r, c, v = DP.triples()
    v = np.asarray(v, np.float64)
    if r.shape[0]:
        uniq, inv = np.unique(r, return_inverse=True)
        tot = np.bincount(inv, weights=v)
        h = np.bincount(inv, weights=v * v) / np.maximum(tot ** 2, 1e-9)
        pos = _keymap(_strip(uniq, f"ip.dst{sep}"), dst_keys)
        ok = pos >= 0
        conc[pos[ok]] = h[ok]
        total_pkts[pos[ok]] = tot[ok]

    fused = np.asarray(_fuse(jnp.asarray(fanin, jnp.float32),
                             jnp.asarray(regularity, jnp.float32),
                             jnp.asarray(conc, jnp.float32),
                             jnp.asarray(total_pkts, jnp.float32)))
    return C2Scores(dst_keys, fused, fanin, regularity, conc)


def detect_c2(E: Queryable, sep: str = "|", top_k: int = 10) -> C2Report:
    """Run the fused detector over an incidence matrix (stage-5 output)
    or directly over the database through a :class:`DBTable` binding."""
    s = c2_scores(E, sep=sep)
    order = np.argsort(s.scores)[::-1][:top_k]
    return C2Report(s.hosts[order], s.scores[order], s.fanin[order],
                    s.regularity[order], s.port_conc[order])


def scan_hits(E: Queryable, sep: str = "|",
              min_fanout: int = 32) -> np.ndarray:
    """Scan-detector scoring core: sources touching at least
    ``min_fanout`` distinct dsts with single packets (logical out-degree
    ≈ packet out-degree).  Like :func:`c2_scores`, accepts an in-memory
    windowed sub-Assoc — the streaming burst detector calls this on each
    closed window's slice."""
    Esrc = E[:, StartsWith(f"ip.src{sep}")]
    Edst = E[:, StartsWith(f"ip.dst{sep}")]
    SD = Esrc.T * Edst
    uniq_out = SD.logical().sum(1)
    pkt_out = SD.sum(1)
    r1, _, v1 = uniq_out.triples()
    r2, _, v2 = pkt_out.triples()
    v2_by_key = dict(zip(r2, np.asarray(v2, np.float64)))
    hits = []
    for k, u in zip(r1, np.asarray(v1, np.float64)):
        if u >= min_fanout and u / max(v2_by_key.get(k, 1.0), 1.0) > 0.9:
            hits.append(k[len(f"ip.src{sep}"):])
    return np.asarray(hits, dtype=str)


def scan_detect(E: Queryable, sep: str = "|",
                min_fanout: int = 32) -> np.ndarray:
    """Port/host-scan detector (see :func:`scan_hits` for the core)."""
    return scan_hits(E, sep=sep, min_fanout=min_fanout)


def scan_report(E: Queryable, sep: str = "|",
                min_fanout: int = 32) -> ScanReport:
    """:func:`scan_detect` wrapped in the JSON-serializable report shape."""
    return ScanReport(scan_detect(E, sep=sep, min_fanout=min_fanout),
                      min_fanout)
