"""Power-law background modeling for network graphs (paper refs [25],[26]).

Internet host-popularity follows a heavy-tailed (power-law / Zipf)
distribution; the Gadepally–Kepner approach models this background so
that *deviations* from it — hosts far off the rank-size line — surface as
anomalies (C2 servers, scanners), instead of simply "the biggest talkers".

Everything numeric here is jit'd JAX: these run on-device over degree
vectors produced by the sharded incidence matrix.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .serialize import JsonReportMixin


class PowerLawFit(NamedTuple):
    alpha: jax.Array      # rank-size exponent (degree ~ C · rank^-alpha)
    log_c: jax.Array      # intercept
    resid: jax.Array      # per-rank log residual (obs - model)
    r2: jax.Array

    # JSON report path (jax scalars coerced; see analytics.serialize)
    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


@jax.jit
def fit_rank_size(degrees: jax.Array) -> PowerLawFit:
    """Weighted least-squares fit of log(degree) vs log(rank).

    ``degrees``: (n,) nonneg; zeros are ignored via weighting.  Head ranks
    get full weight, the noisy tail is down-weighted logarithmically —
    the standard correction for rank-size regression bias.
    """
    d = jnp.sort(degrees)[::-1].astype(jnp.float32)
    n = d.shape[0]
    rank = jnp.arange(1, n + 1, dtype=jnp.float32)
    w = jnp.where(d > 0, 1.0 / jnp.log1p(rank), 0.0)
    x = jnp.log(rank)
    y = jnp.log(jnp.maximum(d, 1e-9))
    wsum = jnp.sum(w)
    xm = jnp.sum(w * x) / wsum
    ym = jnp.sum(w * y) / wsum
    cov = jnp.sum(w * (x - xm) * (y - ym))
    var = jnp.sum(w * (x - xm) ** 2)
    slope = cov / jnp.maximum(var, 1e-9)
    intercept = ym - slope * xm
    model = intercept + slope * x
    resid = jnp.where(d > 0, y - model, 0.0)
    ss_res = jnp.sum(w * resid ** 2)
    ss_tot = jnp.sum(w * (y - ym) ** 2)
    return PowerLawFit(-slope, intercept, resid,
                       1.0 - ss_res / jnp.maximum(ss_tot, 1e-9))


@partial(jax.jit, static_argnames=("n_bins",))
def degree_histogram(degrees: jax.Array, n_bins: int = 64):
    """Log-binned degree histogram n(d) — the degree-distribution view."""
    d = jnp.maximum(degrees.astype(jnp.float32), 0.0)
    logd = jnp.log1p(d)
    hi = jnp.maximum(jnp.max(logd), 1e-6)
    edges = jnp.linspace(0.0, hi * (1 + 1e-6), n_bins + 1)
    idx = jnp.clip(jnp.searchsorted(edges, logd, side="right") - 1,
                   0, n_bins - 1)
    counts = jax.ops.segment_sum(jnp.ones_like(logd), idx,
                                 num_segments=n_bins)
    centers = jnp.expm1(0.5 * (edges[:-1] + edges[1:]))
    return centers, counts


def fit_degree_table(T, prefix: str = "ip.dst|") -> PowerLawFit:
    """Fit the rank-size background straight from the database's
    combiner-maintained degree table (TedgeDeg) through a
    :class:`~repro.db.binding.DBTable` binding — no incidence-matrix
    materialization, which is how the paper sizes the background model
    at ingest rates."""
    import numpy as np
    deg = T.degree_assoc(prefix)
    if deg.nnz == 0:
        return fit_rank_size(jnp.zeros((1,), jnp.float32))
    d = jnp.asarray(np.asarray(deg.triples()[2], np.float32))
    return fit_rank_size(d)


@jax.jit
def background_scores(degrees: jax.Array) -> jax.Array:
    """Anomaly score per vertex: positive log-residual above the fitted
    rank-size background, mapped back from rank order to vertex order."""
    order = jnp.argsort(degrees)[::-1]
    fit = fit_rank_size(degrees)
    scores_ranked = jnp.maximum(fit.resid, 0.0)
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0]))
    return scores_ranked[inv]
