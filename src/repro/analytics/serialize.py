"""JSON-serializable report paths for the analytics outputs.

The detectors return NamedTuples holding numpy arrays and ``jax.Array``
scalars — ``json.dumps`` raises ``TypeError`` on every one of them.  The
serving gateway (and anything else shipping reports over a wire) needs
plain Python containers, so each report type gains ``to_dict`` /
``to_json`` built on :func:`to_jsonable`, plus a ``from_dict`` that
rebuilds the NamedTuple (arrays come back as numpy) for round-trips.
"""
from __future__ import annotations

import json
from typing import Any

import numpy as np


def to_jsonable(obj: Any) -> Any:
    """Recursively coerce numpy / JAX values to plain Python: scalars to
    ``int``/``float``/``bool``/``str``, arrays to (nested) lists, and
    mappings/sequences element-wise.  Anything already JSON-native passes
    through untouched."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (np.generic,)):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return [to_jsonable(x) for x in obj.tolist()] \
            if obj.dtype == object else obj.tolist()
    # jax.Array (and anything else array-like with .item/.tolist) —
    # duck-typed so this module never has to import jax
    if hasattr(obj, "tolist") and hasattr(obj, "shape"):
        return to_jsonable(np.asarray(obj))
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [to_jsonable(x) for x in obj]
    raise TypeError(f"cannot coerce {type(obj).__name__} to JSON")


class JsonReportMixin:
    """``to_dict``/``to_json``/``from_dict`` for report NamedTuples.

    Mix into a class defined with the NamedTuple *class* syntax::

        class C2Report(NamedTuple, JsonReportMixin): ...   # not allowed

    NamedTuple forbids extra bases, so instead the report classes define
    the three methods by assignment (``to_dict = JsonReportMixin.to_dict``)
    — same behavior, satisfies NamedTuple's single-base restriction.
    """

    def to_dict(self) -> dict:
        return {k: to_jsonable(v) for k, v in self._asdict().items()}

    def to_json(self, **json_kw) -> str:
        return json.dumps(self.to_dict(), **json_kw)

    @classmethod
    def from_dict(cls, d: dict):
        """Rebuild from :meth:`to_dict` output; list-valued fields come
        back as numpy arrays (string keys stay ``dtype=str``)."""
        vals = []
        for name in cls._fields:
            v = d[name]
            vals.append(np.asarray(v) if isinstance(v, list) else v)
        return cls(*vals)
