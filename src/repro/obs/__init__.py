"""repro.obs — the observability plane (metrics registry + tracing).

Two stdlib-only modules with no repro-internal imports, so every layer
(core, db, serve, stream, kernels) can instrument without cycles:

* :mod:`repro.obs.metrics` — process-wide :data:`REGISTRY` of
  Counter/Gauge/Histogram families with weakly-held labeled children;
  rendered by the gateway's ``GET /metrics`` (Prometheus text format).
* :mod:`repro.obs.trace` — contextvar-propagated request :func:`span`\\ s
  collected by a bounded :class:`Tracer` ring per gateway, with a
  slow-query log; O(ns) no-ops when no trace is active.

See docs/api.md "Observability" for the metric catalog and tracing
semantics.
"""
from .metrics import (Counter, Gauge, Histogram, MetricFamily, Registry,
                      REGISTRY, obj_label)
from .trace import Tracer, current_ctx, record, span, traced_iter

__all__ = ["Counter", "Gauge", "Histogram", "MetricFamily", "Registry",
           "REGISTRY", "obj_label", "Tracer", "current_ctx", "record",
           "span", "traced_iter"]
