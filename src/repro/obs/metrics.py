"""Process-wide metrics registry — the unified counter plane.

Before this module, every layer grew its own ad-hoc counters: the
:class:`~repro.db.binding.ScanCache` kept plain-int hits/misses, the
:class:`~repro.db.writer.WriterPool` summed per-writer fields outside
any lock, ``ShardClient.n_rpcs`` was incremented from concurrent reader
threads without a lock, and ``core.expr`` mutated a bare module dict per
kernel launch.  Each was individually small; together they made "where
does this deployment spend its time" unanswerable without poking five
objects — and two of them were genuine data races.

This registry absorbs them behind three primitives:

* :class:`Counter` — a lock-guarded monotonic count.  The lock is
  uncontended in the common case (one ``inc`` is ~100 ns), which is what
  "lock-cheap" means here: cheap enough for per-block / per-RPC paths,
  not for per-cell loops (batch those with ``inc(n)``).
* :class:`Gauge` — a settable level, or a live callback
  (:meth:`Gauge.set_function`) so queue depths and backlogs are read at
  scrape time from the owning object instead of being double-maintained.
* :class:`Histogram` — fixed log2 latency buckets (1 µs · 2^i), rendered
  as cumulative Prometheus buckets.

Metrics are grouped into **families** (one name + label schema), and a
family hands out **labeled children** (:meth:`MetricFamily.labels`).
Children are held *weakly*: the owning object (a cache, a writer pool, a
shard client) keeps the only strong reference, so when it is collected
its samples leave ``/metrics`` with it — per-object label cardinality is
bounded by *live* objects, not by every object ever created (test suites
create thousands).  Callers must therefore retain the child they get
back from ``labels()``.

Compatibility contract: objects that migrated their counters here keep
their public attribute shapes (``cache.hits``, ``pool.n_written``,
``client.n_rpcs`` …) as properties reading the same child — so
``T.stats()`` / ``/v1/stats`` payloads are unchanged, and ``/metrics``
reports *identical* values by construction (one underlying count, two
read surfaces; locked by tests/test_obs.py).
"""
from __future__ import annotations

import itertools
import threading
import weakref
from typing import Callable, Dict, Iterable, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricFamily", "Registry",
           "REGISTRY", "obj_label"]

_OBJ_SEQ = itertools.count()


def obj_label(prefix: str) -> str:
    """A process-unique label value for per-object metric children
    (``cache-3``, ``pool-17`` …) — objects that can exist many times per
    process label their children with this so each one's counts stay
    exact (and its compat properties read back only its own)."""
    return f"{prefix}-{next(_OBJ_SEQ)}"


class Counter:
    """Monotonic count; ``inc`` is atomic under an uncontended lock."""

    __slots__ = ("__weakref__", "_lock", "_value")
    kind = "counter"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def samples(self) -> Iterable[tuple]:
        yield "", (), self._value

    def __repr__(self):
        return f"Counter({self._value})"


class Gauge:
    """A level: ``set``/``inc``/``dec``, or a live read via
    :meth:`set_function` (evaluated at scrape — use a weakref-closing
    callback so the gauge never pins its owner)."""

    __slots__ = ("__weakref__", "_lock", "_value", "_fn")
    kind = "gauge"

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        self.inc(-n)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    @property
    def value(self):
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:       # a dying owner must not break scrape
                return 0.0
        return self._value

    def samples(self) -> Iterable[tuple]:
        yield "", (), self.value

    def __repr__(self):
        return f"Gauge({self.value})"


class Histogram:
    """Fixed log2 buckets: upper bounds ``base * 2**i``.  The default
    (1 µs … ~67 s) covers everything from a cache hit to a stuck full
    scan; ``observe`` is O(log buckets) via binary search."""

    __slots__ = ("__weakref__", "_lock", "bounds", "_counts",
                 "_sum", "_count")
    kind = "histogram"

    def __init__(self, base: float = 1e-6, n_buckets: int = 26):
        self.bounds = tuple(base * (1 << i) for i in range(n_buckets))
        self._lock = threading.Lock()
        self._counts = [0] * n_buckets
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            if lo < len(self._counts):
                self._counts[lo] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def samples(self) -> Iterable[tuple]:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum = 0
        for bound, n in zip(self.bounds, counts):
            cum += n
            yield "_bucket", (("le", f"{bound:.9g}"),), cum
        yield "_bucket", (("le", "+Inf"),), total
        yield "_sum", (), s
        yield "_count", (), total

    def __repr__(self):
        return f"Histogram(count={self._count}, sum={self._sum:g})"


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """One metric name + label schema, fanning out to labeled children.

    Children are weakly held (see module docstring); the zero-label
    child (``labels()`` with no schema) is pinned on the family so
    module-level metrics never vanish.
    """

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: Tuple[str, ...] = (), **child_kw):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._child_kw = child_kw
        self._lock = threading.Lock()
        self._children: "weakref.WeakValueDictionary" = \
            weakref.WeakValueDictionary()
        self._default = None        # pin for the unlabeled child

    def labels(self, **kw):
        """The child for one label-value combination, created on first
        use.  Keep the returned object alive — the family only holds it
        weakly."""
        if set(kw) != set(self.labelnames):
            raise ValueError(
                f"{self.name} expects labels {self.labelnames}, "
                f"got {tuple(kw)}")
        key = tuple(str(kw[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = _KINDS[self.kind](**self._child_kw)
                self._children[key] = child
                if not key:
                    self._default = child
            return child

    def collect(self):
        """Snapshot of ``(labelvalues, child)`` pairs, stable-ordered."""
        with self._lock:
            return sorted(self._children.items())


class Registry:
    """Named metric families + the Prometheus text renderer.

    ``counter``/``gauge``/``histogram`` are get-or-create and idempotent
    (same name must mean same kind + label schema), so modules can
    declare their families at import time without registration order
    mattering.  With no ``labels`` schema the (pinned) unlabeled child
    is returned directly — the common case for module-level metrics.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, MetricFamily] = {}

    def _family(self, name: str, kind: str, help: str,
                labels: Tuple[str, ...], **child_kw) -> MetricFamily:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = MetricFamily(name, kind, help, labels, **child_kw)
                self._families[name] = fam
            elif fam.kind != kind or fam.labelnames != tuple(labels):
                raise ValueError(
                    f"metric {name!r} re-registered with a different "
                    f"kind/label schema")
            return fam

    def counter(self, name: str, help: str = "", labels=()):
        fam = self._family(name, "counter", help, tuple(labels))
        return fam if labels else fam.labels()

    def gauge(self, name: str, help: str = "", labels=()):
        fam = self._family(name, "gauge", help, tuple(labels))
        return fam if labels else fam.labels()

    def histogram(self, name: str, help: str = "", labels=(), **kw):
        fam = self._family(name, "histogram", help, tuple(labels), **kw)
        return fam if labels else fam.labels()

    # -- scrape surface ----------------------------------------------------
    @staticmethod
    def _esc(v: str) -> str:
        return v.replace("\\", r"\\").replace('"', r'\"') \
                .replace("\n", r"\n")

    def render(self) -> str:
        """The Prometheus text exposition (``GET /metrics``)."""
        lines = []
        with self._lock:
            fams = sorted(self._families.values(), key=lambda f: f.name)
        for fam in fams:
            children = fam.collect()
            if not children:
                continue
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for labelvalues, child in children:
                base = list(zip(fam.labelnames, labelvalues))
                for suffix, extra, value in child.samples():
                    pairs = base + list(extra)
                    label_s = ",".join(
                        f'{k}="{self._esc(v)}"' for k, v in pairs)
                    label_s = "{" + label_s + "}" if label_s else ""
                    v = f"{value:.9g}" if isinstance(value, float) \
                        else str(value)
                    lines.append(f"{fam.name}{suffix}{label_s} {v}")
        return "\n".join(lines) + "\n"

    def as_dict(self) -> Dict[tuple, float]:
        """``{(name+suffix, ((label, value), ...)): sample}`` — the
        test-friendly view the /metrics↔stats identity assertions use."""
        out = {}
        with self._lock:
            fams = list(self._families.values())
        for fam in fams:
            for labelvalues, child in fam.collect():
                base = tuple(zip(fam.labelnames, labelvalues))
                for suffix, extra, value in child.samples():
                    out[(fam.name + suffix, base + tuple(extra))] = value
        return out


#: The process-wide default registry every layer registers into (and the
#: gateway's ``GET /metrics`` renders).
REGISTRY = Registry()
