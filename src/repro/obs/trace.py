"""Request-scoped tracing: spans from the gateway down to kernel launch.

The serving stack spans six layers (gateway → planner → binding →
WriterPool → LSM/net backend → Pallas kernels); per-object counters say
*how much* work each layer did, but not *which request* paid for it.  A
:class:`Span` answers that: the gateway opens a root span per traced
request, and every instrumented layer underneath attaches child spans —
scan route + cache verdict, the writer drain barrier, each per-shard
RPC (tagged with the shard address), LSM spill/compaction, each device
kernel launch — giving one tree per request that shows exactly where
the budget went.

Design constraints, in priority order:

1. **The untraced hot path stays O(ns).**  Propagation rides a
   :mod:`contextvars` ContextVar; when no trace is active,
   :func:`span` does one ContextVar read and returns a shared no-op —
   no allocation beyond the kwargs dict, no lock, no clock read.
   Layers therefore instrument unconditionally; *sampling is decided
   once, at the gateway* (``?trace=1``, an ``X-Trace-Id`` header, or
   the ``sample`` probability knob).
2. **Bounded memory.**  Finished spans land in a per-:class:`Tracer`
   ring: at most ``max_traces`` traces (LRU-evicted), at most
   ``max_spans`` spans per trace (excess counted, not stored).
3. **Same-thread propagation only.**  Scans, RPC streams, barriers and
   kernel launches all execute on the requesting thread, so ContextVar
   scoping is exactly right; background writer/job threads are *not*
   in the request's critical path and stay untraced.

The tracer doubles as the **slow-query log**: the ``slow_log_size``
slowest root spans over ``slow_threshold_s`` keep their full span tree
(``/v1/debug/slow``); untraced requests that cross the threshold are
noted tree-less by the gateway (:meth:`Tracer.note_slow`) so a slow
query never hides just because it wasn't sampled.
"""
from __future__ import annotations

import contextvars
import itertools
import os
import threading
import time
from collections import OrderedDict
from typing import Iterable, Optional

__all__ = ["Tracer", "span", "current_ctx", "record", "traced_iter"]

_CTX: "contextvars.ContextVar[Optional[_Ctx]]" = contextvars.ContextVar(
    "repro_trace_ctx", default=None)


class _Ctx:
    """The active (tracer, trace, parent-span) triple a thread carries."""

    __slots__ = ("tracer", "trace_id", "span_id")

    def __init__(self, tracer: "Tracer", trace_id: str, span_id: int):
        self.tracer = tracer
        self.trace_id = trace_id
        self.span_id = span_id


def current_ctx() -> Optional[_Ctx]:
    """The active trace context, or None when untraced — generators that
    outlive their creating frame capture this once and :func:`record`
    against it instead of entering a ``with`` block across yields."""
    return _CTX.get()


class _NoopSpan:
    """What :func:`span` returns when no trace is active."""

    __slots__ = ()
    trace_id = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def tag(self, **kw):
        pass


_NOOP = _NoopSpan()


class _Span:
    """A live span: context manager that re-parents the ContextVar for
    its dynamic extent and records itself on exit."""

    __slots__ = ("_ctx", "name", "tags", "_t0", "_wall0", "_sid", "_token")

    def __init__(self, ctx: _Ctx, name: str, tags: dict):
        self._ctx = ctx
        self.name = name
        self.tags = tags

    @property
    def trace_id(self) -> str:
        return self._ctx.trace_id

    def __enter__(self):
        ctx = self._ctx
        self._sid = ctx.tracer._next_span_id()
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        self._token = _CTX.set(_Ctx(ctx.tracer, ctx.trace_id, self._sid))
        return self

    def tag(self, **kw) -> None:
        self.tags.update(kw)

    def __exit__(self, et, ev, tb):
        dur = time.perf_counter() - self._t0
        _CTX.reset(self._token)
        if et is not None:
            self.tags["error"] = f"{et.__name__}: {ev}"
        ctx = self._ctx
        ctx.tracer._record(ctx.trace_id, self._sid, ctx.span_id,
                           self.name, self._wall0, dur, self.tags)
        return False


def span(name: str, **tags):
    """Open a child span under the current trace — or a shared no-op
    when untraced (the O(ns) fast path; see module docstring)."""
    ctx = _CTX.get()
    if ctx is None:
        return _NOOP
    return _Span(ctx, name, tags)


def record(ctx: Optional[_Ctx], name: str, wall0: float, dur: float,
           **tags) -> None:
    """Append a completed span under ``ctx`` without touching the
    ContextVar — the escape hatch for generators whose extent spans
    many resumptions (RPC streams, LSM scans)."""
    if ctx is not None:
        ctx.tracer._record(ctx.trace_id, ctx.tracer._next_span_id(),
                           ctx.span_id, name, wall0, dur, tags)


def traced_iter(name: str, it: Iterable, **tags):
    """Wrap a generator so its full consumption (first ``next`` to
    exhaustion or abandonment) records one span; a no-op passthrough
    when untraced."""
    ctx = _CTX.get()
    if ctx is None:
        yield from it
        return
    wall0 = time.time()
    t0 = time.perf_counter()
    try:
        yield from it
    finally:
        record(ctx, name, wall0, time.perf_counter() - t0, **tags)


class Tracer:
    """Bounded in-memory span collector + slow-query log.

    The gateway owns one; instrumented layers never see it directly —
    they :func:`span` against whatever context the gateway opened.
    """

    def __init__(self, max_traces: int = 256, max_spans: int = 512,
                 slow_log_size: int = 32, slow_threshold_s: float = 0.25):
        self.max_traces = max_traces
        self.max_spans = max_spans
        self.slow_log_size = slow_log_size
        self.slow_threshold_s = slow_threshold_s
        self._lock = threading.Lock()
        self._traces: "OrderedDict[str, dict]" = OrderedDict()
        self._slow: list[dict] = []
        self._span_seq = itertools.count(1)
        self.n_traces = 0
        self.n_spans = 0
        self.n_spans_dropped = 0

    # -- opening a trace ---------------------------------------------------
    def start(self, name: str, trace_id: Optional[str] = None,
              **tags) -> _Span:
        """Open (and register) a root span.  ``trace_id`` honors an
        incoming ``X-Trace-Id`` (sanitized); otherwise a fresh 16-hex-char
        id is minted.  Returns the root span context manager — its
        ``.trace_id`` goes back to the client."""
        if trace_id:
            trace_id = "".join(
                ch for ch in str(trace_id)[:64]
                if ch.isalnum() or ch in "-_") or None
        if not trace_id:
            trace_id = os.urandom(8).hex()
        with self._lock:
            if trace_id not in self._traces:
                while len(self._traces) >= self.max_traces:
                    self._traces.popitem(last=False)
                self._traces[trace_id] = {"spans": [], "dropped": 0}
                self.n_traces += 1
        return _Span(_Ctx(self, trace_id, 0), name, tags)

    # -- recording (span machinery only) -----------------------------------
    def _next_span_id(self) -> int:
        return next(self._span_seq)

    def _record(self, trace_id: str, span_id: int, parent_id: int,
                name: str, wall0: float, dur: float, tags: dict) -> None:
        rec = {"span_id": span_id, "parent_id": parent_id, "name": name,
               "start": wall0, "dur_s": dur, "tags": dict(tags)}
        with self._lock:
            tr = self._traces.get(trace_id)
            if tr is None:      # evicted mid-flight; drop silently
                return
            if len(tr["spans"]) >= self.max_spans:
                tr["dropped"] += 1
                self.n_spans_dropped += 1
            else:
                tr["spans"].append(rec)
                self.n_spans += 1
            if parent_id == 0:      # root closed: slow-log check
                self._traces.move_to_end(trace_id)
                if dur >= self.slow_threshold_s:
                    self._note_slow_locked(
                        trace_id, name, wall0, dur, dict(tags),
                        self._tree_locked(trace_id))

    # -- slow-query log ----------------------------------------------------
    def _note_slow_locked(self, trace_id, name, wall0, dur, tags,
                          tree) -> None:
        entry = {"trace_id": trace_id, "name": name, "start": wall0,
                 "dur_s": dur, "tags": tags, "tree": tree}
        slow = self._slow
        if len(slow) < self.slow_log_size:
            slow.append(entry)
            return
        imin = min(range(len(slow)), key=lambda i: slow[i]["dur_s"])
        if dur > slow[imin]["dur_s"]:
            slow[imin] = entry
        # else: faster than everything retained — drop

    def note_slow(self, name: str, wall0: float, dur: float,
                  **tags) -> None:
        """Record an *untraced* request that crossed the threshold —
        tree-less (there were no spans), but present, so sampling can
        never hide a slow query entirely."""
        if dur < self.slow_threshold_s:
            return
        with self._lock:
            self._note_slow_locked(None, name, wall0, dur, tags, None)

    def slow(self) -> list[dict]:
        """Slowest-first snapshot of the slow-query log."""
        with self._lock:
            return sorted(self._slow, key=lambda e: -e["dur_s"])

    # -- reading -----------------------------------------------------------
    def _tree_locked(self, trace_id: str) -> Optional[dict]:
        tr = self._traces.get(trace_id)
        if tr is None:
            return None
        nodes = {}
        kids: dict = {}
        for rec in tr["spans"]:
            node = dict(rec)
            node["dur_ms"] = round(node.pop("dur_s") * 1e3, 3)
            node["children"] = []
            nodes[rec["span_id"]] = node
            kids.setdefault(rec["parent_id"], []).append(node)
        for sid, node in nodes.items():
            node["children"] = sorted(kids.get(sid, []),
                                      key=lambda n: n["start"])
        roots = sorted(kids.get(0, []), key=lambda n: n["start"])
        if not roots:       # trace registered but root still open
            return {"span_id": 0, "name": "(in flight)", "parent_id": None,
                    "children": [n for n in nodes.values()
                                 if n["parent_id"] not in nodes],
                    "dropped": tr["dropped"]}
        root = roots[0]
        # orphans (parent span dropped by the ring bound) hang off root
        for node in nodes.values():
            pid = node["parent_id"]
            if pid != 0 and pid not in nodes and node is not root:
                root["children"].append(node)
        root["dropped"] = tr["dropped"]
        return root

    def tree(self, trace_id: str) -> Optional[dict]:
        """The nested span tree for one trace id, or None if unknown
        (never collected, or LRU-evicted)."""
        with self._lock:
            return self._tree_locked(trace_id)

    def spans(self, trace_id: str) -> list[dict]:
        """Flat span records (tests assert parentage on these)."""
        with self._lock:
            tr = self._traces.get(trace_id)
            return [dict(r) for r in tr["spans"]] if tr else []

    def stats(self) -> dict:
        with self._lock:
            return {"n_traces": self.n_traces,
                    "live_traces": len(self._traces),
                    "n_spans": self.n_spans,
                    "n_spans_dropped": self.n_spans_dropped,
                    "slow_log": len(self._slow),
                    "slow_threshold_s": self.slow_threshold_s,
                    "max_traces": self.max_traces,
                    "max_spans": self.max_spans}

    def __repr__(self):
        return (f"Tracer(traces={self.n_traces}, spans={self.n_spans}, "
                f"slow={len(self._slow)})")
