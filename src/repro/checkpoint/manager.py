"""Sharded, journaled, atomic checkpointing (restart-capable).

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, metadata
        shard_00000.npz        # flat leaves, chunked ≤ shard_size bytes
        ...
        COMMITTED              # written last — absence ⇒ incomplete

Fault-tolerance contract:
* writes go to ``step_XXXX.tmp/`` and are renamed only after COMMITTED
  is fsync'd — a crash mid-save leaves the previous checkpoint intact;
* ``latest_step()`` ignores uncommitted directories;
* ``restore`` re-shards onto any mesh (arrays are saved as full host
  numpy; production multi-host would save per-host shards — the manifest
  already records per-leaf sharding specs to support that);
* optimizer/sampler state ride along in the same tree.

``AsyncCheckpointer`` overlaps serialization with training (one step of
double buffering — the §Perf overlap trick at the framework layer).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(root: str, step: int, tree: Any, metadata: Optional[dict] = None,
         shard_size: int = 512 * 2**20) -> str:
    """Atomic checkpoint save. Returns the committed directory."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "metadata": metadata or {},
        "time": time.time(),
        "leaves": [],
        "shards": [],
    }
    shard_idx, shard_bytes, shard_payload = 0, 0, {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": shard_idx,
        })
        shard_payload[f"leaf_{i}"] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= shard_size:
            _write_shard(tmp, shard_idx, shard_payload)
            manifest["shards"].append(shard_idx)
            shard_idx += 1
            shard_bytes, shard_payload = 0, {}
    if shard_payload:
        _write_shard(tmp, shard_idx, shard_payload)
        manifest["shards"].append(shard_idx)

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _write_shard(d: str, idx: int, payload: dict):
    path = os.path.join(d, f"shard_{idx:05d}.npz")
    np.savez(path, **payload)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(root, name, "COMMITTED")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str, tree_like: Any, step: Optional[int] = None,
            mesh=None, shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``; optionally re-shard
    onto ``mesh``/``shardings`` (elastic restart onto a different mesh)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = _flatten(tree_like)
    shard_data = {}
    for s in manifest["shards"]:
        with np.load(os.path.join(d, f"shard_{s:05d}.npz")) as z:
            for k in z.files:
                shard_data[k] = z[k]
    leaves = [shard_data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    if shardings is not None:
        flat_sh, _ = _flatten(shardings)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.numpy.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), \
        manifest["metadata"]


class AsyncCheckpointer:
    """Overlap checkpoint serialization with the next training steps."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, metadata=None):
        self.wait()
        # materialize on host before handing to the writer thread
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.root, step, host_tree, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.root, n, "COMMITTED")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)
