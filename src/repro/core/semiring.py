"""Semiring algebra for associative arrays.

D4M associative arrays take values in a semiring (S, ⊕, ⊗, 0, 1).  The
classic examples used in the paper's analytics are:

* ``plus_times``  — ordinary sparse linear algebra (graph construction,
  degree computation, correlation: E'*E).
* ``min_plus`` / ``max_plus`` — shortest/longest path relaxations.
* ``max_min``    — bottleneck capacities.
* ``or_and``     — boolean reachability (logical adjacency).
* ``max_times``  — Viterbi-style products.

Each semiring carries the jnp element-wise combine (``mul``), the
segment-reduction used to contract an axis (``segment_reduce``), and the
identities.  The sparse routines in :mod:`repro.core.sparse` are generic
over this object, so SpMV/SpMM/degree all work for every semiring.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (numeric) semiring with JAX-friendly reduction plumbing."""

    name: str
    add: Callable[[Array, Array], Array]          # ⊕, elementwise
    mul: Callable[[Array, Array], Array]          # ⊗, elementwise
    zero: float                                    # identity of ⊕ (sparse "empty")
    one: float                                     # identity of ⊗
    # segment reduction implementing ⊕ over groups (used to contract axes).
    segment_reduce: Callable[..., Array] = None  # type: ignore[assignment]

    def reduce(self, data: Array, segment_ids: Array, num_segments: int) -> Array:
        return self.segment_reduce(
            data, segment_ids, num_segments=num_segments,
            indices_are_sorted=False,
        )

    def np_add(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Host-side ⊕ for the scipy/numpy path (Assoc construction)."""
        return np.asarray(self.add(jnp.asarray(a), jnp.asarray(b)))


def _seg_sum(data, segment_ids, num_segments, indices_are_sorted=False):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments,
                               indices_are_sorted=indices_are_sorted)


def _seg_min(data, segment_ids, num_segments, indices_are_sorted=False):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments,
                               indices_are_sorted=indices_are_sorted)


def _seg_max(data, segment_ids, num_segments, indices_are_sorted=False):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments,
                               indices_are_sorted=indices_are_sorted)


PLUS_TIMES = Semiring("plus_times", jnp.add, jnp.multiply, 0.0, 1.0, _seg_sum)
MIN_PLUS = Semiring("min_plus", jnp.minimum, jnp.add, float(np.inf), 0.0, _seg_min)
MAX_PLUS = Semiring("max_plus", jnp.maximum, jnp.add, float(-np.inf), 0.0, _seg_max)
MAX_MIN = Semiring("max_min", jnp.maximum, jnp.minimum, 0.0, float(np.inf), _seg_max)
MAX_TIMES = Semiring("max_times", jnp.maximum, jnp.multiply, 0.0, 1.0, _seg_max)
OR_AND = Semiring(
    "or_and",
    lambda a, b: jnp.logical_or(a != 0, b != 0).astype(a.dtype),
    lambda a, b: jnp.logical_and(a != 0, b != 0).astype(a.dtype),
    0.0, 1.0, _seg_max,
)

REGISTRY: dict[str, Semiring] = {
    s.name: s
    for s in (PLUS_TIMES, MIN_PLUS, MAX_PLUS, MAX_MIN, MAX_TIMES, OR_AND)
}


def get(name_or_semiring: "str | Semiring") -> Semiring:
    if isinstance(name_or_semiring, Semiring):
        return name_or_semiring
    try:
        return REGISTRY[name_or_semiring]
    except KeyError:
        raise KeyError(
            f"unknown semiring {name_or_semiring!r}; "
            f"available: {sorted(REGISTRY)}") from None
