"""Device-side sparse payloads for associative arrays.

Two representations:

* :class:`COO` — sorted coordinate triples. The construction format; all
  Assoc payloads normalize to row-major sorted, coalesced COO.
* :class:`CSR` — compressed rows, the layout consumed by the Pallas
  segmented-reduction kernels (see ``repro.kernels``).

Both are registered pytrees so they pass through ``jax.jit`` /
``shard_map`` untouched.  nnz is static (a Python int) — JAX requires
static shapes — so in-jit ops that could shrink nnz (coalesce) keep the
buffer size and park dead entries at ``row == nrows`` (sorted past the
end, value = semiring zero).  Host-side construction (numpy) produces
exact-size buffers.

The degree computation / SpMV here are the numeric heart of the paper:
stage 6 builds ``TedgeDeg`` with exactly :func:`row_degree` /
:func:`col_degree`, and every analytic (power-law background, PageRank)
is a semiring SpMV over the incidence/adjacency payload.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import semiring as sr

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class COO:
    """Sorted, coalesced coordinate-format sparse matrix."""

    rows: Array            # int32[nnz]   (row-major sorted)
    cols: Array            # int32[nnz]
    vals: Array            # dtype[nnz]
    shape: Tuple[int, int]  # static

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.rows, self.cols, self.vals), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        rows, cols, vals = children
        return cls(rows, cols, vals, shape)

    # -- basics ------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    @property
    def dtype(self):
        return self.vals.dtype

    def astype(self, dtype) -> "COO":
        return COO(self.rows, self.cols, self.vals.astype(dtype), self.shape)

    @classmethod
    def from_numpy(cls, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                   shape: Tuple[int, int]) -> "COO":
        """Build from host triples: sort + coalesce (exact nnz) on host."""
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        if rows.size:
            # coalesce duplicates by summation (plus_times construction).
            key = rows * shape[1] + cols
            uniq, inv = np.unique(key, return_inverse=True)
            out = np.zeros(uniq.shape[0], dtype=vals.dtype)
            np.add.at(out, inv, vals)
            rows = (uniq // shape[1]).astype(np.int32)
            cols = (uniq % shape[1]).astype(np.int32)
            vals = out
        return cls(jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32),
                   jnp.asarray(vals), shape)

    def to_dense(self) -> Array:
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)

    def to_scipy(self):
        import scipy.sparse as sp
        return sp.coo_matrix(
            (np.asarray(self.vals), (np.asarray(self.rows), np.asarray(self.cols))),
            shape=self.shape).tocsr()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    """Compressed-sparse-row payload (kernel-facing layout)."""

    row_ptr: Array          # int32[nrows+1]
    cols: Array             # int32[nnz]
    vals: Array             # dtype[nnz]
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.row_ptr, self.cols, self.vals), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        row_ptr, cols, vals = children
        return cls(row_ptr, cols, vals, shape)

    @property
    def nnz(self) -> int:
        return int(self.cols.shape[0])


def coo_to_csr(m: COO) -> CSR:
    counts = jax.ops.segment_sum(
        jnp.ones_like(m.rows), m.rows, num_segments=m.shape[0])
    row_ptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    return CSR(row_ptr, m.cols, m.vals, m.shape)


def csr_to_coo(m: CSR) -> COO:
    nrows = m.shape[0]
    rows = jnp.searchsorted(
        m.row_ptr, jnp.arange(m.nnz, dtype=jnp.int32), side="right"
    ).astype(jnp.int32) - 1
    del nrows
    return COO(rows, m.cols, m.vals, m.shape)


# ---------------------------------------------------------------------------
# Core semiring contractions (jit-safe; used by the sharded analytics).
# ---------------------------------------------------------------------------

def spmv(m: COO, x: Array, ring: "sr.Semiring | str" = sr.PLUS_TIMES) -> Array:
    """y[i] = ⊕_j m[i,j] ⊗ x[j]  — generic semiring mat-vec."""
    ring = sr.get(ring)
    prods = ring.mul(m.vals, x[m.cols])
    return ring.reduce(prods, m.rows, m.shape[0])


def spmv_t(m: COO, x: Array, ring: "sr.Semiring | str" = sr.PLUS_TIMES) -> Array:
    """y[j] = ⊕_i m[i,j] ⊗ x[i]  — transpose mat-vec without re-sorting."""
    ring = sr.get(ring)
    prods = ring.mul(m.vals, x[m.rows])
    return ring.reduce(prods, m.cols, m.shape[1])


def spmm(m: COO, x: Array, ring: "sr.Semiring | str" = sr.PLUS_TIMES) -> Array:
    """(nr, nc) sparse @ (nc, k) dense → (nr, k) dense, generic semiring."""
    ring = sr.get(ring)
    prods = ring.mul(m.vals[:, None], x[m.cols])        # (nnz, k)
    return ring.reduce(prods, m.rows, m.shape[0])


def row_degree(m: COO, weighted: bool = False) -> Array:
    """Out-degree per row — the ``sum(E, 2)`` of the paper's stage 6."""
    w = m.vals if weighted else jnp.ones_like(m.vals)
    return jax.ops.segment_sum(w, m.rows, num_segments=m.shape[0])


def col_degree(m: COO, weighted: bool = False) -> Array:
    """In-degree per column — the ``sum(E, 1)`` building ``TedgeDeg``."""
    w = m.vals if weighted else jnp.ones_like(m.vals)
    return jax.ops.segment_sum(w, m.cols, num_segments=m.shape[1])


def transpose(m: COO) -> COO:
    order = jnp.lexsort((m.rows, m.cols))
    return COO(m.cols[order], m.rows[order], m.vals[order],
               (m.shape[1], m.shape[0]))


@partial(jax.jit, static_argnames=("num_rows",))
def _coalesce_fixed(rows: Array, cols: Array, vals: Array, num_rows: int):
    """In-jit coalesce: keeps nnz, sums duplicates, parks dead slots at end.

    Dead slots get ``row == num_rows`` so a subsequent segment reduce with
    ``num_segments == num_rows`` drops them.
    """
    ncols_key = jnp.max(cols) + 1
    key = rows.astype(jnp.int64) * ncols_key + cols
    order = jnp.argsort(key)
    key, vals = key[order], vals[order]
    head = jnp.concatenate([jnp.ones((1,), bool), key[1:] != key[:-1]])
    # Position of each run head; duplicates accumulate into the head slot.
    seg = jnp.cumsum(head) - 1
    summed = jax.ops.segment_sum(vals, seg, num_segments=key.shape[0])
    n_unique = jnp.sum(head)
    idx = jnp.arange(key.shape[0])
    live = idx < n_unique
    head_pos = jnp.nonzero(head, size=key.shape[0], fill_value=key.shape[0] - 1)[0]
    out_key = jnp.where(live, key[head_pos], -1)
    out_val = jnp.where(live, summed[idx], 0)
    out_rows = jnp.where(live, (out_key // ncols_key).astype(jnp.int32), num_rows)
    out_cols = jnp.where(live, (out_key % ncols_key).astype(jnp.int32), 0)
    return out_rows, out_cols, out_val


def coalesce(m: COO) -> COO:
    """jit-safe coalesce (fixed nnz, dead entries parked at row == nrows)."""
    r, c, v = _coalesce_fixed(m.rows, m.cols, m.vals, m.shape[0])
    return COO(r, c, v, m.shape)


# ---------------------------------------------------------------------------
# Host-side exact algebra (scipy bridge) — used by Assoc, mirrors how D4M
# delegates to MATLAB's sparse engine.  Device analytics never touch this.
# ---------------------------------------------------------------------------

def scipy_from_triples(rows, cols, vals, shape):
    import scipy.sparse as sp
    return sp.csr_matrix(
        (np.asarray(vals, dtype=np.float64),
         (np.asarray(rows, dtype=np.int64), np.asarray(cols, dtype=np.int64))),
        shape=shape)


def coo_from_scipy(m) -> COO:
    m = m.tocoo()
    order = np.lexsort((m.col, m.row))
    return COO(jnp.asarray(m.row[order], jnp.int32),
               jnp.asarray(m.col[order], jnp.int32),
               jnp.asarray(m.data[order]), m.shape)
