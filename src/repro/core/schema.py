"""The D4M schema — exploding dense tables into sparse incidence matrices.

This is the paper's stage 4→5 transformation.  A parsed TSV of packet
headers is first a *dense* associative array (rows = packet IDs, columns
= header fields, values = field strings).  ``val2col`` explodes it into
the *sparse* representation: column keys become ``field|value`` and every
stored value becomes 1 — the **incidence matrix** of the network graph
(paper §III-B steps 4–5, listing in §IV-E).

``col2val`` is the inverse, recovering the dense table from the graph.
"""
from __future__ import annotations

import numpy as np

from .assoc import Assoc


def parse_tsv(text: str, row_prefix: str = "") -> Assoc:
    """Parse a TSV (header line = field names, first col = row id) into a
    dense associative array.  Mirrors D4M's ``ReadCSV``/parse step."""
    lines = [ln for ln in text.split("\n") if ln.strip()]
    if not lines:
        return Assoc()
    header = lines[0].split("\t")
    fields = header[1:]
    rows, cols, vals = [], [], []
    for ln in lines[1:]:
        parts = ln.split("\t")
        rid = row_prefix + parts[0]
        for f, v in zip(fields, parts[1:]):
            if v != "":
                rows.append(rid)
                cols.append(f)
                vals.append(v)
    return Assoc(np.asarray(rows, dtype=str), np.asarray(cols, dtype=str),
                 np.asarray(vals, dtype=str))


def to_tsv(dense: Assoc) -> str:
    """Inverse of :func:`parse_tsv` (round-trip used in tests)."""
    r, c, v = dense.triples()
    fields = list(dense.col)
    fi = {f: i for i, f in enumerate(fields)}
    by_row: dict[str, list[str]] = {}
    for rr, cc, vv in zip(r, c, v):
        by_row.setdefault(rr, [""] * len(fields))[fi[cc]] = str(vv)
    out = ["\t".join(["id"] + fields)]
    for rid in dense.row:
        out.append("\t".join([rid] + by_row.get(rid, [""] * len(fields))))
    return "\n".join(out) + "\n"


def val2col(dense: Assoc, sep: str = "|") -> Assoc:
    """Dense table → sparse incidence matrix (``E = val2col(A,'|')``)."""
    r, c, v = dense.triples()
    if r.shape[0] == 0:
        return Assoc()
    vstr = np.asarray(v, dtype=str) if dense.val is not None else \
        np.asarray([f"{x:g}" for x in np.asarray(v, np.float64)], dtype=str)
    newcols = np.char.add(np.char.add(c.astype(str), sep), vstr)
    return Assoc(r, newcols, np.ones(r.shape[0]))


def col2val(sparse_e: Assoc, sep: str = "|") -> Assoc:
    """Sparse incidence matrix → dense table (inverse of val2col)."""
    r, c, _ = sparse_e.triples()
    if r.shape[0] == 0:
        return Assoc()
    split = np.char.partition(c.astype(str), sep)
    fields, vals = split[:, 0], split[:, 2]
    return Assoc(r, fields, vals.astype(str))
