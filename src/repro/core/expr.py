"""Deferred associative-array algebra — the lazy half of the D4M binding.

An Assoc expression like ``(T[r, :].logical() * T[r, :].logical().T) > k``
normally materializes a host Assoc per step: every ``logical()`` copies the
payload, every comparison rebuilds the array from string triples (unique +
re-sort of the key dictionaries), and a database table ``T`` is scanned once
per subscript.  :class:`LazyAssoc` instead records the chain as an operator
DAG and a small planner executes it in one pass:

* **selection pushdown** — subscripts migrate through transposes,
  elementwise ops, and matmuls down to the leaves, so a
  :class:`repro.db.binding.DBTable` scan reads only the requested tablet
  range instead of the whole table;
* **common-subexpression elimination** — structurally identical subtrees
  (the two ``T[r, :]`` scans above) execute once;
* **elementwise fusion** — chains of ``logical`` / comparison / scalar ops
  apply as one masked pass over the csr payload, skipping the per-stage
  triple rebuild;
* **device lowering** — large-nnz reductions (``sum``) and vector-shaped
  semiring matmuls lower to :class:`repro.core.sparse.COO` segment
  reductions / ``spmv`` on the accelerator (optionally the Pallas ELL
  kernel) instead of scipy on host.

Eager semantics are the specification: for every host-executed chain,
``lazy_chain.eval() == eager_chain`` (see tests/test_binding.py).  The
one licensed deviation is precision: device-lowered reductions (nnz ≥
``DEVICE_NNZ_THRESHOLD``) accumulate in float32 (JAX default), so
non-integer payloads match eager to ~1e-7 relative rather than exactly.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from . import keys as K
from . import sparse as S
from ..obs.metrics import REGISTRY as _REGISTRY
from ..obs.trace import span as _span
from .assoc import Assoc

# nnz at which reductions/matvecs move to the device path; small payloads
# stay on host where scipy beats dispatch+transfer overhead.
DEVICE_NNZ_THRESHOLD = 32768

# Route device matvecs through the Pallas ELL kernel (repro.kernels.spmv)
# instead of the COO segment reduction.  The kernel compiles on TPU and
# falls back to interpret mode elsewhere (see kernels.spmv.spmv_ell);
# REPRO_USE_PALLAS_SPMV=1 enables it process-wide.
USE_PALLAS_SPMV = __import__("os").environ.get(
    "REPRO_USE_PALLAS_SPMV", "0") == "1"

# Device launch odometer: every device-lowered matvec/multivec product
# bumps its counter.  This is the observability hook the batch-fusion
# tests (and the serving layer's stats) use to prove N chains executed
# as ONE fused SpMM launch instead of N SpMV launches.  The counters
# are atomic registry counters (repro.obs) — the old bare-dict version
# raced under the gateway's concurrent reader threads — and surface in
# /metrics as repro_kernel_launches_total{kernel=...}.
_KERNEL_LAUNCH_FAMILY = _REGISTRY.counter(
    "repro_kernel_launches_total", "Device-lowered kernel launches",
    labels=("kernel",))
_KERNEL_COUNTERS = {
    "spmv": _KERNEL_LAUNCH_FAMILY.labels(kernel="spmv"),
    "spmm": _KERNEL_LAUNCH_FAMILY.labels(kernel="spmm"),
}


class _LaunchView:
    """Read-only mapping over the launch counters — the compatibility
    shim for code that indexed the old ``KERNEL_LAUNCHES`` dict."""

    def __getitem__(self, k: str) -> int:
        return _KERNEL_COUNTERS[k].value

    def __iter__(self):
        return iter(_KERNEL_COUNTERS)

    def __len__(self):
        return len(_KERNEL_COUNTERS)

    def keys(self):
        return _KERNEL_COUNTERS.keys()

    def items(self):
        return [(k, c.value) for k, c in _KERNEL_COUNTERS.items()]

    def __repr__(self):
        return f"KERNEL_LAUNCHES{dict(self.items())!r}"


KERNEL_LAUNCHES = _LaunchView()


def launch_counts() -> dict:
    """Snapshot of the device launch counters (copy — safe to diff)."""
    return {k: c.value for k, c in _KERNEL_COUNTERS.items()}

_FUSABLE = frozenset({"logical", "filter", "scale", "shift"})
_ELEMENTWISE_BIN = frozenset({"add", "sub", "emul"})


def _is_all(sel) -> bool:
    """True when a selector denotes the full axis (D4M ':')."""
    return (sel is None or isinstance(sel, K.All)
            or (isinstance(sel, str) and sel == ":")
            or (isinstance(sel, slice) and sel == slice(None)))


def _is_positional(sel) -> bool:
    """Boolean-mask / integer-index selectors refer to *positions* in one
    specific key dictionary, so they cannot migrate through ops that
    change or compact dictionaries — they are pushdown barriers."""
    return isinstance(sel, np.ndarray) and sel.dtype.kind in "biu"


def _sel_key(sel) -> Any:
    """Hashable structural key for a selector (CSE + plan identity)."""
    if _is_all(sel):
        return ":"
    if isinstance(sel, (K.StartsWith, K.KeyRange)):
        return sel
    if isinstance(sel, str):
        return sel
    if isinstance(sel, np.ndarray):
        return ("arr",) + tuple(sel.tolist())
    if isinstance(sel, (list, tuple)):
        return ("seq",) + tuple(str(x) for x in sel)
    return repr(sel)


class LazyAssoc:
    """A node in a deferred Assoc-expression DAG.

    Mirrors the :class:`Assoc` operator surface; algebra builds the graph,
    and anything that needs concrete data (``triples``, ``row``, ``repr``,
    ``device_coo`` …) triggers :meth:`eval` and delegates.  Results are
    cached per node, so a DAG evaluates at most once.
    """

    __slots__ = ("op", "children", "args", "_value")

    def __init__(self, op: str, children: tuple = (), **args):
        self.op = op
        self.children = children
        self.args = args
        self._value: Optional[Assoc] = None

    # -- constructors ------------------------------------------------------
    @classmethod
    def leaf(cls, a: Assoc) -> "LazyAssoc":
        return cls("leaf", assoc=a)

    @classmethod
    def scan(cls, table, rsel=None, csel=None) -> "LazyAssoc":
        """Deferred ``table[rsel, csel]`` over a DB table binding."""
        return cls("scan", table=table, rsel=rsel, csel=csel)

    @staticmethod
    def wrap(x) -> "LazyAssoc":
        if isinstance(x, LazyAssoc):
            return x
        if isinstance(x, Assoc):
            return LazyAssoc.leaf(x)
        # DBTable and friends expose .lazy() returning their full scan
        if hasattr(x, "lazy"):
            return x.lazy()
        raise TypeError(f"cannot defer {type(x)!r}")

    # -- deferred algebra (mirrors Assoc) ----------------------------------
    def __getitem__(self, idx) -> "LazyAssoc":
        rsel, csel = idx if isinstance(idx, tuple) else (idx, None)
        return LazyAssoc("select", (self,), rsel=rsel, csel=csel)

    def transpose(self) -> "LazyAssoc":
        return LazyAssoc("transpose", (self,))

    @property
    def T(self) -> "LazyAssoc":
        return self.transpose()

    def logical(self) -> "LazyAssoc":
        return LazyAssoc("logical", (self,))

    def multiply(self, other) -> "LazyAssoc":
        return LazyAssoc("emul", (self, LazyAssoc.wrap(other)))

    def __mul__(self, other) -> "LazyAssoc":
        if isinstance(other, (int, float)):
            return LazyAssoc("scale", (self,), k=float(other))
        return LazyAssoc("matmul", (self, LazyAssoc.wrap(other)))

    def __rmul__(self, other) -> "LazyAssoc":
        if isinstance(other, (int, float)):
            return LazyAssoc("scale", (self,), k=float(other))
        return LazyAssoc("matmul", (LazyAssoc.wrap(other), self))

    def __add__(self, other) -> "LazyAssoc":
        if isinstance(other, (int, float)):
            return LazyAssoc("shift", (self,), k=float(other))
        return LazyAssoc("add", (self, LazyAssoc.wrap(other)))

    def __sub__(self, other) -> "LazyAssoc":
        return LazyAssoc("sub", (self, LazyAssoc.wrap(other)))

    def __and__(self, other) -> "LazyAssoc":
        return self.logical().multiply(LazyAssoc.wrap(other).logical())

    def __or__(self, other) -> "LazyAssoc":
        return (self.logical() + LazyAssoc.wrap(other).logical()).logical()

    def sum(self, axis: int) -> "LazyAssoc":
        return LazyAssoc("sum", (self,), axis=axis)

    def sqin(self) -> "LazyAssoc":
        return self.T * self

    def sqout(self) -> "LazyAssoc":
        return self * self.T

    def _cmp(self, cmp: str, x) -> "LazyAssoc":
        return LazyAssoc("filter", (self,), cmp=cmp, x=x)

    def __gt__(self, x):
        return self._cmp("gt", x)

    def __ge__(self, x):
        return self._cmp("ge", x)

    def __lt__(self, x):
        return self._cmp("lt", x)

    def __le__(self, x):
        return self._cmp("le", x)

    def __eq__(self, x):  # noqa: D105 — D4M filter, like Assoc.__eq__
        if isinstance(x, (Assoc, LazyAssoc)):
            other = x.eval() if isinstance(x, LazyAssoc) else x
            return self.eval() == other
        return self._cmp("eq", x)

    __hash__ = None

    # -- forcing -----------------------------------------------------------
    def eval(self) -> Assoc:
        """Optimize and execute the DAG; cached per node."""
        if self._value is None:
            with _span("planner.eval", op=self.op):
                self._value = _Executor().run(_optimize(self))
        return self._value

    def __getattr__(self, name: str):
        # Fallback for everything Assoc-shaped that needs concrete data
        # (triples, row, col, nnz, shape, putval, device_coo, save, ...).
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self.eval(), name)

    def __len__(self):
        return len(self.eval())

    def __bool__(self):
        return bool(self.eval())

    def __repr__(self):
        if self._value is not None:
            return f"LazyAssoc(evaluated)\n{self._value!r}"
        return f"LazyAssoc<{self._plan_str()}>"

    def _plan_str(self) -> str:
        if self.op == "leaf":
            a = self.args["assoc"]
            return f"leaf[{a.shape[0]}x{a.shape[1]}]"
        if self.op == "scan":
            return (f"scan({getattr(self.args['table'], 'name', '?')}, "
                    f"{_sel_key(self.args['rsel'])}, "
                    f"{_sel_key(self.args['csel'])})")
        inner = ", ".join(c._plan_str() for c in self.children)
        extra = {k: v for k, v in self.args.items()}
        return f"{self.op}({inner}{', ' + repr(extra) if extra else ''})"


def lazy(x) -> LazyAssoc:
    """Wrap an Assoc (or table binding) into a deferred expression."""
    return LazyAssoc.wrap(x)


# ---------------------------------------------------------------------------
# Planner: selection pushdown + structural identity.
# ---------------------------------------------------------------------------

_NOT_COMPOSABLE = object()


def _compose_sel(inner, outer):
    """Compose two selectors on one axis; only trivial (either side is
    ':') compositions fuse — anything else stays a nested select."""
    if _is_all(outer):
        return inner
    if _is_all(inner):
        return outer
    return _NOT_COMPOSABLE


def _optimize(node: LazyAssoc) -> LazyAssoc:
    """Bottom-up rewrite: push selections toward the leaves so DB scans
    read only the requested key ranges, and cancel double transposes."""
    kids = tuple(_optimize(c) for c in node.children)
    n = LazyAssoc(node.op, kids, **node.args) if kids != node.children \
        else node

    if n.op == "transpose" and n.children[0].op == "transpose":
        return n.children[0].children[0]

    if n.op != "select":
        return n
    rsel, csel = n.args["rsel"], n.args["csel"]
    if _is_all(rsel) and _is_all(csel):
        return n.children[0]
    if _is_positional(rsel) or _is_positional(csel):
        return n   # positional selectors bind to this node's dictionaries
    (child,) = n.children

    if child.op == "select":
        rr = _compose_sel(child.args["rsel"], rsel)
        cc = _compose_sel(child.args["csel"], csel)
        if rr is not _NOT_COMPOSABLE and cc is not _NOT_COMPOSABLE:
            return _optimize(LazyAssoc("select", child.children,
                                       rsel=rr, csel=cc))
    if child.op == "scan":
        rr = _compose_sel(child.args["rsel"], rsel)
        cc = _compose_sel(child.args["csel"], csel)
        if rr is not _NOT_COMPOSABLE and cc is not _NOT_COMPOSABLE:
            return LazyAssoc("scan", table=child.args["table"],
                             rsel=rr, csel=cc)
    if child.op == "transpose":
        return _optimize(LazyAssoc(
            "transpose",
            (LazyAssoc("select", child.children, rsel=csel, csel=rsel),)))
    if child.op in _FUSABLE:
        # unary elementwise ops commute with selection entrywise; push the
        # select below so it keeps sinking toward a scan
        return _optimize(LazyAssoc(
            child.op,
            (LazyAssoc("select", child.children, rsel=rsel, csel=csel),),
            **child.args))
    if child.op in _ELEMENTWISE_BIN:
        return _optimize(LazyAssoc(
            child.op,
            tuple(LazyAssoc("select", (gc,), rsel=rsel, csel=csel)
                  for gc in child.children)))
    if child.op == "matmul":
        a, b = child.children
        return _optimize(LazyAssoc("matmul", (
            LazyAssoc("select", (a,), rsel=rsel, csel=None),
            LazyAssoc("select", (b,), rsel=None, csel=csel))))
    return n


def _skey(node: LazyAssoc):
    """Structural key — identical subtrees share one execution (CSE)."""
    if node.op == "leaf":
        return ("leaf", id(node.args["assoc"]))
    if node.op == "scan":
        return ("scan", id(node.args["table"]),
                _sel_key(node.args["rsel"]), _sel_key(node.args["csel"]))
    args = tuple(sorted((k, _sel_key(v) if k in ("rsel", "csel") else v)
                        for k, v in node.args.items()))
    return (node.op, args, tuple(_skey(c) for c in node.children))


# ---------------------------------------------------------------------------
# Executor.
# ---------------------------------------------------------------------------

_CMPS = {
    "gt": lambda v, x: v > x, "ge": lambda v, x: v >= x,
    "lt": lambda v, x: v < x, "le": lambda v, x: v <= x,
    "eq": lambda v, x: v == x,
}


class _Executor:
    def __init__(self):
        self._memo: dict = {}

    def run(self, node: LazyAssoc) -> Assoc:
        if node._value is not None:
            # a subtree forced earlier (its own .eval, or a previous DAG
            # sharing this node) never re-executes — scans included
            return node._value
        key = _skey(node)
        out = self._memo.get(key)
        if out is None:
            out = self._exec(node)
            self._memo[key] = out
        node._value = out
        return out

    def _exec(self, node: LazyAssoc) -> Assoc:
        op = node.op
        if op == "leaf":
            return node.args["assoc"]
        if op == "scan":
            return node.args["table"]._scan(node.args["rsel"],
                                            node.args["csel"])
        if op == "select":
            a = self.run(node.children[0])
            rsel = node.args["rsel"] if node.args["rsel"] is not None \
                else K.All()
            csel = node.args["csel"] if node.args["csel"] is not None \
                else K.All()
            return a[rsel, csel]
        if op == "transpose":
            return self.run(node.children[0]).transpose()
        if op in _FUSABLE:
            return self._exec_fused(node)
        if op == "add":
            return self.run(node.children[0]) + self.run(node.children[1])
        if op == "sub":
            return self.run(node.children[0]) - self.run(node.children[1])
        if op == "emul":
            return self.run(node.children[0]).multiply(
                self.run(node.children[1]))
        if op == "matmul":
            return self._exec_matmul(node)
        if op == "sum":
            return self._exec_sum(node)
        raise ValueError(f"unknown op {op!r}")

    # -- elementwise fusion ------------------------------------------------
    def _exec_fused(self, node: LazyAssoc) -> Assoc:
        """Collapse a unary elementwise chain into one pass over the csr
        payload: no per-stage Assoc rebuild, one compaction at the end."""
        chain = []
        cur = node
        while cur.op in _FUSABLE:
            chain.append(cur)
            cur = cur.children[0]
        base = self.run(cur)
        ops = chain[::-1]  # innermost first

        if base.val is not None and any(o.op == "filter" for o in ops):
            # categorical comparisons keep eager (string dictionary)
            # semantics; fusion only covers the numeric payload.
            return _apply_eager(base, ops)

        sm = base._numeric_sm().copy()
        data = sm.data.astype(np.float64, copy=True)
        alive = np.ones(data.shape[0], dtype=bool)
        filtered = False
        for o in ops:
            if o.op == "logical":
                data = np.ones_like(data)
            elif o.op == "scale":
                data = data * o.args["k"]
            elif o.op == "shift":
                data = data + o.args["k"]
            else:  # filter — eager rebuilds here, which also drops
                # entries that are exactly zero *at this stage* (the
                # Assoc constructor eliminates zeros); later scalar ops
                # may reintroduce explicit zeros, which eager keeps.
                alive &= _CMPS[o.args["cmp"]](data, o.args["x"])
                alive &= data != 0.0
                filtered = True
        if not filtered:
            sm.data = data
            return Assoc._from_parts(base.row, base.col, None, sm)
        # Drop dead entries and compact keys by *pattern*, preserving any
        # explicit zeros among the survivors (eager parity).
        import scipy.sparse as sp
        coo = sm.tocoo()  # canonical csr ⇒ data aligned with sm.data
        rk, ck, dk = coo.row[alive], coo.col[alive], data[alive]
        rmask = np.zeros(sm.shape[0], dtype=bool)
        rmask[rk] = True
        cmask = np.zeros(sm.shape[1], dtype=bool)
        cmask[ck] = True
        rmap = np.cumsum(rmask) - 1
        cmap = np.cumsum(cmask) - 1
        out = sp.csr_matrix((dk, (rmap[rk], cmap[ck])),
                            shape=(int(rmask.sum()), int(cmask.sum())))
        return Assoc._from_parts(base.row[rmask], base.col[cmask], None, out)

    # -- matmul with optional device lowering ------------------------------
    def _exec_matmul(self, node: LazyAssoc) -> Assoc:
        # Fused chain lowering: a left-spine matmul chain ending in a
        # vector (A @ B @ x) runs as successive device spmvs with the
        # intermediate vector staying on device — no host round-trips
        # between factors.  Reassociation (A@B)@x → A@(B@x) is licensed
        # by plus_times semiring algebra (float32 accumulation, same
        # precision contract as all device lowering).
        factors = []
        cur = node
        while cur.op == "matmul":
            factors.append(cur.children[1])
            cur = cur.children[0]
        factors.append(cur)
        factors.reverse()               # [A, B, ..., x]
        if len(factors) >= 3:
            mats = [self.run(f) for f in factors]
            out = _device_matmul_chain(mats)
            if out is not None:
                return out
        a = self.run(node.children[0])
        b = self.run(node.children[1])
        inner = np.intersect1d(a.col, b.row)
        asm = a._onto(a.row, inner)
        bsm = b._onto(inner, b.col)
        vector_out = b.col.shape[0] == 1 and asm.nnz >= DEVICE_NNZ_THRESHOLD
        if vector_out:
            y = _device_spmv(asm, np.asarray(bsm.todense()).ravel())
            sm = S.scipy_from_triples(
                np.arange(y.shape[0]), np.zeros(y.shape[0], np.int64),
                y, (y.shape[0], 1))
            sm.eliminate_zeros()
            return Assoc._from_parts(a.row, b.col, None, sm)._compact()
        return Assoc._from_parts(a.row, b.col, None, asm @ bsm)._compact()

    # -- sum with device lowering ------------------------------------------
    def _exec_sum(self, node: LazyAssoc) -> Assoc:
        a = self.run(node.children[0])
        axis = node.args["axis"]
        if a.nnz < DEVICE_NNZ_THRESHOLD or a.nnz == 0:
            return a.sum(axis)
        coo = a.device_coo()
        if axis in (1, 2):
            v = np.asarray(S.row_degree(coo, weighted=True),
                           dtype=np.float64)
            keep = v != 0
            n = int(keep.sum())
            return Assoc._from_parts(
                a.row[keep], np.asarray([""]), None,
                S.scipy_from_triples(np.arange(n), np.zeros(n, np.int64),
                                     v[keep], (n, 1)))
        v = np.asarray(S.col_degree(coo, weighted=True), dtype=np.float64)
        keep = v != 0
        n = int(keep.sum())
        return Assoc._from_parts(
            np.asarray([""]), a.col[keep], None,
            S.scipy_from_triples(np.zeros(n, np.int64), np.arange(n),
                                 v[keep], (1, n)))


def _apply_eager(base: Assoc, ops) -> Assoc:
    out = base
    for o in ops:
        if o.op == "logical":
            out = out.logical()
        elif o.op == "scale":
            out = out * o.args["k"]
        elif o.op == "shift":
            out = out + o.args["k"]
        else:
            out = getattr(out, f"__{o.args['cmp']}__")(o.args["x"])
    return out


def _device_spmv_dev(asm, x):
    """y = A @ x on device, device array in/out; COO segment reduction,
    or the Pallas ELL kernel when enabled (repro.kernels.spmv — the TPU
    hot path, compiled on TPU / interpreted elsewhere)."""
    import jax.numpy as jnp
    with _span("kernel.spmv", nnz=asm.nnz):
        _KERNEL_COUNTERS["spmv"].inc()
        if USE_PALLAS_SPMV:
            from ..kernels import spmv as kspmv
            csr = asm.tocsr()
            k_max = int(max(np.diff(csr.indptr).max(), 1))
            ecols, evals = kspmv.csr_to_ell(csr.indptr, csr.indices,
                                            csr.data, csr.shape[0], k_max)
            return kspmv.spmv_ell(ecols, evals, x.astype(jnp.float32))
        coo = S.coo_from_scipy(asm)
        return S.spmv(coo, x)


def _device_spmm_dev(asm, X):
    """Y = A @ X on device with X a dense (n, b) multi-vector — the
    batched unit: one launch answers all b queries.  Pallas ELL SpMM
    when enabled (same ``USE_PALLAS_SPMV`` switch as the matvec path,
    the env now covers SpMM), COO segment reduction otherwise."""
    import jax.numpy as jnp
    with _span("kernel.spmm", nnz=asm.nnz, b=int(X.shape[1])):
        _KERNEL_COUNTERS["spmm"].inc()
        if USE_PALLAS_SPMV:
            from ..kernels import spmm as kspmm
            from ..kernels import spmv as kspmv
            csr = asm.tocsr()
            k_max = int(max(np.diff(csr.indptr).max(), 1))
            ecols, evals = kspmv.csr_to_ell(csr.indptr, csr.indices,
                                            csr.data, csr.shape[0], k_max)
            return kspmm.spmm_ell(ecols, evals, X.astype(jnp.float32))
        coo = S.coo_from_scipy(asm)
        return S.spmm(coo, X)


def _device_spmv(asm, x: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp
    return np.asarray(_device_spmv_dev(asm, jnp.asarray(x, jnp.float32)),
                      dtype=np.float64)


def _device_matmul_chain(mats) -> Optional[Assoc]:
    """Lower A @ B @ ... @ x to successive device spmvs, keeping the
    intermediate vector on device between factors.  Returns None when
    the chain is not eligible (non-vector tail, empty factor, or every
    factor below DEVICE_NNZ_THRESHOLD) so the caller falls back to
    pairwise host matmul."""
    import jax.numpy as jnp
    *factors, vec = mats
    if vec.col.shape[0] != 1:
        return None
    if any(m.nnz == 0 for m in mats):
        return None
    if max(f.nnz for f in factors) < DEVICE_NNZ_THRESHOLD:
        return None
    y_keys = vec.row                    # sorted key dictionary
    y = jnp.asarray(np.asarray(vec._numeric_sm().todense()).ravel(),
                    jnp.float32)
    for F in reversed(factors):
        inner = np.intersect1d(F.col, y_keys)
        if inner.size == 0:
            y_keys = F.row
            y = jnp.zeros(F.row.shape[0], jnp.float32)
            continue
        fsm = F._onto(F.row, inner)
        idx = np.searchsorted(y_keys, inner)    # inner ⊆ y_keys, sorted
        y = _device_spmv_dev(fsm, jnp.take(y, jnp.asarray(idx)))
        y_keys = F.row
    yv = np.asarray(y, dtype=np.float64)        # single host transfer
    sm = S.scipy_from_triples(
        np.arange(yv.shape[0]), np.zeros(yv.shape[0], np.int64),
        yv, (yv.shape[0], 1))
    sm.eliminate_zeros()
    return Assoc._from_parts(y_keys, vec.col, None, sm)._compact()


# ---------------------------------------------------------------------------
# Batch evaluation: N expressions, one executor, fused device launches.
# ---------------------------------------------------------------------------

def lazy_batch(exprs) -> list:
    """Wrap a sequence of expressions (Assoc / LazyAssoc / table) into
    deferred nodes destined for one :func:`eval_batch` call."""
    return [LazyAssoc.wrap(x) for x in exprs]


def eval_batch(exprs) -> list:
    """Evaluate N independent expressions as ONE batch.

    Beyond per-DAG planning, the batch executor exploits *cross*-expression
    structure (arXiv:2309.02464's real-time trick — many hypersparse
    queries per launch):

    * **batch CSE** — one shared executor memoizes across all N DAGs, so
      structurally identical subtrees (the same table scan issued by
      every member) execute once;
    * **scan batching** — distinct scans against the same
      :class:`~repro.db.binding.DBTable` prefetch through
      ``table._scan_batch``: one union tablet scan per physical route,
      split per member host-side (each member still lands its own
      :class:`~repro.db.binding.ScanCache` entry);
    * **SpMM chain fusion** — matvec chains over identical factor lists
      (same structural scan key, different tail vectors) stack their
      vectors into a dense multi-vector and run as one device SpMM
      launch per factor (:func:`_device_spmm_dev`) instead of N SpMV
      launches, the intermediate multi-vector staying on device.

    Returns the evaluated :class:`Assoc` list, aligned with the input.
    Error semantics match per-member ``.eval()``: a member whose scan
    raises (e.g. the degree guard) raises when *that* member executes —
    such members are simply excluded from the fused prefetch.
    """
    nodes = [LazyAssoc.wrap(x) for x in exprs]
    with _span("planner.eval_batch", n=len(nodes)):
        ex = _Executor()
        plans = [n if n._value is not None else _optimize(n) for n in nodes]
        live = [p for n, p in zip(nodes, plans) if n._value is None]
        if len(live) >= 2:
            _prefetch_batch_scans(live, ex)
            _fuse_chain_groups(live, ex)
        out = []
        for n, p in zip(nodes, plans):
            if n._value is None:
                n._value = ex.run(p)
            out.append(n._value)
    return out


def _collect_scans(node: LazyAssoc, out: dict) -> None:
    if node._value is not None:
        return
    if node.op == "scan":
        out.setdefault(_skey(node), node)
    for c in node.children:
        _collect_scans(c, out)


def _prefetch_batch_scans(plans, ex: "_Executor") -> None:
    """Group the batch's distinct scan leaves by table and serve each
    group through one ``_scan_batch`` union scan, seeding the executor's
    memo (members the table declines stay lazy and scan individually)."""
    scans: dict = {}
    for p in plans:
        _collect_scans(p, scans)
    by_table: dict = {}
    for key, node in scans.items():
        if key in ex._memo:
            continue
        t = node.args["table"]
        if hasattr(t, "_scan_batch"):
            by_table.setdefault(id(t), []).append((key, node))
    for group in by_table.values():
        if len(group) < 2:
            continue            # nothing to amortize
        table = group[0][1].args["table"]
        sels = [(n.args["rsel"], n.args["csel"]) for _, n in group]
        results = table._scan_batch(sels)
        for (key, _), a in zip(group, results):
            if a is not None:
                ex._memo[key] = a


def _chain_parts(node: LazyAssoc):
    """[A, B, ..., x] for a left-spine matmul chain root; None else."""
    if node.op != "matmul":
        return None
    parts = []
    cur = node
    while cur.op == "matmul":
        parts.append(cur.children[1])
        cur = cur.children[0]
    parts.append(cur)
    parts.reverse()
    return parts


def _fuse_chain_groups(plans, ex: "_Executor") -> None:
    """Find matvec chains sharing an identical factor list and execute
    each group as one SpMM launch, seeding the executor's memo with the
    per-chain result columns."""
    groups: dict = {}
    for p in plans:
        parts = _chain_parts(p)
        if parts is None or len(parts) < 2:
            continue
        fkey = tuple(_skey(f) for f in parts[:-1])
        # dedupe by root skey — exact duplicates are already CSE'd
        groups.setdefault(fkey, {}).setdefault(_skey(p), parts)
    for chains in groups.values():
        if len(chains) < 2:
            continue
        # factor/tail evaluation goes through the shared executor, so
        # scans hit the batch-prefetched memo entries
        any_parts = next(iter(chains.values()))
        factors = [ex.run(f) for f in any_parts[:-1]]
        tails = [(rkey, ex.run(parts[-1]))
                 for rkey, parts in chains.items()]
        elig = [(rkey, v) for rkey, v in tails
                if v.col.shape[0] == 1 and v.nnz > 0]
        if len(elig) < 2:
            continue
        outs = _device_matmul_chain_multi(factors, [v for _, v in elig])
        if outs is None:
            continue
        for (rkey, _), out in zip(elig, outs):
            ex._memo[rkey] = out


def _device_matmul_chain_multi(factors, vecs) -> Optional[list]:
    """Lower N chains A @ B @ ... @ x_j (identical factors, different
    vectors) to successive device SpMMs over the stacked multi-vector
    X = [x_1 … x_N]: every factor streams from HBM once for the whole
    batch.  Column j of the zero-padded X reproduces chain j exactly
    under plus_times (padding zeros contribute nothing), so each result
    column equals its chain's :func:`_device_matmul_chain` output.
    Returns None when ineligible (empty factor, or all factors below
    DEVICE_NNZ_THRESHOLD) so the callers fall back per chain."""
    import jax.numpy as jnp
    if any(f.nnz == 0 for f in factors):
        return None
    if max(f.nnz for f in factors) < DEVICE_NNZ_THRESHOLD:
        return None
    y_keys = vecs[0].row
    for v in vecs[1:]:
        y_keys = np.union1d(y_keys, v.row)
    b = len(vecs)
    X = np.zeros((y_keys.shape[0], b), np.float32)
    for j, v in enumerate(vecs):
        idx = np.searchsorted(y_keys, v.row)    # v.row ⊆ y_keys, sorted
        X[idx, j] = np.asarray(v._numeric_sm().todense()).ravel()
    Y = jnp.asarray(X)
    for F in reversed(factors):
        inner = np.intersect1d(F.col, y_keys)
        if inner.size == 0:
            y_keys = F.row
            Y = jnp.zeros((F.row.shape[0], b), jnp.float32)
            continue
        fsm = F._onto(F.row, inner)
        idx = np.searchsorted(y_keys, inner)
        Y = _device_spmm_dev(fsm, jnp.take(Y, jnp.asarray(idx), axis=0))
        y_keys = F.row
    Yh = np.asarray(Y, dtype=np.float64)        # single host transfer
    outs = []
    for j, v in enumerate(vecs):
        col = Yh[:, j]
        sm = S.scipy_from_triples(
            np.arange(col.shape[0]), np.zeros(col.shape[0], np.int64),
            col, (col.shape[0], 1))
        sm.eliminate_zeros()
        outs.append(Assoc._from_parts(y_keys, v.col, None, sm)._compact())
    return outs
