"""Associative arrays — the paper's central mathematical object.

An :class:`Assoc` is a sparse matrix whose rows and columns are indexed by
sorted string keys and whose values live in a semiring; it unifies
spreadsheets, SQL/NoSQL tables, and sparse linear algebra (paper §II-B,
Fig. 2).  This implementation mirrors the documented D4M (MATLAB/Julia)
surface: triple construction, key-aligned algebra (+, elementwise *,
semiring matmul), sub-array selection by key lists / ranges / prefixes,
``val2col`` schema explosion, and ``putval``/``putcol`` renaming used by
the paper's ingest step.

Host/device split (the TPU adaptation, see DESIGN.md §2): key
dictionaries and exact-size algebra live on the host (numpy + scipy
sparse, the same role MATLAB's sparse engine plays for D4M), while the
numeric payload exports to :class:`repro.core.sparse.COO` for jit'd,
shard_map'd analytics on the device mesh.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

from . import keys as K
from . import sparse as S

_AGGS = {
    "sum": lambda out, inv, vals: np.add.at(out, inv, vals),
    "min": lambda out, inv, vals: np.minimum.at(out, inv, vals),
    "max": lambda out, inv, vals: np.maximum.at(out, inv, vals),
}


def _agg_numeric(inv: np.ndarray, vals: np.ndarray, n: int, agg: str):
    if agg == "first":
        out = np.zeros(n, dtype=np.float64)
        # reversed so that the first occurrence wins
        out[inv[::-1]] = vals[::-1]
        return out
    if agg == "last":
        out = np.zeros(n, dtype=np.float64)
        out[inv] = vals
        return out
    init = {"sum": 0.0, "min": np.inf, "max": -np.inf}[agg]
    out = np.full(n, init, dtype=np.float64)
    _AGGS[agg](out, inv, vals.astype(np.float64))
    return out


class Assoc:
    """D4M associative array.

    Parameters mimic D4M's triple constructor::

        A = Assoc('r1,r2,', 'c1,c2,', [1.0, 2.0])
        A = Assoc(rows, cols, 'v1,v2,')          # string values (categorical)

    Duplicate (row, col) pairs collide via ``agg`` (default: numeric sum,
    string lexicographic min — D4M's documented behaviour).
    """

    __slots__ = ("row", "col", "val", "sm")

    def __init__(self, row=None, col=None, val=None, agg: str = None,
                 _parts=None):
        if _parts is not None:  # internal fast path
            self.row, self.col, self.val, self.sm = _parts
            return
        if row is None:  # empty
            import scipy.sparse as sp
            self.row = np.empty((0,), dtype="U1")
            self.col = np.empty((0,), dtype="U1")
            self.val = None
            self.sm = sp.csr_matrix((0, 0))
            return

        rkeys = K.parse_keys(row)
        ckeys = K.parse_keys(col)
        if isinstance(val, (int, float)):
            val = np.full(max(rkeys.shape[0], ckeys.shape[0]), val)
        vraw = val

        # broadcast singleton key lists against the longest input
        n = max(rkeys.shape[0], ckeys.shape[0],
                len(vraw) if hasattr(vraw, "__len__") and not isinstance(vraw, str)
                else K.parse_keys(vraw).shape[0] if isinstance(vraw, str) else 0)
        if rkeys.shape[0] == 1 and n > 1:
            rkeys = np.repeat(rkeys, n)
        if ckeys.shape[0] == 1 and n > 1:
            ckeys = np.repeat(ckeys, n)

        categorical = False
        if isinstance(vraw, str) or (
                isinstance(vraw, np.ndarray) and vraw.dtype.kind in "US") or (
                isinstance(vraw, (list, tuple)) and len(vraw) and
                isinstance(vraw[0], (str, bytes))):
            vkeys = K.parse_keys(vraw)
            if vkeys.shape[0] == 1 and n > 1:
                vkeys = np.repeat(vkeys, n)
            categorical = True
            vals_arr = vkeys
        else:
            vals_arr = np.asarray(vraw, dtype=np.float64)
            if vals_arr.ndim == 0:
                vals_arr = np.repeat(vals_arr[None], n)

        if not (rkeys.shape[0] == ckeys.shape[0] == vals_arr.shape[0]):
            raise ValueError(
                f"triple lengths differ: rows={rkeys.shape[0]} "
                f"cols={ckeys.shape[0]} vals={vals_arr.shape[0]}")

        self.row, ri = np.unique(rkeys, return_inverse=True)
        self.col, ci = np.unique(ckeys, return_inverse=True)

        import scipy.sparse as sp
        nr, nc = self.row.shape[0], self.col.shape[0]
        if rkeys.shape[0] == 0:
            self.val = None
            self.sm = sp.csr_matrix((nr, nc))
            return

        lin = ri.astype(np.int64) * nc + ci.astype(np.int64)
        uniq, inv = np.unique(lin, return_inverse=True)

        if categorical:
            agg = agg or "min"
            # collide string values by lexicographic agg, then build the
            # value dictionary; payload stores 1-based dictionary indices.
            order = np.argsort(vals_arr) if agg == "min" else \
                np.argsort(vals_arr)[::-1]
            chosen = np.empty(uniq.shape[0], dtype=vals_arr.dtype)
            # reversed write ⇒ smallest (agg=min) value wins per slot
            chosen[inv[order][::-1]] = vals_arr[order][::-1]
            self.val, vidx = np.unique(chosen, return_inverse=True)
            data = vidx.astype(np.float64) + 1.0
        else:
            agg = agg or "sum"
            self.val = None
            data = _agg_numeric(inv, vals_arr, uniq.shape[0], agg)

        r = (uniq // nc).astype(np.int64)
        c = (uniq % nc).astype(np.int64)
        self.sm = sp.csr_matrix((data, (r, c)), shape=(nr, nc))
        self.sm.eliminate_zeros()
        self._compact()

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    @classmethod
    def _from_parts(cls, row, col, val, sm) -> "Assoc":
        a = cls(_parts=(np.asarray(row, dtype=str), np.asarray(col, dtype=str),
                        None if val is None else np.asarray(val, dtype=str),
                        sm.tocsr()))
        return a

    def _compact(self) -> "Assoc":
        """Drop rows/cols with no entries (D4M condenses key sets)."""
        self.sm.eliminate_zeros()
        coo = self.sm.tocoo()
        rmask = np.zeros(self.sm.shape[0], bool)
        rmask[coo.row] = True
        cmask = np.zeros(self.sm.shape[1], bool)
        cmask[coo.col] = True
        if rmask.all() and cmask.all():
            return self
        self.row = self.row[rmask]
        self.col = self.col[cmask]
        self.sm = self.sm[rmask][:, cmask].tocsr()
        return self

    def _numeric_sm(self):
        """Numeric view: categorical arrays are viewed as logical (D4M)."""
        if self.val is None:
            return self.sm
        out = self.sm.copy()
        out.data = np.ones_like(out.data)
        return out

    # ------------------------------------------------------------------
    # basics
    # ------------------------------------------------------------------
    @property
    def shape(self):
        return self.sm.shape

    @property
    def nnz(self) -> int:
        return int(self.sm.nnz)

    def triples(self):
        """Return (row_keys, col_keys, values) triple arrays (D4M find)."""
        coo = self.sm.tocoo()
        order = np.lexsort((coo.col, coo.row))
        r, c, d = coo.row[order], coo.col[order], coo.data[order]
        vals = (self.val[(d - 1).astype(np.int64)]
                if self.val is not None else d)
        return self.row[r], self.col[c], vals

    def getval(self):
        return self.triples()[2]

    def __len__(self):
        return self.nnz

    def __bool__(self):
        return self.nnz > 0

    def copy(self) -> "Assoc":
        return Assoc._from_parts(self.row.copy(), self.col.copy(),
                                 None if self.val is None else self.val.copy(),
                                 self.sm.copy())

    # ------------------------------------------------------------------
    # selection
    # ------------------------------------------------------------------
    def __getitem__(self, idx) -> "Assoc":
        rsel, csel = idx if isinstance(idx, tuple) else (idx, All())
        ri = K.resolve_selector(rsel, self.row)
        ci = K.resolve_selector(csel, self.col)
        sub = self.sm[ri][:, ci].tocsr()
        out = Assoc._from_parts(self.row[ri], self.col[ci], self.val, sub)
        return out._compact()

    def row_select(self, sel) -> "Assoc":
        return self[sel, All()]

    def col_select(self, sel) -> "Assoc":
        return self[All(), sel]

    # ------------------------------------------------------------------
    # algebra
    # ------------------------------------------------------------------
    def _union_keys(self, other: "Assoc"):
        row = np.union1d(self.row, other.row)
        col = np.union1d(self.col, other.col)
        return row, col

    def _promote(self, row, col):
        """Re-index payload onto superset key dictionaries."""
        return self._onto(row, col, numeric=False)

    def _onto(self, row, col, numeric: bool = True):
        """Project the payload onto arbitrary key dictionaries: entries
        whose keys are absent from the targets are dropped; the rest are
        re-indexed.  This is the correct alignment for key-intersected
        matmul and key-unioned addition alike."""
        import scipy.sparse as sp

        def keymap(sub: np.ndarray, target: np.ndarray) -> np.ndarray:
            if target.shape[0] == 0 or sub.shape[0] == 0:
                return np.full(sub.shape[0], -1, np.int64)
            pos = np.searchsorted(target, sub)
            pos_c = np.clip(pos, 0, target.shape[0] - 1)
            hit = target[pos_c] == sub
            return np.where(hit, pos_c, -1).astype(np.int64)

        sm = self._numeric_sm() if numeric else self.sm
        coo = sm.tocoo()
        rmap = keymap(self.row, np.asarray(row))
        cmap = keymap(self.col, np.asarray(col))
        rr, cc = rmap[coo.row], cmap[coo.col]
        m = (rr >= 0) & (cc >= 0)
        return sp.csr_matrix(
            (coo.data[m], (rr[m], cc[m])),
            shape=(np.asarray(row).shape[0], np.asarray(col).shape[0]))

    def __add__(self, other) -> "Assoc":
        if isinstance(other, (int, float)):
            out = self.copy()
            out.sm.data = out._numeric_sm().data + other
            out.val = None
            return out
        if self.val is not None or other.val is not None:
            # categorical union-add: collide via lexicographic min
            r1, c1, v1 = self.triples()
            r2, c2, v2 = other.triples()
            return Assoc(np.concatenate([r1, r2]), np.concatenate([c1, c2]),
                         np.concatenate([v1.astype(str), v2.astype(str)]),
                         agg="min")
        row, col = self._union_keys(other)
        sm = self._promote(row, col) + other._promote(row, col)
        return Assoc._from_parts(row, col, None, sm)._compact()

    def __sub__(self, other) -> "Assoc":
        row, col = self._union_keys(other)
        sm = self._numeric_sm_promoted(row, col) - \
            other._numeric_sm_promoted(row, col)
        return Assoc._from_parts(row, col, None, sm)._compact()

    def _numeric_sm_promoted(self, row, col):
        return self._onto(row, col, numeric=True)

    def multiply(self, other: "Assoc") -> "Assoc":
        """Element-wise (Hadamard) product on intersected keys."""
        row = np.intersect1d(self.row, other.row)
        col = np.intersect1d(self.col, other.col)
        a = self._onto(row, col)
        b = other._onto(row, col)
        return Assoc._from_parts(row, col, None, a.multiply(b))._compact()

    def __and__(self, other) -> "Assoc":
        return self.logical().multiply(other.logical())

    def __or__(self, other) -> "Assoc":
        return (self.logical() + other.logical()).logical()

    def __mul__(self, other) -> "Assoc":
        """Semiring (+.*) array multiply with key-aligned inner dimension.

        D4M aligns the inner dimension by key *intersection*: only columns
        of A that are also rows of B contribute (paper Fig. 2 semantics).
        """
        if isinstance(other, (int, float)):
            out = self.copy()
            out.sm = out._numeric_sm() * other
            out.val = None
            return out
        inner = np.intersect1d(self.col, other.row)
        a = self._onto(self.row, inner)
        b = other._onto(inner, other.col)
        sm = a @ b
        return Assoc._from_parts(self.row, other.col, None, sm)._compact()

    __rmul__ = __mul__

    def sqin(self) -> "Assoc":
        """A' * A — column-key correlation (graph from incidence: who
        shares a packet). The paper's adjacency construction."""
        return self.transpose() * self

    def sqout(self) -> "Assoc":
        """A * A' — row-key correlation."""
        return self * self.transpose()

    def transpose(self) -> "Assoc":
        return Assoc._from_parts(self.col, self.row, self.val,
                                 self.sm.T.tocsr())

    @property
    def T(self) -> "Assoc":
        return self.transpose()

    def sum(self, axis: int) -> "Assoc":
        """Semiring row/col sums. axis=1 sums across columns (out-degree);
        axis=0 down rows (in-degree) — `sum(E,1)` / `sum(E,2)` of stage 6."""
        m = self._numeric_sm()
        if axis in (1, 2):  # accept MATLAB's 2 for "across columns"
            v = np.asarray(m.sum(axis=1)).ravel()
            keep = v != 0
            return Assoc._from_parts(self.row[keep], np.asarray([""]), None,
                                     S.scipy_from_triples(
                                         np.arange(keep.sum()),
                                         np.zeros(keep.sum(), np.int64),
                                         v[keep], (int(keep.sum()), 1)))
        v = np.asarray(m.sum(axis=0)).ravel()
        keep = v != 0
        return Assoc._from_parts(np.asarray([""]), self.col[keep], None,
                                 S.scipy_from_triples(
                                     np.zeros(keep.sum(), np.int64),
                                     np.arange(keep.sum()),
                                     v[keep], (1, int(keep.sum()))))

    def logical(self) -> "Assoc":
        """spones — every stored entry becomes numeric 1."""
        out = self._numeric_sm().copy()
        out.data = np.ones_like(out.data)
        return Assoc._from_parts(self.row, self.col, None, out)

    # comparison filters (D4M: A > 5 keeps passing entries)
    def _filter(self, pred: Callable[[np.ndarray], np.ndarray]) -> "Assoc":
        r, c, v = self.triples()
        if self.val is None:
            m = pred(v)
        else:
            m = pred(v.astype(str))
        return Assoc(r[m], c[m], v[m]) if m.any() else Assoc()

    def __gt__(self, x):
        return self._filter(lambda v: v > x)

    def __ge__(self, x):
        return self._filter(lambda v: v >= x)

    def __lt__(self, x):
        return self._filter(lambda v: v < x)

    def __le__(self, x):
        return self._filter(lambda v: v <= x)

    def __eq__(self, x):  # noqa: D105 — D4M filter semantics, not identity
        if isinstance(x, Assoc):
            return (self.nnz == x.nnz and np.array_equal(self.row, x.row)
                    and np.array_equal(self.col, x.col)
                    and np.array_equal(np.asarray(self.triples()[2], dtype=str),
                                       np.asarray(x.triples()[2], dtype=str)))
        return self._filter(lambda v: v == x)

    __hash__ = None

    # ------------------------------------------------------------------
    # value/key rewriting (paper's ingest idioms)
    # ------------------------------------------------------------------
    def putval(self, val) -> "Assoc":
        """Overwrite every stored value — `putVal(E,'1,')` of stage 6."""
        r, c, _ = self.triples()
        vv = K.parse_keys(val)
        if vv.shape[0] == 1:
            vv = np.repeat(vv, r.shape[0])
        return Assoc(r, c, vv)

    def putcol(self, col) -> "Assoc":
        """Overwrite column keys — `putCol(sum(E',2),'degree,')`."""
        r, _, v = self.triples()
        cc = K.parse_keys(col)
        if cc.shape[0] == 1:
            cc = np.repeat(cc, r.shape[0])
        return Assoc(r, cc, v)

    def putrow(self, row) -> "Assoc":
        _, c, v = self.triples()
        rr = K.parse_keys(row)
        if rr.shape[0] == 1:
            rr = np.repeat(rr, c.shape[0])
        return Assoc(rr, c, v)

    def num2str(self) -> "Assoc":
        """Numeric → categorical string values (paper: num2str(Edeg))."""
        r, c, v = self.triples()
        sv = np.asarray([f"{x:g}" for x in np.asarray(v, dtype=np.float64)],
                        dtype=str)
        return Assoc(r, c, sv)

    def str2num(self) -> "Assoc":
        r, c, v = self.triples()
        return Assoc(r, c, np.asarray(v, dtype=np.float64))

    # ------------------------------------------------------------------
    # schema ops (delegates; see repro.core.schema)
    # ------------------------------------------------------------------
    def val2col(self, sep: str = "|") -> "Assoc":
        from . import schema
        return schema.val2col(self, sep)

    def col2val(self, sep: str = "|") -> "Assoc":
        from . import schema
        return schema.col2val(self, sep)

    # ------------------------------------------------------------------
    # deferred algebra bridge
    # ------------------------------------------------------------------
    def lazy(self) -> "LazyAssoc":
        """Wrap into a deferred expression (see :mod:`repro.core.expr`):
        subsequent algebra builds an operator DAG that a planner fuses
        and executes in one pass."""
        from .expr import LazyAssoc
        return LazyAssoc.leaf(self)

    # ------------------------------------------------------------------
    # device bridge
    # ------------------------------------------------------------------
    def device_coo(self, dtype=None) -> S.COO:
        """Export the numeric payload as a JAX COO for jit'd analytics."""
        import jax.numpy as jnp
        coo = self._numeric_sm().tocoo()
        order = np.lexsort((coo.col, coo.row))
        vals = coo.data[order]
        if dtype is not None:
            vals = vals.astype(dtype)
        return S.COO(jnp.asarray(coo.row[order], jnp.int32),
                     jnp.asarray(coo.col[order], jnp.int32),
                     jnp.asarray(vals), self.shape)

    # ------------------------------------------------------------------
    # io / display
    # ------------------------------------------------------------------
    def __repr__(self):
        r, c, v = self.triples()
        lines = [f"Assoc {self.shape[0]}x{self.shape[1]} nnz={self.nnz}"
                 + (" (categorical)" if self.val is not None else "")]
        show = min(self.nnz, 12)
        for i in range(show):
            lines.append(f"  ({r[i]}, {c[i]})  {v[i]}")
        if self.nnz > show:
            lines.append(f"  ... {self.nnz - show} more")
        return "\n".join(lines)

    def save(self, path: str) -> None:
        """Atomic save (tmp + rename) — safe under the runner's
        speculative re-execution: concurrent writers of identical
        content cannot tear the file."""
        import os
        import threading
        r, c, v = self.triples()
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp.npz"
        np.savez_compressed(tmp, rows=r, cols=c,
                            vals=np.asarray(v),
                            categorical=self.val is not None)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "Assoc":
        z = np.load(path, allow_pickle=False)
        vals = z["vals"]
        if z["categorical"]:
            vals = vals.astype(str)
        return cls(z["rows"].astype(str), z["cols"].astype(str), vals)


# convenience re-exports used all over the pipeline code
All = K.All
StartsWith = K.StartsWith
KeyRange = K.KeyRange
