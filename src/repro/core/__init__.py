"""repro.core — D4M associative arrays, semiring sparse algebra, schema.

The paper's primary contribution as a composable JAX library:

* :class:`Assoc` — string-keyed associative arrays (paper §II-B).
* :mod:`repro.core.sparse` — device COO/CSR payloads + semiring SpMV/SpMM.
* :mod:`repro.core.schema` — the D4M exploded schema (val2col/col2val).
* :mod:`repro.core.graph` — incidence→adjacency, degree tables, PageRank.
"""
from .assoc import All, Assoc, KeyRange, StartsWith
from .expr import LazyAssoc, eval_batch, lazy, lazy_batch
from .schema import col2val, parse_tsv, to_tsv, val2col
from .semiring import (MAX_MIN, MAX_PLUS, MAX_TIMES, MIN_PLUS, OR_AND,
                       PLUS_TIMES, Semiring)
from .sparse import COO, CSR, coo_to_csr, csr_to_coo, col_degree, row_degree, \
    spmm, spmv, spmv_t
from . import graph

__all__ = [
    "Assoc", "All", "KeyRange", "StartsWith", "LazyAssoc", "lazy",
    "lazy_batch", "eval_batch",
    "parse_tsv", "to_tsv", "val2col", "col2val",
    "Semiring", "PLUS_TIMES", "MIN_PLUS", "MAX_PLUS", "MAX_MIN", "MAX_TIMES",
    "OR_AND",
    "COO", "CSR", "coo_to_csr", "csr_to_coo", "spmv", "spmv_t", "spmm",
    "row_degree", "col_degree", "graph",
]
