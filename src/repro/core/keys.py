"""Key handling for associative arrays.

D4M indexes arrays by arbitrary totally-ordered key sets — almost always
strings ("1.1.1.1", "ip.src|63.237.205.194", packet IDs).  This module
holds the host-side (numpy) machinery: parsing D4M's delimiter-terminated
key strings, canonical sorted-unique dictionaries, and the selector
objects used in subscripting (ranges, prefixes).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Union

import numpy as np

# D4M convention: a single string whose *last* character is the delimiter
# encodes a key list, e.g. 'a,b,c,' or 'ip.src|1.2.3.4|'.
KeysLike = Union[str, bytes, int, float, Sequence, np.ndarray]


def parse_keys(keys: KeysLike) -> np.ndarray:
    """Normalize any key spec to a 1-D numpy unicode array (not uniqued)."""
    if isinstance(keys, np.ndarray):
        if keys.dtype.kind in "US":
            return keys.astype(str)
        return keys.astype(str)
    if isinstance(keys, bytes):
        keys = keys.decode()
    if isinstance(keys, str):
        if len(keys) == 0:
            return np.empty((0,), dtype="U1")
        sep = keys[-1]
        parts = keys.split(sep)[:-1]  # trailing sep → drop final empty
        return np.asarray(parts, dtype=str)
    if isinstance(keys, (int, float, np.integer, np.floating)):
        return np.asarray([keys], dtype=str) if isinstance(keys, float) \
            else np.asarray([str(keys)])
    if isinstance(keys, Iterable):
        return np.asarray([k.decode() if isinstance(k, bytes) else str(k)
                           for k in keys], dtype=str)
    raise TypeError(f"cannot interpret keys from {type(keys)!r}")


def unique_keys(keys: KeysLike) -> tuple[np.ndarray, np.ndarray]:
    """Return (sorted-unique dictionary, index of each input key)."""
    arr = parse_keys(keys)
    uniq, inv = np.unique(arr, return_inverse=True)
    return uniq, inv.astype(np.int64)


# ---------------------------------------------------------------------------
# Selectors — the things that can appear in A[rsel, csel].
# ---------------------------------------------------------------------------

class Selector:
    def mask(self, dictionary: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class All(Selector):
    """The ':' selector."""

    def mask(self, dictionary: np.ndarray) -> np.ndarray:
        return np.ones(dictionary.shape[0], dtype=bool)


@dataclasses.dataclass(frozen=True)
class KeyRange(Selector):
    """Inclusive lexicographic range — D4M's 'a,:,b,'."""
    start: str
    stop: str

    def mask(self, dictionary: np.ndarray) -> np.ndarray:
        return (dictionary >= self.start) & (dictionary <= self.stop)


@dataclasses.dataclass(frozen=True)
class StartsWith(Selector):
    """Prefix scan — D4M's StartsWith('ip.src|,'); how one selects all
    columns of a given field in the exploded schema."""
    prefix: str

    def mask(self, dictionary: np.ndarray) -> np.ndarray:
        n = len(self.prefix)
        if n == 0:
            return np.ones(dictionary.shape[0], dtype=bool)
        # Vectorized prefix test on the sorted dictionary via range trick:
        # keys with this prefix form a contiguous lexicographic band.
        lo = np.searchsorted(dictionary, self.prefix, side="left")
        hi = np.searchsorted(dictionary, self.prefix + "￿", side="right")
        m = np.zeros(dictionary.shape[0], dtype=bool)
        m[lo:hi] = True
        return m


def resolve_selector(sel, dictionary: np.ndarray) -> np.ndarray:
    """Map a user selector to integer indices into ``dictionary``.

    Accepts: ':' / slice(None) / Selector / key list (string forms per
    parse_keys) / boolean mask / integer array.
    """
    if isinstance(sel, str) and sel == ":":
        sel = All()
    if sel is None or (isinstance(sel, slice) and sel == slice(None)):
        sel = All()
    if isinstance(sel, Selector):
        return np.nonzero(sel.mask(dictionary))[0]
    if isinstance(sel, np.ndarray) and sel.dtype == bool:
        return np.nonzero(sel)[0]
    if isinstance(sel, np.ndarray) and sel.dtype.kind in "iu":
        return sel.astype(np.int64)
    # D4M range string: 'a,:,b,'
    if isinstance(sel, str):
        parts = parse_keys(sel)
        if parts.shape[0] == 3 and parts[1] == ":":
            return np.nonzero(KeyRange(str(parts[0]), str(parts[2]))
                              .mask(dictionary))[0]
    wanted = parse_keys(sel)
    if dictionary.shape[0] == 0 or wanted.shape[0] == 0:
        return np.empty((0,), np.int64)
    # D4M prefix atoms: a key ending in '*' selects every key with that
    # prefix ('ip.src|*,' → the whole ip.src column block).
    stars = np.char.endswith(wanted, "*")
    if stars.any():
        m = np.zeros(dictionary.shape[0], dtype=bool)
        for k, is_prefix in zip(wanted, stars):
            if is_prefix:
                m |= StartsWith(str(k[:-1])).mask(dictionary)
            else:
                m |= dictionary == k
        return np.nonzero(m)[0]
    idx = np.searchsorted(dictionary, wanted)
    idx = np.clip(idx, 0, max(dictionary.shape[0] - 1, 0))
    hit = dictionary[idx] == wanted
    # sorted-unique: result arrays must keep the sorted-dictionary
    # invariant every other Assoc path (and _onto alignment) relies on
    return np.unique(idx[hit]).astype(np.int64)
