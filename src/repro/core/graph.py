"""Graph construction and device-side graph algebra.

The incidence matrix ``E`` (packets × field|value columns) produced by
the D4M schema directly encodes the network graph: selecting the
``ip.src|*`` block and the ``ip.dst|*`` block and correlating them
(``E_src' * E_dst``) yields the directed source→destination adjacency
matrix (paper §IV-E/F, and Fig. 2's "find 1.1.1.1's connections").

Host-side functions operate on :class:`Assoc` (exact, string-keyed);
device-side functions operate on :class:`repro.core.sparse.COO` under
``jit``/``shard_map`` — these are the hot loops the Pallas kernels
accelerate on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .assoc import Assoc, StartsWith
from . import sparse as S


# ---------------------------------------------------------------------------
# Host-side (Assoc) graph construction — mirrors the paper's D4M listings.
# ---------------------------------------------------------------------------

def adjacency(E: Assoc, src_field: str = "ip.src", dst_field: str = "ip.dst",
              sep: str = "|") -> Assoc:
    """Directed adjacency  A[src, dst] = #packets  from the incidence matrix."""
    # columns are field|value ⇒ select column blocks:
    Esrc = E[:, StartsWith(f"{src_field}{sep}")]
    Edst = E[:, StartsWith(f"{dst_field}{sep}")]
    A = Esrc.T * Edst  # (src values) × (dst values), packet-count weighted
    # strip the 'field|' prefixes so keys are bare IPs
    r, c, v = A.triples()
    strip = len(src_field) + len(sep)
    stripd = len(dst_field) + len(sep)
    return Assoc(np.asarray([k[strip:] for k in r], dtype=str),
                 np.asarray([k[stripd:] for k in c], dtype=str), v)


def square(A: Assoc) -> Assoc:
    """Promote to a square array over the union of row/col keys (needed
    before spectral/PageRank work on a directed adjacency)."""
    nodes = np.union1d(A.row, A.col)
    sm = A._numeric_sm_promoted(nodes, nodes)
    return Assoc._from_parts(nodes, nodes, None, sm)


def connections(E: Assoc, ip: str, src_field: str = "ip.src",
                dst_field: str = "ip.dst", sep: str = "|") -> Assoc:
    """Fig. 2's operation: every host that ``ip`` talked to (either
    direction), as a packet-count-valued associative array."""
    out_pkts = E[:, [f"{src_field}{sep}{ip}"]]
    in_pkts = E[:, [f"{dst_field}{sep}{ip}"]]
    # packets involving ip → all their other endpoint columns
    touched = (out_pkts.sum(1) + in_pkts.sum(1)).logical()  # packets × ['']
    sel = touched.T * E  # 1 × columns, counts per field|value
    return sel[:, StartsWith(f"{dst_field}{sep}")] + \
        sel[:, StartsWith(f"{src_field}{sep}")]


def degree_table(E: Assoc) -> Assoc:
    """``TedgeDeg``: per-column-key degree (stage 6's
    ``Edeg = putCol(sum(E.',2),'degree,')``)."""
    return E.T.sum(1).putcol("degree,")


# ---------------------------------------------------------------------------
# Device-side (COO) graph algebra — jit'd, semiring-generic, shardable.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_iters",))
def pagerank(adj: S.COO, num_iters: int = 20, damping: float = 0.85) -> jax.Array:
    """PageRank on a directed adjacency COO (Bottrack-style botnet
    centrality, paper ref [23]).  Dangling mass redistributed uniformly."""
    n = adj.shape[0]
    out_deg = S.row_degree(adj, weighted=True)
    inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1e-30), 0.0)
    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)

    def body(rank, _):
        contrib = rank * inv_deg
        spread = S.spmv_t(adj, contrib)  # mass flows src→dst
        dangling = jnp.sum(jnp.where(out_deg > 0, 0.0, rank))
        rank_new = (1 - damping) / n + damping * (spread + dangling / n)
        return rank_new, None

    rank, _ = jax.lax.scan(body, rank, None, length=num_iters)
    return rank


@jax.jit
def triangle_count(adj: S.COO, probe: jax.Array) -> jax.Array:
    """Randomized triangle-mass estimate  ≈ tr(A³)/6 via Hutchinson probes
    (z' A³ z).  ``probe``: (n, k) ±1.  Used as a density anomaly score."""
    az = S.spmm(adj, probe)
    aaz = S.spmm(adj, az)
    aaaz = S.spmm(adj, aaz)
    return jnp.mean(jnp.sum(probe * aaaz, axis=0)) / 6.0


@jax.jit
def degree_counts(m: S.COO) -> tuple[jax.Array, jax.Array]:
    """(row_degrees, col_degrees) of an incidence/adjacency payload."""
    return S.row_degree(m), S.col_degree(m)


def bfs_reachable(adj: S.COO, seed: jax.Array, hops: int = 3) -> jax.Array:
    """Boolean k-hop reachability via the or_and semiring (command-and-
    control spread estimation)."""
    frontier = seed.astype(jnp.float32)

    def body(f, _):
        nxt = S.spmv_t(adj, f, ring="or_and")
        return jnp.maximum(f, nxt), None

    out, _ = jax.lax.scan(body, frontier, None, length=hops)
    return out > 0
