"""Fault-tolerant parallel work-queue runner (the SuperCloud scheduler analog).

The paper scales by mapping idempotent file→file tasks over thousands of
cores with a dynamic scheduler.  This runner provides the same contract
for a production deployment:

* **Checkpoint/restart** — every completion is journaled (JSONL, fsync'd);
  a restarted run skips journaled tasks.  Combined with atomic-rename
  outputs, a node can die at any instant without corrupting state.
* **Straggler mitigation** — speculative re-execution: when a task's
  runtime exceeds ``straggler_factor × p95`` of completed tasks (and a
  worker is idle), a backup copy is issued; first finisher wins.
* **Retries / fault injection** — worker crashes (simulated via
  :class:`FaultInjector` in tests) re-queue the task up to ``max_retries``.
* **Elasticity** — ``set_workers(n)`` grows/shrinks the pool while a run
  is in flight (workers drain at task boundaries).

Tasks form a DAG via ``deps``; the runner schedules any task whose
dependencies are journaled complete.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Task:
    task_id: str
    fn: Callable[[], object]          # idempotent
    deps: tuple = ()                  # "*" = every non-barrier task
    stage: str = ""                   # for per-stage stats
    # Durability coupling for tasks whose side effects are *enqueued*
    # rather than applied (async ingest): a defer_commit task completes
    # for scheduling purposes but is journaled only when a commit_point
    # task (the flush barrier, where the writes are actually applied and
    # fsync'd) finishes.  A crash in between leaves the task unjournaled,
    # so a restart re-runs it and the writes are replayed.
    defer_commit: bool = False
    commit_point: bool = False


@dataclasses.dataclass
class TaskRecord:
    task_id: str
    elapsed: float
    worker: int
    result: object = None


class WorkerKilled(RuntimeError):
    """Raised by fault injection to simulate a node failure mid-task."""


class FaultInjector:
    """Deterministically kills a fraction of task executions (tests)."""

    def __init__(self, kill_rate: float = 0.0, seed: int = 0,
                 max_kills: Optional[int] = None):
        self.kill_rate = kill_rate
        self.rng = np.random.default_rng(seed)
        self.max_kills = max_kills
        self.kills = 0
        self._lock = threading.Lock()

    def maybe_kill(self, task_id: str) -> None:
        with self._lock:
            if self.max_kills is not None and self.kills >= self.max_kills:
                return
            if self.rng.random() < self.kill_rate:
                self.kills += 1
                raise WorkerKilled(f"injected fault in {task_id}")


class Journal:
    """Append-only JSONL completion log — the restart checkpoint."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self.done: Dict[str, dict] = {}
        self._lock = threading.Lock()
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    rec = json.loads(line)
                    self.done[rec["task_id"]] = rec

    def commit(self, task_id: str, elapsed: float, stage: str) -> None:
        rec = {"task_id": task_id, "elapsed": elapsed, "stage": stage,
               "t": time.time()}
        with self._lock:
            self.done[task_id] = rec
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    try:
                        os.fsync(f.fileno())
                    except OSError:
                        pass  # non-regular file (/dev/null, some tmpfs)


class Runner:
    def __init__(self, n_workers: int = 4, journal_path: Optional[str] = None,
                 straggler_factor: float = 3.0, straggler_min_s: float = 0.25,
                 max_retries: int = 3, fault_injector: Optional[FaultInjector] = None,
                 speculative: bool = True):
        self.journal = Journal(journal_path)
        self.n_workers_target = n_workers
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self.max_retries = max_retries
        self.fault = fault_injector
        self.speculative = speculative
        # run state
        self._q: "queue.Queue[Task]" = queue.Queue()
        self._lock = threading.Lock()
        self._done: Dict[str, TaskRecord] = {}
        self._inflight: Dict[str, float] = {}   # task_id → start time
        self._retries: Dict[str, int] = {}
        self._speculated: set = set()
        self._failed: Dict[str, str] = {}
        self._elapsed_hist: List[float] = []
        self._deferred: List[tuple] = []    # (tid, elapsed, stage) awaiting
        self.stats: Dict[str, dict] = {}    # a commit-point task

    # -- elasticity ---------------------------------------------------------
    def set_workers(self, n: int) -> None:
        self.n_workers_target = n

    # -- deferred journaling -----------------------------------------------
    def commit_deferred(self) -> None:
        """Journal every completed defer_commit task.  Fired when a
        commit-point task finishes (its deps guarantee they all ran);
        also callable by the driver after an out-of-band commit — e.g.
        a restart whose barrier task was journaled in a *previous* run,
        where only the driver's trailing flush covers the fresh writes."""
        with self._lock:
            batch, self._deferred = self._deferred, []
        for tid, elapsed, stage in batch:
            self.journal.commit(tid, elapsed, stage)

    # -- core loop ------------------------------------------------------------
    def run(self, tasks: Sequence[Task]) -> Dict[str, TaskRecord]:
        # Barrier tasks: deps containing "*" expand to every non-barrier
        # task — the driver uses this for the end-of-DAG writer flush
        # (async ingest's commit point).
        plain_ids = tuple(t.task_id for t in tasks if "*" not in t.deps)
        tasks = [
            dataclasses.replace(
                t, deps=tuple(d for d in t.deps if d != "*")
                + tuple(i for i in plain_ids if i != t.task_id))
            if "*" in t.deps else t
            for t in tasks]
        by_id = {t.task_id: t for t in tasks}
        pending = {t.task_id for t in tasks
                   if t.task_id not in self.journal.done}
        for tid in set(by_id) - pending:  # restored from journal
            rec = self.journal.done[tid]
            self._done[tid] = TaskRecord(tid, rec["elapsed"], -1)

        def ready(t: Task) -> bool:
            return all(d in self._done or d in self.journal.done
                       for d in t.deps)

        scheduled: set = set()

        def schedule_ready():
            with self._lock:
                for tid in sorted(pending - scheduled):
                    if ready(by_id[tid]):
                        self._q.put(by_id[tid])
                        scheduled.add(tid)

        stop = threading.Event()
        workers: List[threading.Thread] = []

        def worker(wid: int):
            while not stop.is_set():
                if wid >= self.n_workers_target:  # elastic shrink
                    return
                try:
                    task = self._q.get(timeout=0.05)
                except queue.Empty:
                    continue
                tid = task.task_id
                with self._lock:
                    if tid in self._done:       # speculative duplicate lost
                        continue
                    self._inflight[tid] = time.time()
                t_start = time.time()
                try:
                    if self.fault is not None:
                        self.fault.maybe_kill(tid)
                    result = task.fn()
                except WorkerKilled:
                    with self._lock:
                        self._inflight.pop(tid, None)
                        n = self._retries.get(tid, 0) + 1
                        self._retries[tid] = n
                        if n <= self.max_retries:
                            self._q.put(task)   # re-issue (restart semantics)
                        else:
                            self._failed[tid] = "retries exhausted"
                            pending.discard(tid)
                    continue
                except Exception as e:  # hard task failure
                    with self._lock:
                        self._inflight.pop(tid, None)
                        n = self._retries.get(tid, 0) + 1
                        self._retries[tid] = n
                        if n <= self.max_retries:
                            self._q.put(task)
                        else:
                            self._failed[tid] = repr(e)
                            pending.discard(tid)
                    continue
                elapsed = time.time() - t_start
                first = False
                with self._lock:
                    if tid not in self._done:   # first finisher wins
                        first = True
                        self._done[tid] = TaskRecord(tid, elapsed, wid, result)
                        self._inflight.pop(tid, None)
                        pending.discard(tid)
                        self._elapsed_hist.append(elapsed)
                        st = self.stats.setdefault(
                            task.stage, {"n": 0, "total_s": 0.0})
                        st["n"] += 1
                        st["total_s"] += elapsed
                        if task.defer_commit:
                            # same locked section that marks the task
                            # done: a barrier firing the instant we
                            # release the lock must already see this
                            # entry, or the task stays unjournaled
                            self._deferred.append(
                                (tid, elapsed, task.stage))
                if first:
                    # journal/scheduling errors must never kill a worker
                    # (the task is already recorded done)
                    try:
                        if task.commit_point:
                            # the deferred tasks' writes are durable now
                            # (the barrier flushed + fsync'd them): journal
                            # them first, then the barrier itself, so a
                            # crash mid-commit never records the barrier
                            # without its ingests
                            self.commit_deferred()
                        if not task.defer_commit:
                            self.journal.commit(tid, elapsed, task.stage)
                    except Exception:
                        pass
                    schedule_ready()

        def supervisor():
            """Speculative re-execution of stragglers."""
            while not stop.is_set():
                time.sleep(0.05)
                if not self.speculative:
                    continue
                with self._lock:
                    if len(self._elapsed_hist) < 4:
                        continue
                    p95 = float(np.percentile(self._elapsed_hist, 95))
                    deadline = max(self.straggler_factor * p95,
                                   self.straggler_min_s)
                    now = time.time()
                    for tid, t0 in list(self._inflight.items()):
                        if now - t0 > deadline and tid not in self._speculated:
                            self._speculated.add(tid)
                            self._q.put(by_id[tid])  # backup copy

        schedule_ready()
        max_pool = max(self.n_workers_target, 1)
        for wid in range(max_pool):
            th = threading.Thread(target=worker, args=(wid,), daemon=True)
            th.start()
            workers.append(th)
        sup = threading.Thread(target=supervisor, daemon=True)
        sup.start()

        try:
            while pending:
                time.sleep(0.01)
                with self._lock:
                    # elastic grow: top up the pool
                    alive = sum(th.is_alive() for th in workers)
                if alive < self.n_workers_target:
                    for wid in range(alive, self.n_workers_target):
                        th = threading.Thread(target=worker, args=(wid,),
                                              daemon=True)
                        th.start()
                        workers.append(th)
                if self._failed and not self._inflight and self._q.empty():
                    break
        finally:
            stop.set()
        for th in workers:
            th.join(timeout=2.0)
        if self._failed:
            raise RuntimeError(f"tasks failed permanently: {self._failed}")
        return dict(self._done)
