"""repro.pipeline — the paper's six-stage PCAP → database pipeline."""
from .driver import PipelineConfig, build_tasks, run_pipeline
from .pcap import TrafficConfig, botnet_truth, read_pcap, synth_packets, \
    write_pcap
from .runner import FaultInjector, Journal, Runner, Task, WorkerKilled
from . import stages

__all__ = [
    "PipelineConfig", "build_tasks", "run_pipeline",
    "TrafficConfig", "synth_packets", "write_pcap", "read_pcap",
    "botnet_truth", "Runner", "Task", "Journal", "FaultInjector",
    "WorkerKilled", "stages",
]
