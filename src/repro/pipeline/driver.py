"""End-to-end pipeline driver — the paper's Fig. 4 as a task DAG.

``build_tasks`` wires N capture files through
uncompress → split → parse → sort → sparse → ingest with per-file
dependency chains; ``run_pipeline`` executes the DAG on the runner and
returns per-stage timing/size stats (the data behind Fig. 5 and the
expansion-factor table).

This module (plus ~10 lines of user script, see examples/pcap_pipeline.py)
is the analog of the paper's "135 lines of D4M code".
"""
from __future__ import annotations

import dataclasses
import glob
import os
from typing import Dict, List, Optional

from . import pcap as P
from . import stages
from .runner import FaultInjector, Runner, Task


@dataclasses.dataclass
class PipelineConfig:
    workdir: str
    n_files: int = 4                    # capture files (paper: 385)
    duration_per_file_s: float = 0.05   # paper: ~15 min per file
    split_size: int = 64 * 1024         # paper: 5 MB
    traffic: P.TrafficConfig = dataclasses.field(default_factory=P.TrafficConfig)
    n_workers: int = 4
    journal: Optional[str] = None       # default: <workdir>/journal.jsonl


def build_tasks(cfg: PipelineConfig, db) -> List[Task]:
    os.makedirs(cfg.workdir, exist_ok=True)
    tasks: List[Task] = []
    results: Dict[str, stages.StageResult] = {}

    def record(tid):
        def deco(fn):
            def wrapped():
                res = fn()
                results[tid] = res
                return res
            return wrapped
        return deco

    for i in range(cfg.n_files):
        raw = os.path.join(cfg.workdir, f"capture{i:04d}.pcap.gz")
        tcfg = dataclasses.replace(cfg.traffic, seed=cfg.traffic.seed + i)
        t0 = 1_492_000_000.0 + i * cfg.duration_per_file_s

        gen_id = f"generate/{i}"
        tasks.append(Task(gen_id, record(gen_id)(
            lambda raw=raw, tcfg=tcfg, t0=t0:
                stages.generate(raw, tcfg, cfg.duration_per_file_s, t0)),
            stage="generate"))

        unc_id = f"uncompress/{i}"
        tasks.append(Task(unc_id, record(unc_id)(
            lambda raw=raw: stages.uncompress(raw)),
            deps=(gen_id,), stage="uncompress"))

        spl_id = f"split/{i}"
        tasks.append(Task(spl_id, record(spl_id)(
            lambda raw=raw: stages.split(raw[:-3], cfg.split_size)),
            deps=(unc_id,), stage="split"))

        # The split fan-out is data-dependent; downstream per-chunk work is
        # built lazily inside one task per (file, stage) that maps its chunks.
        def chain(i=i, raw=raw, spl_id=spl_id):
            def parse_all():
                outs = []
                r_in = r_out = 0
                for part in sorted(glob.glob(raw[:-8] + ".split*.pcap")):
                    res = stages.parse(part)
                    outs += res.outputs
                    r_in += res.bytes_in
                    r_out += res.bytes_out
                return stages.StageResult(outs, r_in, r_out)

            def map_stage(fn, pattern):
                def run():
                    outs = []
                    r_in = r_out = 0
                    for part in sorted(glob.glob(pattern)):
                        res = fn(part)
                        outs += res.outputs
                        r_in += res.bytes_in
                        r_out += res.bytes_out
                    return stages.StageResult(outs, r_in, r_out)
                return run

            par_id = f"parse/{i}"
            srt_id = f"sort/{i}"
            sps_id = f"sparse/{i}"
            ing_id = f"ingest/{i}"
            tasks.append(Task(par_id, record(par_id)(parse_all),
                              deps=(spl_id,), stage="parse"))
            tasks.append(Task(srt_id, record(srt_id)(map_stage(
                stages.sort_stage, raw[:-8] + ".split*.pcap.tsv")),
                deps=(par_id,), stage="sort"))
            tasks.append(Task(sps_id, record(sps_id)(map_stage(
                stages.sparse_stage, raw[:-8] + ".split*.pcap.tsv.A.npz")),
                deps=(srt_id,), stage="sparse"))
            # defer_commit: ingest only *enqueues* writes, so its journal
            # entry is committed at the flush barrier (where the writes
            # are applied and fsync'd) — a crash in between re-runs the
            # ingest on restart instead of silently losing the writes
            tasks.append(Task(ing_id, record(ing_id)(map_stage(
                lambda p: stages.ingest(p, db),
                raw[:-8] + ".split*.pcap.tsv.A.E.npz")),
                deps=(sps_id,), stage="ingest", defer_commit=True))
        chain()

    # flush barrier: ingest tasks only *enqueue* writes (async writer
    # pool); this task is the commit point where all queued mutations
    # are applied (and fsync'd on durable backends) — and where any
    # writer error surfaces.  commit_point: the runner journals the
    # deferred ingest tasks only once this barrier completes.
    flush_id = "flush/writers"

    def flush_writers():
        from ..db.binding import bind
        bind(db).flush()
        return stages.StageResult([], 0, 0)

    tasks.append(Task(flush_id, record(flush_id)(flush_writers),
                      deps=("*",), stage="flush", commit_point=True))

    # expose per-task results on the task list for the driver to collect
    build_tasks.results = results  # type: ignore[attr-defined]
    return tasks


def run_pipeline(cfg: PipelineConfig, db,
                 fault_injector: Optional[FaultInjector] = None,
                 n_workers: Optional[int] = None) -> dict:
    journal = cfg.journal or os.path.join(cfg.workdir, "journal.jsonl")
    tasks = build_tasks(cfg, db)
    runner = Runner(n_workers=n_workers or cfg.n_workers,
                    journal_path=journal, fault_injector=fault_injector)
    runner.run(tasks)
    # the flush barrier task is journaled like any other; on a partial
    # restart it may be skipped while fresh ingest tasks enqueued new
    # writes — flush again here so run_pipeline never returns with
    # queued (or, on durable backends, un-fsync'd) mutations, then
    # journal any ingest tasks whose commit was deferred to a barrier
    # that only ran in a previous incarnation
    from ..db.binding import bind
    bind(db).flush()
    runner.commit_deferred()
    results = build_tasks.results  # type: ignore[attr-defined]
    per_stage: Dict[str, dict] = {}
    for tid, res in results.items():
        stage = tid.split("/")[0]
        st = per_stage.setdefault(stage, {"bytes_in": 0, "bytes_out": 0,
                                          "n_tasks": 0})
        st["bytes_in"] += res.bytes_in
        st["bytes_out"] += res.bytes_out
        st["n_tasks"] += 1
    for stage, timing in runner.stats.items():
        per_stage.setdefault(stage, {}).update(timing)
    return {"stages": per_stage, "db_entries": db.n_entries}
