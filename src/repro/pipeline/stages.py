"""The paper's six pipeline stages (§III-B, §IV-A..F), file → file.

Every stage is **idempotent** (outputs written via atomic rename), which
is what makes the runner's straggler re-issue and crash-restart sound:
a re-executed task simply overwrites identical bytes.

Stage semantics mirror the paper exactly:

1. ``uncompress`` — gunzip the raw capture (2 GB → 6 GB per file there;
   compression ratio here depends on the synthetic data).
2. ``split``      — cut the pcap into ~``split_size`` chunks (paper: 5 MB)
   so later stages parallelize; each chunk is a *valid* pcap.
3. ``parse``      — tshark analog: binary pcap → TSV with the paper's
   field set (§III-A listing).
4. ``sort``       — TSV → **dense** associative array; the time field is
   restructured (bucketed to whole seconds) so the exploded schema's
   column space stays bounded; array is saved sorted (construction sorts).
5. ``sparse``     — ``E = val2col(A,'|')``: dense table → incidence matrix.
6. ``ingest``     — ``put(Tedge, putVal(E,'1,'))`` + degree table insert.
"""
from __future__ import annotations

import dataclasses
import gzip
import os
from typing import List, Optional

import numpy as np

from ..core.assoc import Assoc
from ..core.schema import parse_tsv, val2col
from . import pcap as P


@dataclasses.dataclass
class StageResult:
    outputs: List[str]
    bytes_in: int
    bytes_out: int


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


# --------------------------------------------------------------------------
# Stage 0 (setup, not in the paper's count): capture-appliance emulation.
# --------------------------------------------------------------------------

def generate(path: str, cfg: P.TrafficConfig, duration_s: float,
             t0: float = 1_492_000_000.0) -> StageResult:
    rec = P.synth_packets(cfg, duration_s, t0=t0)
    n = P.write_pcap(path, rec, compress=True)
    return StageResult([path], 0, n)


# --------------------------------------------------------------------------
# Stage 1: uncompress  (paper: `system(['gunzip -k ' ... '.pcap.gz'])`)
# --------------------------------------------------------------------------

def uncompress(src: str) -> StageResult:
    assert src.endswith(".pcap.gz"), src
    dst = src[: -len(".gz")]
    with gzip.open(src, "rb") as f:
        data = f.read()
    _atomic_write(dst, data)
    return StageResult([dst], os.path.getsize(src), len(data))


# --------------------------------------------------------------------------
# Stage 2: split  (paper: tcpdump → ~5 MB chunks, split ID appended)
# --------------------------------------------------------------------------

def split(src: str, split_size: int = 5 * 2**20) -> StageResult:
    with open(src, "rb") as f:
        buf = f.read()
    ghdr = buf[: P._GLOBAL_HDR.itemsize]
    body = buf[P._GLOBAL_HDR.itemsize:]
    rec_size = P.REC_DTYPE.itemsize
    per_chunk = max(split_size // rec_size, 1)
    n_rec = len(body) // rec_size
    outputs = []
    total_out = 0
    for j, start in enumerate(range(0, n_rec, per_chunk)):
        chunk = body[start * rec_size:(start + per_chunk) * rec_size]
        dst = f"{src[:-5]}.split{j:05d}.pcap"
        _atomic_write(dst, ghdr + chunk)
        outputs.append(dst)
        total_out += len(ghdr) + len(chunk)
    return StageResult(outputs, len(buf), total_out)


# --------------------------------------------------------------------------
# Stage 3: parse  (tshark analog — binary → TSV, paper's field filter)
# --------------------------------------------------------------------------

def parse(src: str, t0: Optional[float] = None) -> StageResult:
    rec = P.read_pcap(src)
    base = os.path.basename(src)
    tsv = P.records_to_tsv(rec, t0=t0, pkt_prefix=base + "|")
    dst = src + ".tsv"
    _atomic_write(dst, tsv.encode())
    return StageResult([dst], os.path.getsize(src), len(tsv))


# --------------------------------------------------------------------------
# Stage 4: sort — dense associative array construction
# --------------------------------------------------------------------------

def sort_stage(src: str) -> StageResult:
    with open(src, "rb") as f:
        text = f.read().decode()
    A = parse_tsv(text)
    # "restructure the time field": bucket frame.time to whole seconds so
    # the exploded column space stays bounded (near-unique values would
    # otherwise make one column per packet).
    if A.nnz:
        r, c, v = A.triples()
        tmask = c == "frame.time"
        if tmask.any():
            v = v.astype(object)
            secs = np.asarray(
                [f"{float(x):.0f}" for x in v[tmask]], dtype=object)
            v[tmask] = secs
            v = v.astype(str)
        rmask = c == "frame.time_relative"  # drop per-packet-unique field
        A = Assoc(r[~rmask], c[~rmask], v[~rmask])
    dst = src + ".A.npz"
    A.save(dst)
    return StageResult([dst], os.path.getsize(src), os.path.getsize(dst))


# --------------------------------------------------------------------------
# Stage 5: sparse — `E = val2col(A,'|')` (incidence matrix)
# --------------------------------------------------------------------------

def sparse_stage(src: str) -> StageResult:
    A = Assoc.load(src)
    E = val2col(A, "|")
    dst = src[: -len(".npz")] + ".E.npz"
    E.save(dst)
    return StageResult([dst], os.path.getsize(src), os.path.getsize(dst))


# --------------------------------------------------------------------------
# Stage 6: ingest — put(Tedge, putVal(E,'1,')) + degree table
# --------------------------------------------------------------------------

def ingest(src: str, db) -> StageResult:
    from ..db.binding import bind, put

    E = Assoc.load(src)
    # paper: put(Tedge, putVal(E,'1,')) through the D4M binding — batched
    # writers, file→instance routing on multi-instance backends.
    # paper: Edeg = putCol(sum(E.',2),'degree,'); put(TedgeDeg, num2str(Edeg))
    # (the store's sum combiner maintains TedgeDeg during the same put)
    # sync=False: batches enqueue to the backend's writer pool so tablet
    # mutation overlaps the runner's parse/sort tasks; the driver's
    # end-of-DAG flush barrier is the commit point.
    n = put(bind(db), E.putval("1,"), file_id=src, sync=False)
    return StageResult([], os.path.getsize(src), n)
