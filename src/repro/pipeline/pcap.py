"""libpcap codec + synthetic MAWI-like traffic generator.

The MAWI DITL traces used in the paper are not redistributable, so the
framework ships a calibrated generator producing *real* libpcap files
(magic ``0xa1b2c3d4``, LINKTYPE_RAW=101 ⇒ packets are bare IPv4, headers
40 bytes = 20 IP + 20 TCP exactly as the paper states).  The parse stage
is therefore a genuine binary protocol parser (tshark analog), not a mock.

Traffic model (matching the paper's observed structure):
* host popularity ~ Zipf (the power-law background the analytics model),
* exponential inter-arrival at ~``pkt_rate`` packets/s (paper: >100k/s on 1 GbE),
* heavy-tailed packet lengths,
* an injected botnet: ``n_bots`` clients beaconing a C2 server on a fixed
  port with low-jitter periodicity — the anomaly the analytics must find.
"""
from __future__ import annotations

import dataclasses
import gzip
import os
from typing import Iterator, Optional

import numpy as np

PCAP_MAGIC = 0xA1B2C3D4
LINKTYPE_RAW = 101  # bare IP — 40-byte headers as in the paper
SNAPLEN = 40        # header capture only, like MAWI header traces

_GLOBAL_HDR = np.dtype([
    ("magic", "<u4"), ("vmaj", "<u2"), ("vmin", "<u2"),
    ("thiszone", "<i4"), ("sigfigs", "<u4"),
    ("snaplen", "<u4"), ("network", "<u4"),
])

# pcap record header (little-endian) + IPv4 + TCP headers (big-endian wire)
REC_DTYPE = np.dtype([
    ("ts_sec", "<u4"), ("ts_usec", "<u4"),
    ("incl_len", "<u4"), ("orig_len", "<u4"),
    ("ver_ihl", "u1"), ("tos", "u1"), ("tot_len", ">u2"),
    ("ip_id", ">u2"), ("frag", ">u2"),
    ("ttl", "u1"), ("proto", "u1"), ("ip_csum", ">u2"),
    ("src", ">u4"), ("dst", ">u4"),
    ("sport", ">u2"), ("dport", ">u2"),
    ("seq", ">u4"), ("ack", ">u4"),
    ("off_flags", ">u2"), ("win", ">u2"),
    ("tcp_csum", ">u2"), ("urg", ">u2"),
])
assert REC_DTYPE.itemsize == 16 + 40


@dataclasses.dataclass
class TrafficConfig:
    n_hosts: int = 4096
    zipf_a: float = 1.3            # popularity exponent (power-law background)
    pkt_rate: float = 100_000.0    # packets/s (paper: 10 GbE ≈ >100k pkt/s)
    tcp_fraction: float = 0.9
    # botnet injection
    n_bots: int = 24
    beacon_period_s: float = 30.0
    beacon_jitter_s: float = 0.5
    c2_port: int = 6667
    seed: int = 0


def _ip_pool(n_hosts: int, rng: np.random.Generator) -> np.ndarray:
    """Random public-looking IPv4 addresses as uint32."""
    ips = rng.integers(0x0B000000, 0xDF000000, size=n_hosts, dtype=np.uint64)
    return np.unique(ips.astype(np.uint32))


def synth_packets(cfg: TrafficConfig, duration_s: float,
                  t0: float = 1_492_000_000.0) -> np.ndarray:
    """Generate a time-sorted structured record array of packet headers."""
    rng = np.random.default_rng(cfg.seed)
    pool = _ip_pool(cfg.n_hosts, rng)
    n = max(int(cfg.pkt_rate * duration_s), 16)

    # --- background traffic: Zipf-popular destinations, uniform-ish sources
    ranks = np.arange(1, pool.shape[0] + 1, dtype=np.float64)
    pop = ranks ** (-cfg.zipf_a)
    pop /= pop.sum()
    dst = rng.choice(pool, size=n, p=pop)
    src = rng.choice(pool, size=n, p=np.roll(pop, pool.shape[0] // 3))
    # avoid self-talk
    same = src == dst
    src[same] = np.roll(src[same], 1) if same.sum() > 1 else pool[0]

    ts = t0 + np.sort(rng.uniform(0.0, duration_s, size=n))
    length = np.minimum(
        40 + rng.pareto(1.2, size=n).astype(np.int64) * 64, 1500)
    proto = np.where(rng.random(n) < cfg.tcp_fraction, 6, 17).astype(np.uint8)
    sport = rng.integers(1024, 65535, size=n, dtype=np.uint32).astype(np.uint16)
    well_known = np.asarray([80, 443, 53, 22, 25, 8080], dtype=np.uint16)
    dport = well_known[rng.integers(0, well_known.shape[0], size=n)]
    flags = np.full(n, 0x5010, dtype=np.uint16)  # data_off=5, ACK

    # --- botnet: bots beacon the C2 host periodically on c2_port.
    # Drawn from an independent RNG stream so botnet_truth() can replay it.
    rng_bot = np.random.default_rng([cfg.seed, 0xB07])
    c2 = pool[rng_bot.integers(0, pool.shape[0])]
    bots = rng_bot.choice(pool[pool != c2], size=cfg.n_bots, replace=False)
    beat_times, beat_src = [], []
    for b in bots:
        t = rng_bot.uniform(0, cfg.beacon_period_s)
        while t < duration_s:
            beat_times.append(t0 + t)
            beat_src.append(b)
            t += cfg.beacon_period_s + rng_bot.normal(0, cfg.beacon_jitter_s)
    nb = len(beat_times)
    if nb:
        ts = np.concatenate([ts, np.asarray(beat_times)])
        src = np.concatenate([src, np.asarray(beat_src, dtype=np.uint32)])
        dst = np.concatenate([dst, np.full(nb, c2, dtype=np.uint32)])
        length = np.concatenate([length, np.full(nb, 60)])
        proto = np.concatenate([proto, np.full(nb, 6, np.uint8)])
        sport = np.concatenate(
            [sport, rng.integers(40000, 50000, nb).astype(np.uint16)])
        dport = np.concatenate(
            [dport, np.full(nb, cfg.c2_port, dtype=np.uint16)])
        flags = np.concatenate([flags, np.full(nb, 0x5018, np.uint16)])  # PSH|ACK

    order = np.argsort(ts, kind="stable")
    rec = np.zeros(ts.shape[0], dtype=REC_DTYPE)
    rec["ts_sec"] = ts[order].astype(np.uint64).astype(np.uint32)
    rec["ts_usec"] = ((ts[order] % 1.0) * 1e6).astype(np.uint32)
    rec["incl_len"] = SNAPLEN
    rec["orig_len"] = length[order]
    rec["ver_ihl"] = 0x45
    rec["tot_len"] = np.minimum(length[order], 65535)
    rec["ttl"] = 64
    rec["proto"] = proto[order]
    rec["src"] = src[order]
    rec["dst"] = dst[order]
    rec["sport"] = sport[order]
    rec["dport"] = dport[order]
    rec["off_flags"] = flags[order]
    rec["win"] = 65535
    return rec


def write_pcap(path: str, rec: np.ndarray, compress: bool = False) -> int:
    """Serialize records to a real libpcap file (optionally .gz)."""
    hdr = np.zeros(1, dtype=_GLOBAL_HDR)
    hdr["magic"] = PCAP_MAGIC
    hdr["vmaj"], hdr["vmin"] = 2, 4
    hdr["snaplen"] = SNAPLEN
    hdr["network"] = LINKTYPE_RAW
    payload = hdr.tobytes() + rec.tobytes()
    opener = gzip.open if compress else open
    tmp = path + ".tmp"
    with opener(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)  # atomic — idempotent under task re-issue
    return len(payload)


def read_pcap(path: str) -> np.ndarray:
    """Parse a libpcap file back into the structured record array."""
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        buf = f.read()
    hdr = np.frombuffer(buf[:_GLOBAL_HDR.itemsize], dtype=_GLOBAL_HDR)[0]
    if hdr["magic"] != PCAP_MAGIC:
        raise ValueError(f"{path}: bad pcap magic {hdr['magic']:#x}")
    if hdr["network"] != LINKTYPE_RAW or hdr["snaplen"] != SNAPLEN:
        raise ValueError(f"{path}: unsupported linktype/snaplen")
    body = buf[_GLOBAL_HDR.itemsize:]
    if len(body) % REC_DTYPE.itemsize:
        body = body[: len(body) - len(body) % REC_DTYPE.itemsize]
    return np.frombuffer(body, dtype=REC_DTYPE)


def ip_str(ip_u32: np.ndarray) -> np.ndarray:
    """Vectorized uint32 → dotted-quad strings."""
    ip = np.asarray(ip_u32, dtype=np.uint32)
    a = (ip >> 24) & 0xFF
    b = (ip >> 16) & 0xFF
    c = (ip >> 8) & 0xFF
    d = ip & 0xFF
    out = np.char.add(np.char.add(a.astype("U3"), "."), b.astype("U3"))
    out = np.char.add(np.char.add(out, "."), c.astype("U3"))
    return np.char.add(np.char.add(out, "."), d.astype("U3"))


# paper §III-A listing — the tshark field set we extract
TSV_FIELDS = ("frame.time_relative", "frame.time", "ip.dst", "ip.len",
              "ip.proto", "ip.src", "tcp.dstport", "tcp.flags", "tcp.srcport")


def records_to_tsv(rec: np.ndarray, t0: Optional[float] = None,
                   pkt_prefix: str = "") -> str:
    """tshark analog: binary records → TSV with the paper's field set."""
    if rec.shape[0] == 0:
        return "id\t" + "\t".join(TSV_FIELDS) + "\n"
    ts = rec["ts_sec"].astype(np.float64) + rec["ts_usec"] * 1e-6
    if t0 is None:
        t0 = float(ts[0])
    rel = ts - t0
    cols = {
        "frame.time_relative": np.char.mod("%.9f", rel),
        "frame.time": np.char.mod("%.6f", ts),
        "ip.dst": ip_str(rec["dst"]),
        "ip.len": rec["orig_len"].astype("U6"),
        "ip.proto": rec["proto"].astype("U3"),
        "ip.src": ip_str(rec["src"]),
        "tcp.dstport": rec["dport"].astype("U5"),
        "tcp.flags": np.asarray([f"0x{x:08x}" for x in rec["off_flags"]]),
        "tcp.srcport": rec["sport"].astype("U5"),
    }
    ids = np.char.add(pkt_prefix,
                      np.char.zfill(np.arange(rec.shape[0]).astype("U9"), 9))
    body = ids
    for f in TSV_FIELDS:
        body = np.char.add(np.char.add(body, "\t"), cols[f])
    return "id\t" + "\t".join(TSV_FIELDS) + "\n" + "\n".join(body) + "\n"


def botnet_truth(cfg: TrafficConfig) -> dict:
    """Recompute the injected C2/bot identities (deterministic in seed) —
    the ground truth the analytics layer is validated against."""
    pool = _ip_pool(cfg.n_hosts, np.random.default_rng(cfg.seed))
    rng_bot = np.random.default_rng([cfg.seed, 0xB07])
    c2 = pool[rng_bot.integers(0, pool.shape[0])]
    bots = rng_bot.choice(pool[pool != c2], size=cfg.n_bots, replace=False)
    return {
        "c2": str(ip_str(np.asarray([c2]))[0]),
        "bots": [str(s) for s in ip_str(bots)],
        "c2_port": cfg.c2_port,
    }
