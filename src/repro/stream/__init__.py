"""repro.stream — streaming temporal analytics over async ingest.

Hierarchical time-bucket rollups riding the WriterPool ingest tap
(:mod:`.windows`), online detectors with root-cause localization
(:mod:`.detectors`), and the seeded synthetic traffic scenario harness
that grounds them in known truth (:mod:`.synth`).
"""
from .windows import LEVEL_SECONDS, TemporalRollup, WindowSummary
from .detectors import AlertReport, DetectorBank, RootCauseReport, \
    StreamAnalytics, WesternElectric, root_cause
from .synth import AttackSpec, ScenarioConfig, records_to_incidence, \
    scenario_incidence, scenario_truth, stream_blocks, synth_scenario

__all__ = [
    "LEVEL_SECONDS", "TemporalRollup", "WindowSummary",
    "AlertReport", "DetectorBank", "RootCauseReport", "StreamAnalytics",
    "WesternElectric", "root_cause",
    "AttackSpec", "ScenarioConfig", "records_to_incidence",
    "scenario_incidence", "scenario_truth", "stream_blocks",
    "synth_scenario",
]
