"""Hierarchical time-bucket rollups over the async-ingest triple stream.

:class:`TemporalRollup` consumes the exact triple blocks the
:class:`~repro.db.writer.WriterPool` coalesces — registered as an ingest
tap (``DBTable.add_ingest_tap``) it observes every block *as it drains*,
so the streaming aggregates ride the write path with no extra table
scan.  Triples are attributed to **hierarchical time buckets**
(packet → second → minute → hour); each level accumulates its own
Assoc-compatible aggregate (cell/packet counts, unique src/dst support,
per-key degree sketches), so the conservation law *child buckets sum
exactly to their parent* is a real cross-check of the attribution, not
an artifact of derivation.

On close, each bucket is summarized — including a per-level
**scaling-relation** fit (rank-size power law of the destination-degree
distribution via the existing :func:`~repro.analytics.powerlaw.
fit_rank_size`), the paper's observation that sub-sampled traffic
windows obey the same heavy-tailed background as the whole trace.

Timestamps come from the incidence schema itself: every packet row
carries exactly one ``frame.time|<epoch>`` column (``val2col``
explosion).  A block may arrive *before* the block holding its rows'
time triples (``put(batch_size=...)`` slicing can split a packet across
blocks), so unattributed triples park in a bounded pending map keyed by
row and drain the moment the row's timestamp is learned.

Thread-safety: ``ingest`` is called from WriterPool writer threads (one
per pool instance) and only parks block references under the rollup
lock — O(1), no parsing or copying on the write path.  Readers
(``summaries``, ``totals``, ``slice``, ``close_due``) drain the parked
backlog under the same lock before reading, so they always see every
block ingested before the call.  A backlog past ``max_backlog_blocks``
drains inline on the writer thread: a slow consumer still
backpressures ingest, just amortized — same contract as a slow
accumulator combiner.
"""
from __future__ import annotations

import threading
import weakref
from collections import Counter, OrderedDict
from typing import Dict, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

from ..analytics.powerlaw import fit_rank_size
from ..analytics.serialize import JsonReportMixin
from ..core.assoc import Assoc
from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label

_M_LATE = _REGISTRY.counter(
    "repro_stream_late_total",
    "Triples attributed to already-closed buckets", labels=("rollup",))
_M_BACKLOG = _REGISTRY.gauge(
    "repro_stream_backlog_blocks",
    "Ingest-tap blocks parked awaiting a reader drain", labels=("rollup",))

#: level name → bucket width in seconds (hierarchy must nest exactly:
#: every width divides the next one up, or conservation is vacuous).
LEVEL_SECONDS: "OrderedDict[str, float]" = OrderedDict(
    [("second", 1.0), ("minute", 60.0), ("hour", 3600.0)])


class WindowSummary(NamedTuple):
    """One closed bucket, flattened to the JSON-report shape the gateway
    ships from ``/v1/windows`` (same serialize path as C2Report)."""
    level: str
    start: float               # bucket start (epoch seconds, aligned)
    width: float               # bucket width in seconds
    n_cells: int               # triples attributed (= table cells)
    n_packets: int             # distinct packets (one frame.time each)
    n_src: int                 # unique ip.src keys
    n_dst: int                 # unique ip.dst keys
    max_dst_deg: float         # busiest destination's packet count
    top_dst: str
    top_dst_share: float       # max_dst_deg / total dst packet mass
    alpha: float               # rank-size exponent of dst degrees (NaN
    r2: float                  # when too few keys to fit), and fit R²
    truncated: bool            # slice retention clipped (counts exact)

    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


class _Bucket:
    """One live bucket at one level: exact counters plus (base level
    only) the retained triples backing ``slice()``.

    Retention is by *reference*, not copy: ``chunks`` holds
    ``(r, c, v, idx)`` where ``idx`` is an integer index array into the
    ingested block's arrays (``None`` = the whole block), and
    ``deg_pending`` holds ``(c, idx)`` pairs the degree fold has not
    consumed yet.  The write path therefore never gathers or
    prefix-matches the unicode arrays — that materialization happens on
    the read side (``TemporalRollup._fold_deg`` / ``slice``), keeping
    the expensive string ops off the WriterPool drain loop, which
    carries the tap's <10% ingest overhead budget.  Buckets sharing a
    block share its arrays, so a block stays alive until every bucket
    referencing it is evicted."""
    __slots__ = ("start", "n_cells", "n_packets", "deg", "deg_pending",
                 "chunks", "slice_cells", "truncated", "closed")

    def __init__(self, start: float):
        self.start = start
        self.n_cells = 0
        self.n_packets = 0
        self.deg: Counter = Counter()    # full col key → packet count
        self.deg_pending: list = []      # [(cols, idx)] not yet folded
        self.chunks: list = []           # [(r, c, v, idx)] — base only
        self.slice_cells = 0
        self.truncated = False
        self.closed = False


class _DegreeView:
    """Duck-typed ``degree_assoc(prefix)`` view of one bucket's degree
    sketch — makes a rollup bucket a drop-in for
    :func:`~repro.analytics.powerlaw.fit_degree_table`, which normally
    reads a DBTable's combiner-maintained TedgeDeg."""

    def __init__(self, bucket: _Bucket):
        self._deg = bucket.deg

    def degree_assoc(self, prefix: str = "") -> Assoc:
        items = sorted((k, v) for k, v in self._deg.items()
                       if k.startswith(prefix))
        if not items:
            return Assoc()
        keys = np.asarray([k for k, _ in items], dtype=str)
        vals = np.asarray([float(v) for _, v in items])
        return Assoc(keys, np.repeat(np.asarray(["degree"]), len(items)),
                     vals)


def _pow2_pad(d: np.ndarray) -> np.ndarray:
    """Zero-pad a degree vector to the next power-of-two length: zeros
    carry zero weight in ``fit_rank_size``, so alpha is unchanged, and
    the jit cache sees O(log n) shapes instead of one per window."""
    n = max(int(d.shape[0]), 1)
    target = 1 << (n - 1).bit_length()
    return np.pad(d, (0, target - d.shape[0]))


class TemporalRollup:
    """Streaming hierarchical time-bucket aggregation (see module doc).

    Parameters
    ----------
    levels : ordered level names from :data:`LEVEL_SECONDS` (base first).
    time_field : the schema field carrying the packet timestamp; matched
        as ``f"{time_field}{sep}"`` exactly, so ``frame.time_relative|``
        columns (same field-name prefix) are *not* mistaken for it.
    lateness_s : watermark lag — a bucket closes only once the max
        observed timestamp clears its end by this much.
    track_prefixes : column bands kept in the per-bucket degree sketch.
    slice_cells_per_bucket : base-level triple retention cap backing
        ``slice()``; beyond it the bucket is marked truncated (counter
        aggregates stay exact).
    max_row_ts / max_pending_rows : bounds on the row→timestamp map and
        the park-until-timestamp pending map (LRU/FIFO evicted; evicted
        pending triples count as unattributed, never silently vanish).
    max_backlog_blocks : ingest-deferral bound — blocks the write path
        may park unprocessed before it must drain them inline (readers
        drain on every call, so this only binds with no reader polling).
    """

    def __init__(self, levels: Iterable[str] = ("second", "minute", "hour"),
                 sep: str = "|", time_field: str = "frame.time",
                 lateness_s: float = 2.0,
                 track_prefixes: Iterable[str] = ("ip.src", "ip.dst",
                                                  "tcp.dstport"),
                 slice_cells_per_bucket: int = 2_000_000,
                 max_row_ts: int = 1_000_000,
                 max_pending_rows: int = 100_000,
                 max_summaries: int = 4096,
                 max_buckets: int = 8192,
                 fit_min_keys: int = 4,
                 max_backlog_blocks: int = 64):
        widths = []
        for lv in levels:
            if lv not in LEVEL_SECONDS:
                raise ValueError(f"unknown level {lv!r} "
                                 f"(have {list(LEVEL_SECONDS)})")
            widths.append((lv, LEVEL_SECONDS[lv]))
        widths.sort(key=lambda p: p[1])
        for (_, wa), (_, wb) in zip(widths, widths[1:]):
            if wb % wa:
                raise ValueError("levels must nest exactly")
        self.levels: Tuple[Tuple[str, float], ...] = tuple(widths)
        self.base_level = widths[0][0]
        self._base_width = widths[0][1]
        self.sep = sep
        self.time_field = time_field
        self._time_prefix = f"{time_field}{sep}"
        self.lateness_s = float(lateness_s)
        self.track_prefixes = tuple(f"{p}{sep}" for p in track_prefixes)
        self.slice_cells_per_bucket = int(slice_cells_per_bucket)
        self.max_row_ts = int(max_row_ts)
        self.max_pending_rows = int(max_pending_rows)
        self.max_summaries = int(max_summaries)
        self.max_buckets = int(max_buckets)
        self.fit_min_keys = int(fit_min_keys)
        self.max_backlog_blocks = int(max_backlog_blocks)

        self._lock = threading.RLock()
        # write-path deferral: ingest() parks block references here and
        # returns; any read drains it (see _drain_locked).  Bounded —
        # the cap forces an inline drain, so a slow consumer still
        # backpressures ingest, just amortized over the backlog.
        self._backlog: list = []
        self._buckets: Dict[str, Dict[float, _Bucket]] = \
            {lv: {} for lv, _ in self.levels}
        self._summaries: Dict[str, "OrderedDict[float, WindowSummary]"] = \
            {lv: OrderedDict() for lv, _ in self.levels}
        self._row_ts: "OrderedDict[str, float]" = OrderedDict()
        self._pending: Dict[str, list] = {}
        self._n_pending = 0
        # eviction remainders: totals() stays exact for counts even after
        # old closed buckets (and their degree sketches/chunks) age out
        self._evicted: Dict[str, Dict[str, int]] = \
            {lv: {"n_cells": 0, "n_packets": 0, "n_buckets": 0}
             for lv, _ in self.levels}

        # counters (exactness bookkeeping — see stats())
        self.n_blocks = 0
        self.n_ingested = 0          # triples seen
        self.n_attributed = 0        # triples placed in buckets (×1/level)
        self.n_unattributed = 0      # evicted pending: timestamp never seen
        self.max_ts = -np.inf
        self.metrics_label = _obj_label("rollup")
        # attributed-after-bucket-close counter, registry-backed
        self._m_late = _M_LATE.labels(rollup=self.metrics_label)
        self._m_backlog = _M_BACKLOG.labels(rollup=self.metrics_label)
        ref = weakref.ref(self)
        self._m_backlog.set_function(lambda: len(ref()._backlog))

    @property
    def n_late(self) -> int:
        """Triples attributed after their bucket closed (registry-backed
        compat shape)."""
        return self._m_late.value

    # ---------------------------------------------------------- ingest

    def ingest(self, r, c, v) -> None:
        """Tap entry point — one coalesced triple block as WriterPool
        drains it.  Called from writer threads; O(1): the block's array
        references park in a bounded backlog and all processing happens
        on the *reader's* thread at the next ``totals``/``summaries``/
        ``slice``/``close_due``/``stats`` call (or inline here once the
        backlog hits ``max_backlog_blocks`` — amortized backpressure).
        This is what keeps the tap inside its <10% ingest-overhead
        budget: the write path never parses, matches, or copies a
        string."""
        with self._lock:
            self._backlog.append((r, c, v))
            if len(self._backlog) >= self.max_backlog_blocks:
                self._drain_locked()

    def _drain_locked(self) -> None:
        """Process every parked block in arrival order (lock held)."""
        backlog, self._backlog = self._backlog, []
        for r, c, v in backlog:
            r, c = (a if isinstance(a, np.ndarray) and a.dtype.kind == "U"
                    else np.asarray(a, dtype=str) for a in (r, c))
            v = np.asarray(v)  # only stored (slice chunks), never parsed
            if r.shape[0]:
                self._ingest_locked(r, c, v)

    def _ingest_locked(self, r, c, v) -> None:
        self.n_blocks += 1
        self.n_ingested += int(r.shape[0])

        # 1. learn row → timestamp from this block's time triples; the
        # epoch parse runs through numpy's C float parser, with a
        # per-cell fallback only if some cell is malformed
        tp = self._time_prefix
        k = len(tp)
        is_time = np.char.startswith(c, tp)
        newly: list = []
        if is_time.any():
            t_rows = r[is_time].tolist()
            t_strs = [s[k:] for s in c[is_time].tolist()]
            try:
                t_vals = np.asarray(t_strs, dtype=np.float64).tolist()
            except ValueError:       # drop malformed cells, keep the rest
                keep_rows, t_vals = [], []
                for row, s in zip(t_rows, t_strs):
                    try:
                        t_vals.append(float(s))
                        keep_rows.append(row)
                    except ValueError:
                        continue
                t_rows = keep_rows
            if t_vals:
                if self._pending:
                    newly = [row for row in t_rows
                             if row in self._pending]
                self._row_ts.update(zip(t_rows, t_vals))
                m = max(t_vals)
                if m > self.max_ts:
                    self.max_ts = m
                while len(self._row_ts) > self.max_row_ts:
                    self._row_ts.popitem(last=False)

        # 2. resolve each triple's timestamp through the row map.  A
        # packet's cells sit adjacent in its put's sorted triples, so
        # grouping identical *runs* gets ~one lookup per packet without
        # np.unique's argsort; a row split across non-adjacent runs just
        # pays a second dict hit.
        if r.shape[0] > 1:
            bounds = np.r_[0, 1 + np.nonzero(r[1:] != r[:-1])[0]]
        else:
            bounds = np.zeros(1, dtype=np.intp)
        runs = np.diff(np.r_[bounds, r.shape[0]])
        ts_u = np.fromiter(
            (self._row_ts.get(k, np.nan) for k in r[bounds].tolist()),
            dtype=np.float64, count=bounds.shape[0])
        ts = np.repeat(ts_u, runs)
        known = ~np.isnan(ts)

        # 3. park triples whose row timestamp hasn't arrived yet
        if not known.all():
            for row, col, val in zip(r[~known], c[~known], v[~known]):
                self._pending.setdefault(row, []).append((col, val))
                self._n_pending += 1
            while (len(self._pending) > self.max_pending_rows
                   and self._pending):
                oldest = next(iter(self._pending))
                lost = self._pending.pop(oldest)
                self._n_pending -= len(lost)
                self.n_unattributed += len(lost)

        if known.all():
            self._attribute(r, c, v, ts, is_time)
        elif known.any():
            self._attribute(r[known], c[known], v[known], ts[known],
                            is_time[known])

        # 4. drain pending rows resolved by this block's time triples
        for row in newly:
            parked = self._pending.pop(row, None)
            if not parked:
                continue
            self._n_pending -= len(parked)
            pc = np.asarray([p[0] for p in parked], dtype=str)
            pv = np.asarray([p[1] for p in parked])
            pr = np.repeat(np.asarray([row], dtype=str), pc.shape[0])
            pts = np.full(pc.shape[0], self._row_ts[row])
            self._attribute(pr, pc, pv, pts,
                            np.char.startswith(pc, tp))

    def _attribute(self, r, c, v, ts, is_time) -> None:
        """Place timestamped triples into every level's bucket.  Each
        level accumulates independently from the same triples — that is
        what makes child-sums-to-parent a genuine invariant check.

        The write-path budget (``bench_stream``: the attached tap within
        10% of untapped ingest) rules out re-grouping per level: cells
        are grouped once at base granularity, and because coarser widths
        nest exactly (validated in ``__init__``), every base group lands
        whole in one parent bucket — the same scalar counts and one
        shared column-array reference update all levels.  Degree
        counting (prefix match + unique) is deferred to
        :meth:`_fold_deg` at close/read time."""
        self.n_attributed += int(r.shape[0])
        bw = self._base_width
        starts = np.floor(ts / bw) * bw
        # Zero string copies on the write path: a bucket stores *index
        # arrays* into the block's (r, c, v) — materialized only by the
        # read side (``slice`` / ``_fold_deg``).  Grouping runs on the
        # integer bucket ids (unique + bincount + argsort), never by
        # gathering the unicode arrays, whose memcpy dominates the tap
        # cost on coalesced blocks.  ``idx is None`` means the whole
        # block (the common one-bucket-per-put case: no sort at all).
        if starts.shape[0] > 1 and starts.min() != starts.max():
            uniq, inv = np.unique(starts, return_inverse=True)
            counts = np.bincount(inv)
            n_pks = np.bincount(inv[is_time], minlength=uniq.shape[0])
            order = np.argsort(inv, kind="stable")
            bnd = np.r_[0, np.cumsum(counts)]
            groups = [(float(uniq[i]), int(counts[i]), int(n_pks[i]),
                       order[bnd[i]:bnd[i + 1]])
                      for i in range(uniq.shape[0])]
        else:
            groups = [(float(starts[0]), int(starts.shape[0]),
                       int(np.count_nonzero(is_time)), None)]
        for s, n, n_pk, idx in groups:
            for level, width in self.levels:
                bs = float(np.floor(s / width) * width)
                buckets = self._buckets[level]
                b = buckets.get(bs)
                if b is None:
                    b = buckets[bs] = _Bucket(bs)
                if b.closed:
                    self._m_late.inc(n)
                b.n_cells += n
                b.n_packets += n_pk
                b.deg_pending.append((c, idx))
                if level == self.base_level:
                    if b.slice_cells + n <= self.slice_cells_per_bucket:
                        b.chunks.append((r, c, v, idx))
                        b.slice_cells += n
                    else:
                        b.truncated = True

    def _fold_deg(self, b: _Bucket) -> None:
        """Materialize a bucket's deferred degree increments (lock
        held).  Idempotent: pending arrays are consumed."""
        if not b.deg_pending:
            return
        parts = [cols if idx is None else cols[idx]
                 for cols, idx in b.deg_pending]
        b.deg_pending = []
        cols = parts[0] if len(parts) == 1 else np.concatenate(parts)
        tracked = np.zeros(cols.shape[0], dtype=bool)
        for pfx in self.track_prefixes:
            tracked |= np.char.startswith(cols, pfx)
        if tracked.any():
            ck, cn = np.unique(cols[tracked], return_counts=True)
            b.deg.update(dict(zip(ck.tolist(), cn.tolist())))

    # ----------------------------------------------------------- close

    @property
    def watermark(self) -> float:
        """Largest timestamp safe to close below: max seen − lateness."""
        with self._lock:
            self._drain_locked()
            return self.max_ts - self.lateness_s

    def close_due(self, now: Optional[float] = None,
                  force: bool = False) -> List[WindowSummary]:
        """Close every bucket whose end has passed the watermark (or all
        open buckets, with ``force`` — end-of-stream flush).  Returns the
        fresh summaries ordered by (width, start): base level first, so
        a consumer sees seconds before the minute containing them."""
        out: List[WindowSummary] = []
        with self._lock:
            self._drain_locked()
            wm = (self.max_ts - self.lateness_s if now is None
                  else now - self.lateness_s)
            for level, width in self.levels:
                for s in sorted(self._buckets[level]):
                    b = self._buckets[level][s]
                    if b.closed:
                        continue
                    if not force and s + width > wm:
                        break
                    b.closed = True
                    summ = self._summarize(level, width, b)
                    store = self._summaries[level]
                    store[s] = summ
                    while len(store) > self.max_summaries:
                        store.popitem(last=False)
                    out.append(summ)
                self._evict_locked(level)
        out.sort(key=lambda w: (w.width, w.start))
        return out

    def _evict_locked(self, level: str) -> None:
        """Age out the oldest *closed* buckets past ``max_buckets`` —
        base-level buckets retain triples, so retention must be bounded.
        Their counts roll into the eviction remainder so ``totals()``
        stays exact; their degree sketches and slices are gone."""
        buckets = self._buckets[level]
        if len(buckets) <= self.max_buckets:
            return
        ev = self._evicted[level]
        for s in sorted(buckets):
            if len(buckets) <= self.max_buckets:
                break
            b = buckets[s]
            if not b.closed:
                break                   # never evict ahead of the watermark
            ev["n_cells"] += b.n_cells
            ev["n_packets"] += b.n_packets
            ev["n_buckets"] += 1
            del buckets[s]

    def _summarize(self, level: str, width: float,
                   b: _Bucket) -> WindowSummary:
        self._fold_deg(b)
        src_pfx = f"ip.src{self.sep}"
        dst_pfx = f"ip.dst{self.sep}"
        n_src = n_dst = 0
        top_dst, max_deg, dst_mass = "", 0.0, 0.0
        dst_degs = []
        for k, n in b.deg.items():
            if k.startswith(src_pfx):
                n_src += 1
            elif k.startswith(dst_pfx):
                n_dst += 1
                dst_degs.append(float(n))
                dst_mass += n
                if n > max_deg:
                    max_deg, top_dst = float(n), k[len(dst_pfx):]
        alpha = r2 = float("nan")
        if len(dst_degs) >= self.fit_min_keys:
            fit = fit_rank_size(_pow2_pad(np.asarray(dst_degs,
                                                     np.float32)))
            alpha, r2 = float(fit.alpha), float(fit.r2)
        return WindowSummary(
            level=level, start=b.start, width=width,
            n_cells=b.n_cells, n_packets=b.n_packets,
            n_src=n_src, n_dst=n_dst, max_dst_deg=max_deg,
            top_dst=top_dst,
            top_dst_share=max_deg / dst_mass if dst_mass else 0.0,
            alpha=alpha, r2=r2, truncated=b.truncated)

    # ---------------------------------------------------------- access

    def summaries(self, level: str = "second", limit: int = 100,
                  since: Optional[float] = None) -> List[WindowSummary]:
        """Closed-window summaries for one level, oldest first."""
        with self._lock:
            self._drain_locked()
            items = list(self._summaries[level].values())
        if since is not None:
            items = [s for s in items if s.start >= since]
        return items[-limit:]

    def degree_view(self, level: str, start: float) -> _DegreeView:
        """A ``fit_degree_table``-compatible view of one bucket's degree
        sketch (``fit_degree_table(rollup.degree_view(...), "ip.dst|")``)."""
        with self._lock:
            self._drain_locked()
            b = self._buckets[level][start]
            self._fold_deg(b)
            return _DegreeView(b)

    def totals(self, level: str) -> dict:
        """Exact per-level totals over *all* buckets (open + closed) —
        the quantity the conservation and batch-recount checks compare."""
        with self._lock:
            self._drain_locked()
            ev = self._evicted[level]
            n_cells, n_packets = ev["n_cells"], ev["n_packets"]
            deg: Counter = Counter()
            for b in self._buckets[level].values():
                n_cells += b.n_cells
                n_packets += b.n_packets
                self._fold_deg(b)
                deg.update(b.deg)
            return {"n_cells": n_cells, "n_packets": n_packets,
                    "deg": deg, "n_evicted_buckets": ev["n_buckets"]}

    def slice(self, start: float, stop: float) -> Assoc:
        """The retained incidence sub-Assoc for ``[start, stop)`` —
        base-level chunks reassembled, bucket-aligned.  This is what the
        streaming detectors hand to ``c2_scores`` / ``scan_hits`` /
        ``pagerank_table``: an in-memory window, no table rescan."""
        width = dict(self.levels)[self.base_level]
        with self._lock:
            self._drain_locked()
            chunks = []
            for s, b in self._buckets[self.base_level].items():
                if s + width <= start or s >= stop:
                    continue
                chunks.extend(b.chunks)
        if not chunks:
            return Assoc()
        r = np.concatenate([ch[0] if ch[3] is None else ch[0][ch[3]]
                            for ch in chunks])
        c = np.concatenate([ch[1] if ch[3] is None else ch[1][ch[3]]
                            for ch in chunks])
        v = np.concatenate([ch[2] if ch[3] is None else ch[2][ch[3]]
                            for ch in chunks])
        return Assoc(r, c, v, agg="min")

    def stats(self) -> dict:
        with self._lock:
            self._drain_locked()
            open_b = {lv: sum(not b.closed for b in bs.values())
                      for lv, bs in self._buckets.items()}
            closed = {lv: len(s) for lv, s in self._summaries.items()}
            return {
                "n_blocks": self.n_blocks,
                "n_ingested": self.n_ingested,
                "n_attributed": self.n_attributed,
                "n_unattributed": self.n_unattributed,
                "n_late": self.n_late,
                "n_pending": self._n_pending,
                "n_row_ts": len(self._row_ts),
                "max_ts": None if self.max_ts == -np.inf
                else float(self.max_ts),
                "open_buckets": open_b,
                "closed_windows": closed,
            }
