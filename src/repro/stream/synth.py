"""Synthetic traffic scenario harness — reproducible streams with truth.

Every streaming detector in this package is benchmarked against *known*
ground truth: a scenario is a seeded mix of diurnal background traffic
plus injected attacks (C2 beaconing, port/host scans, DDoS bursts), and
:func:`synth_scenario` returns both the packet records and the labels —
which hosts attacked whom, over exactly which window.  The records are
the same ``REC_DTYPE`` structured arrays the pipeline's pcap codec
produces (``repro.pipeline.pcap``), so a scenario can be written to a
real libpcap file, run through the batch pipeline, or streamed
block-by-block into async ingest with :func:`stream_blocks`.

Background model (as in ``pcap.synth_packets``): Zipf-popular
destinations over a seeded host pool, well-known service ports, TCP-
dominated — but with the arrival rate modulated by a **diurnal load
curve** ``rate(t) = base_rate · (1 + amplitude · sin(2πt/period))``, the
slow non-stationarity the SPC detectors must *not* alarm on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from ..core.assoc import Assoc
from ..core.schema import parse_tsv, val2col
from ..pipeline.pcap import REC_DTYPE, _ip_pool, ip_str, records_to_tsv

_WELL_KNOWN = np.asarray([80, 443, 53, 22, 25, 8080], dtype=np.uint16)


@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """One injected attack.  ``kind`` selects the traffic shape:

    * ``'c2'`` — ``n_hosts`` bots beacon one C2 server every ``period_s``
      (± ``jitter_s``) on ``port`` for the whole window;
    * ``'scan'`` — one attacker touches ``rate`` fresh destinations per
      second, one SYN each (logical fan-out ≈ packet fan-out);
    * ``'ddos'`` — ``n_hosts`` attackers flood one victim at ``rate``
      packets/s *each* on ``port``.
    """
    kind: str                   # 'c2' | 'scan' | 'ddos'
    start: float                # seconds from scenario start
    duration: float
    n_hosts: int = 6
    rate: float = 50.0
    period_s: float = 5.0
    jitter_s: float = 0.1
    port: int = 6667


@dataclasses.dataclass
class ScenarioConfig:
    """A seeded scenario mix: diurnal background + injected attacks."""
    duration_s: float = 120.0
    n_hosts: int = 128
    base_rate: float = 150.0        # mean background packets/s
    diurnal_amplitude: float = 0.3
    diurnal_period_s: float = 600.0  # a compressed "day"
    zipf_a: float = 1.3
    tcp_fraction: float = 0.9
    seed: int = 0
    t0: float = 1_492_000_000.0
    attacks: Tuple[AttackSpec, ...] = ()


def _background(cfg: ScenarioConfig, rng: np.random.Generator):
    """Diurnal background: per-second Poisson counts around the load
    curve, Zipf-popular destinations, service-port mix."""
    secs = np.arange(int(np.ceil(cfg.duration_s)))
    lam = cfg.base_rate * (
        1.0 + cfg.diurnal_amplitude *
        np.sin(2 * np.pi * secs / cfg.diurnal_period_s))
    counts = rng.poisson(np.maximum(lam, 1.0))
    n = int(counts.sum())
    ts = cfg.t0 + np.repeat(secs.astype(np.float64), counts) \
        + rng.uniform(0.0, 1.0, size=n)

    pool = _ip_pool(cfg.n_hosts, rng)
    ranks = np.arange(1, pool.shape[0] + 1, dtype=np.float64)
    pop = ranks ** (-cfg.zipf_a)
    pop /= pop.sum()
    dst = rng.choice(pool, size=n, p=pop)
    src = rng.choice(pool, size=n, p=np.roll(pop, pool.shape[0] // 3))
    same = src == dst
    src[same] = np.roll(src[same], 1) if same.sum() > 1 else pool[0]

    length = np.minimum(
        40 + rng.pareto(1.2, size=n).astype(np.int64) * 64, 1500)
    proto = np.where(rng.random(n) < cfg.tcp_fraction, 6, 17) \
        .astype(np.uint8)
    sport = rng.integers(1024, 65535, size=n).astype(np.uint16)
    dport = _WELL_KNOWN[rng.integers(0, _WELL_KNOWN.shape[0], size=n)]
    flags = np.full(n, 0x5010, dtype=np.uint16)     # data_off=5, ACK
    return pool, dict(ts=ts, src=src, dst=dst, length=length, proto=proto,
                      sport=sport, dport=dport, flags=flags)


def _attack_packets(cfg: ScenarioConfig, spec: AttackSpec, idx: int,
                    pool: np.ndarray) -> tuple[dict, dict]:
    """(packet columns, truth label) for one injected attack.  Each
    attack draws from its own RNG stream so labels are replayable."""
    rng = np.random.default_rng([cfg.seed, 0xA77, idx])
    lo, hi = cfg.t0 + spec.start, cfg.t0 + spec.start + spec.duration

    if spec.kind == "c2":
        c2 = pool[rng.integers(0, pool.shape[0])]
        bots = rng.choice(pool[pool != c2], size=spec.n_hosts,
                          replace=False)
        ts, src = [], []
        for b in bots:
            t = lo + rng.uniform(0, spec.period_s)
            while t < hi:
                ts.append(t)
                src.append(b)
                t += spec.period_s + rng.normal(0, spec.jitter_s)
        n = len(ts)
        cols = dict(
            ts=np.asarray(ts), src=np.asarray(src, np.uint32),
            dst=np.full(n, c2, np.uint32),
            length=np.full(n, 60), proto=np.full(n, 6, np.uint8),
            sport=rng.integers(40000, 50000, n).astype(np.uint16),
            dport=np.full(n, spec.port, np.uint16),
            flags=np.full(n, 0x5018, np.uint16))          # PSH|ACK
        truth = {"kind": "c2", "attackers": [str(s) for s in ip_str(bots)],
                 "victim": str(ip_str(np.asarray([c2]))[0])}

    elif spec.kind == "scan":
        attacker = pool[rng.integers(0, pool.shape[0])]
        n = max(int(spec.rate * spec.duration), 1)
        # fresh targets outside the pool: every probe hits a new host
        targets = rng.integers(0x0B000000, 0xDF000000, size=n,
                               dtype=np.uint64).astype(np.uint32)
        cols = dict(
            ts=np.sort(rng.uniform(lo, hi, size=n)),
            src=np.full(n, attacker, np.uint32), dst=targets,
            length=np.full(n, 40), proto=np.full(n, 6, np.uint8),
            sport=rng.integers(40000, 60000, n).astype(np.uint16),
            dport=rng.integers(1, 1024, n).astype(np.uint16),
            flags=np.full(n, 0x5002, np.uint16))          # SYN
        truth = {"kind": "scan",
                 "attackers": [str(ip_str(np.asarray([attacker]))[0])],
                 "victim": ""}

    elif spec.kind == "ddos":
        victim = pool[rng.integers(0, pool.shape[0])]
        attackers = rng.choice(pool[pool != victim], size=spec.n_hosts,
                               replace=False)
        per = rng.poisson(spec.rate * spec.duration, size=spec.n_hosts)
        n = int(per.sum())
        cols = dict(
            ts=rng.uniform(lo, hi, size=n),
            src=np.repeat(attackers, per).astype(np.uint32),
            dst=np.full(n, victim, np.uint32),
            length=np.full(n, 60), proto=np.full(n, 6, np.uint8),
            sport=rng.integers(1024, 65535, n).astype(np.uint16),
            dport=np.full(n, spec.port if spec.port != 6667 else 80,
                          np.uint16),
            flags=np.full(n, 0x5010, np.uint16))
        truth = {"kind": "ddos",
                 "attackers": [str(s) for s in ip_str(attackers)],
                 "victim": str(ip_str(np.asarray([victim]))[0])}
    else:
        raise ValueError(f"unknown attack kind {spec.kind!r}")

    truth.update(start=lo, stop=hi, port=int(spec.port),
                 n_packets=int(cols["ts"].shape[0]))
    return cols, truth


def synth_scenario(cfg: ScenarioConfig
                   ) -> tuple[np.ndarray, dict]:
    """Generate the scenario: a time-sorted ``REC_DTYPE`` record array
    plus the ground-truth label dict ``{"attacks": [...], ...}``."""
    rng = np.random.default_rng(cfg.seed)
    pool, cols = _background(cfg, rng)
    labels = []
    for i, spec in enumerate(cfg.attacks):
        acols, truth = _attack_packets(cfg, spec, i, pool)
        labels.append(truth)
        for k in cols:
            cols[k] = np.concatenate([cols[k], acols[k]])

    order = np.argsort(cols["ts"], kind="stable")
    n = order.shape[0]
    rec = np.zeros(n, dtype=REC_DTYPE)
    ts = cols["ts"][order]
    rec["ts_sec"] = ts.astype(np.uint64).astype(np.uint32)
    rec["ts_usec"] = ((ts % 1.0) * 1e6).astype(np.uint32)
    rec["incl_len"] = 40
    rec["orig_len"] = cols["length"][order]
    rec["ver_ihl"] = 0x45
    rec["tot_len"] = np.minimum(cols["length"][order], 65535)
    rec["ttl"] = 64
    rec["proto"] = cols["proto"][order]
    rec["src"] = cols["src"][order]
    rec["dst"] = cols["dst"][order]
    rec["sport"] = cols["sport"][order]
    rec["dport"] = cols["dport"][order]
    rec["off_flags"] = cols["flags"][order]
    rec["win"] = 65535
    truth = {"t0": cfg.t0, "duration_s": cfg.duration_s, "seed": cfg.seed,
             "attacks": labels}
    return rec, truth


def scenario_truth(cfg: ScenarioConfig) -> dict:
    """Just the labels (deterministic in the seed; regenerates)."""
    return synth_scenario(cfg)[1]


def records_to_incidence(rec: np.ndarray, t0: float,
                         pkt_prefix: str = "p") -> Assoc:
    """Records → sparse incidence Assoc via the stage 4→5 schema path
    (tshark-analog TSV → dense table → ``val2col`` explosion)."""
    return val2col(parse_tsv(records_to_tsv(rec, t0=t0,
                                            pkt_prefix=pkt_prefix)))


def scenario_incidence(cfg: ScenarioConfig) -> tuple[Assoc, dict]:
    """Whole scenario as one incidence matrix (the batch-ingest shape)."""
    rec, truth = synth_scenario(cfg)
    return records_to_incidence(rec, cfg.t0), truth


def stream_blocks(cfg: ScenarioConfig, block_s: float = 1.0,
                  rec: Optional[np.ndarray] = None
                  ) -> Iterator[tuple[float, Assoc]]:
    """Stream the scenario as ``(block_start_ts, incidence)`` pairs, one
    per ``block_s`` of traffic — the shape async ingest consumes.
    Packet ids are prefixed per block so rows stay globally unique."""
    if rec is None:
        rec, _ = synth_scenario(cfg)
    ts = rec["ts_sec"].astype(np.float64) + rec["ts_usec"] * 1e-6
    n_blocks = int(np.ceil(cfg.duration_s / block_s)) + 1
    for i in range(n_blocks):
        lo = cfg.t0 + i * block_s
        m = (ts >= lo) & (ts < lo + block_s)
        if not m.any():
            continue
        yield lo, records_to_incidence(rec[m], cfg.t0,
                                       pkt_prefix=f"b{i:06d}-p")
