"""Online detectors over closed rollup windows.

Four detector families run as windows close, all reusing the batch
analytics cores rather than re-implementing them:

* **SPC** — rolling mean/σ statistical process control per summary
  metric (packet rate, cell rate, unique src/dst) with the Western
  Electric run rules.  The baseline is a trailing window, so the
  diurnal load curve is absorbed as slow drift; only the sharper rules
  (1: beyond 3σ, 2: two-of-three beyond 2σ) raise alerts by default —
  rules 3/4 trip on sustained ramps and stay advisory.
* **C2 beaconing** — :func:`~repro.analytics.anomaly.c2_scores` (the
  ``detect_c2`` scoring core) over each closed *minute*'s retained
  slice; thresholded on fused score and fan-in.
* **scan / DDoS bursts** — :func:`~repro.analytics.anomaly.scan_hits`
  over each closed *second*'s slice, plus a rate-spike × destination-
  concentration gate for DDoS (packet-rate z-score from the SPC state
  joined with the window's ``top_dst_share``).
* **root-cause localization** (MicroRCA-style) — personalized PageRank
  over the anomalous sub-window's subgraph, *reversed* so rank mass
  flows from the victim back through the hosts feeding it traffic;
  rides the existing mesh-sharded
  :func:`~repro.analytics.distributed.pagerank_table`.

:class:`StreamAnalytics` composes a rollup with a detector bank and
attaches to a live :class:`~repro.db.binding.DBTable` via the ingest
tap — the end-to-end streaming pipeline in one object.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence

import numpy as np

from ..analytics.anomaly import c2_scores, scan_hits
from ..analytics.distributed import pagerank_table
from ..analytics.serialize import JsonReportMixin
from .windows import TemporalRollup, WindowSummary


class AlertReport(NamedTuple):
    """One alert, JSON-serializable (same mixin path as C2Report)."""
    kind: str                  # 'spc' | 'c2' | 'scan' | 'ddos'
    level: str                 # rollup level the window came from
    window_start: float
    window_stop: float
    metric: str                # SPC metric, or '' for graph detectors
    rule: int                  # Western Electric rule #, 0 otherwise
    score: float               # z-score / fused C2 score / fan-out
    hosts: np.ndarray          # suspected attacker hosts (may be empty)
    victim: str                # victim host ('' when n/a)
    detail: dict

    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


class RootCauseReport(NamedTuple):
    """Root-cause ranking for one anomalous window: hosts ordered by
    reversed personalized-PageRank mass flowing back from the seeds."""
    hosts: np.ndarray
    ranks: np.ndarray
    seeds: np.ndarray
    window_start: float
    window_stop: float

    to_dict = JsonReportMixin.to_dict
    to_json = JsonReportMixin.to_json
    from_dict = classmethod(JsonReportMixin.from_dict.__func__)


class WesternElectric:
    """Rolling mean/σ SPC chart with the four Western Electric rules.

    ``update(x)`` returns ``(rule, z)``: the lowest-numbered rule that
    fired (0 if none) and the z-score of ``x`` against the *trailing*
    baseline (the sample enters the baseline only after being scored, so
    a step change is judged against the pre-step regime).  ``sigma_floor_
    frac`` floors σ at a fraction of |mean| — Poisson shot noise on a
    busy link is a few percent of the mean, and without the floor a
    quiet metric alarms on counting noise.
    """

    def __init__(self, baseline: int = 60, min_baseline: int = 10,
                 sigma_floor_frac: float = 0.15):
        self.baseline = int(baseline)
        self.min_baseline = int(min_baseline)
        self.sigma_floor_frac = float(sigma_floor_frac)
        self._hist: deque = deque(maxlen=self.baseline)
        self._z: deque = deque(maxlen=8)

    def update(self, x: float) -> tuple:
        rule, z = 0, 0.0
        if len(self._hist) >= self.min_baseline:
            h = np.asarray(self._hist, np.float64)
            mean = float(h.mean())
            sigma = max(float(h.std()),
                        self.sigma_floor_frac * abs(mean), 1e-9)
            z = (float(x) - mean) / sigma
            self._z.append(z)
            rule = self._check()
        self._hist.append(float(x))
        return rule, z

    def _check(self) -> int:
        zs = list(self._z)
        if abs(zs[-1]) > 3.0:
            return 1
        for side in (1.0, -1.0):
            s = [z * side for z in zs]
            if len(s) >= 3 and sum(z > 2.0 for z in s[-3:]) >= 2:
                return 2
            if len(s) >= 5 and sum(z > 1.0 for z in s[-5:]) >= 4:
                return 3
            if len(s) >= 8 and all(z > 0.0 for z in s[-8:]):
                return 4
        return 0


def root_cause(source, start: float, stop: float,
               seeds: Sequence[str], top_k: int = 5,
               num_iters: int = 30, damping: float = 0.3,
               sep: str = "|") -> RootCauseReport:
    """MicroRCA-style localization: personalized PageRank over the
    anomalous sub-window's subgraph, reversed so mass flows from the
    seed victim(s) back to the traffic sources feeding them.  ``source``
    is a :class:`TemporalRollup` (its retained ``slice`` is used) or any
    Queryable incidence the selection grammar accepts.  Seeds are
    excluded from the returned ranking.

    ``damping`` defaults well below the web-surfing 0.85: attack sources
    have near-zero in-degree, so at high damping their rank drains to
    whichever background host sent them a stray packet — restart
    dominance keeps the mass within a hop or two of the seeds, which is
    exactly the localization radius MicroRCA wants."""
    E = source.slice(start, stop) if hasattr(source, "slice") else source
    seeds = [str(s) for s in seeds]
    keys, ranks = pagerank_table(
        E, sep=sep, num_iters=num_iters, reverse=True, damping=damping,
        personalize={s: 1.0 for s in seeds})
    ranks = np.asarray(ranks, np.float64)
    keep = ~np.isin(keys, np.asarray(seeds, dtype=str)) \
        if keys.shape[0] else np.zeros(0, bool)
    keys, ranks = keys[keep], ranks[keep]
    order = np.argsort(ranks)[::-1][:top_k]
    return RootCauseReport(np.asarray(keys[order], dtype=str),
                           ranks[order],
                           np.asarray(seeds, dtype=str), start, stop)


class DetectorBank:
    """Runs the online detectors over whatever windows the rollup
    closes.  ``process()`` pulls newly closed windows (optionally
    forcing an end-of-stream flush) and returns fresh alerts; alerts
    are also kept in a bounded history and fanned out to ``on_alert``
    callbacks (the gateway's SSE publisher rides those)."""

    def __init__(self, rollup: TemporalRollup,
                 spc_metrics: Iterable[str] = ("n_packets", "n_cells",
                                               "n_src", "n_dst"),
                 spc_level: str = "second",
                 alert_rules: Iterable[int] = (1, 2),
                 spc_kw: Optional[dict] = None,
                 beacon_level: str = "minute",
                 beacon_min_score: float = 0.5,
                 beacon_min_fanin: float = 3.0,
                 scan_level: str = "second",
                 scan_min_fanout: int = 24,
                 ddos_min_z: float = 3.0,
                 ddos_min_share: float = 0.55,
                 history: int = 1024):
        self.rollup = rollup
        self.spc_metrics = tuple(spc_metrics)
        self.spc_level = spc_level
        self.alert_rules = frozenset(alert_rules)
        self.beacon_level = beacon_level
        self.beacon_min_score = float(beacon_min_score)
        self.beacon_min_fanin = float(beacon_min_fanin)
        self.scan_level = scan_level
        self.scan_min_fanout = int(scan_min_fanout)
        self.ddos_min_z = float(ddos_min_z)
        self.ddos_min_share = float(ddos_min_share)
        self._spc: Dict[str, WesternElectric] = {
            m: WesternElectric(**(spc_kw or {})) for m in self.spc_metrics}
        self._alerts: deque = deque(maxlen=int(history))
        self._callbacks: list = []
        self._lock = threading.Lock()
        self.n_windows = 0
        self.n_alerts = 0

    def on_alert(self, fn) -> None:
        """Register an alert callback (called inline from process())."""
        self._callbacks.append(fn)

    # --------------------------------------------------------- process

    def process(self, now: Optional[float] = None,
                force: bool = False) -> List[AlertReport]:
        """Close due windows and run every detector on them.  Windows
        are handled in (width, start) order, so the SPC charts consume
        seconds chronologically."""
        closed = self.rollup.close_due(now=now, force=force)
        alerts: List[AlertReport] = []
        with self._lock:
            for w in closed:
                self.n_windows += 1
                if w.level == self.spc_level:
                    alerts.extend(self._spc_step(w))
                if w.level == self.scan_level:
                    alerts.extend(self._scan_step(w))
                if w.level == self.beacon_level:
                    alerts.extend(self._beacon_step(w))
            for a in alerts:
                self._alerts.append(a)
            self.n_alerts += len(alerts)
        for a in alerts:
            for fn in self._callbacks:
                fn(a)
        return alerts

    def _spc_step(self, w: WindowSummary) -> List[AlertReport]:
        out = []
        zs: Dict[str, float] = {}
        for m in self.spc_metrics:
            rule, z = self._spc[m].update(float(getattr(w, m)))
            zs[m] = z
            if rule in self.alert_rules:
                out.append(AlertReport(
                    kind="spc", level=w.level, window_start=w.start,
                    window_stop=w.start + w.width, metric=m, rule=rule,
                    score=z, hosts=np.empty(0, dtype=str), victim="",
                    detail={"value": float(getattr(w, m))}))
        # DDoS gate: a packet-rate spike *concentrated on one dst* —
        # rate z-score joined with the window's top-dst share
        z_pkt = zs.get("n_packets", 0.0)
        if (z_pkt >= self.ddos_min_z
                and w.top_dst_share >= self.ddos_min_share and w.top_dst):
            out.append(AlertReport(
                kind="ddos", level=w.level, window_start=w.start,
                window_stop=w.start + w.width, metric="n_packets",
                rule=0, score=z_pkt, hosts=np.empty(0, dtype=str),
                victim=w.top_dst,
                detail={"top_dst_share": w.top_dst_share,
                        "n_packets": w.n_packets}))
        return out

    def _scan_step(self, w: WindowSummary) -> List[AlertReport]:
        if w.n_cells == 0:
            return []
        E = self.rollup.slice(w.start, w.start + w.width)
        if E.nnz == 0:
            return []
        hits = scan_hits(E, sep=self.rollup.sep,
                         min_fanout=self.scan_min_fanout)
        if hits.shape[0] == 0:
            return []
        return [AlertReport(
            kind="scan", level=w.level, window_start=w.start,
            window_stop=w.start + w.width, metric="", rule=0,
            score=float(w.n_dst), hosts=hits, victim="",
            detail={"min_fanout": self.scan_min_fanout,
                    "n_dst": w.n_dst})]

    def _beacon_step(self, w: WindowSummary) -> List[AlertReport]:
        if w.n_cells == 0:
            return []
        E = self.rollup.slice(w.start, w.start + w.width)
        if E.nnz == 0:
            return []
        s = c2_scores(E, sep=self.rollup.sep)
        mask = (s.scores >= self.beacon_min_score) \
            & (s.fanin >= self.beacon_min_fanin)
        if not mask.any():
            return []
        order = np.argsort(s.scores[mask])[::-1]
        hosts = s.hosts[mask][order]
        return [AlertReport(
            kind="c2", level=w.level, window_start=w.start,
            window_stop=w.start + w.width, metric="", rule=0,
            score=float(s.scores[mask].max()), hosts=hosts,
            victim=str(hosts[0]),
            detail={"fanin": float(s.fanin[mask].max()),
                    "n_candidates": int(mask.sum())})]

    # ---------------------------------------------------------- access

    def alerts(self, limit: int = 100, kind: Optional[str] = None,
               since: Optional[float] = None) -> List[AlertReport]:
        with self._lock:
            items = list(self._alerts)
        if kind is not None:
            items = [a for a in items if a.kind == kind]
        if since is not None:
            items = [a for a in items if a.window_start >= since]
        return items[-limit:]

    def stats(self) -> dict:
        with self._lock:
            kinds: Dict[str, int] = {}
            for a in self._alerts:
                kinds[a.kind] = kinds.get(a.kind, 0) + 1
            return {"n_windows": self.n_windows,
                    "n_alerts": self.n_alerts,
                    "alerts_by_kind": kinds}


class StreamAnalytics:
    """Rollup + detector bank bound to a live table's write path.

    ``attach(table)`` registers the rollup as a WriterPool ingest tap;
    from then on every drained triple block updates the rollup with no
    extra table scan.  ``step()`` (or the optional pacing thread started
    by ``start()``) closes due windows and runs the detectors.
    """

    def __init__(self, rollup: Optional[TemporalRollup] = None,
                 bank: Optional[DetectorBank] = None,
                 interval: float = 1.0, **bank_kw):
        self.rollup = rollup if rollup is not None else TemporalRollup()
        self.bank = bank if bank is not None \
            else DetectorBank(self.rollup, **bank_kw)
        self.interval = float(interval)
        self._table = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def attach(self, table) -> "StreamAnalytics":
        if self._table is not None:
            raise RuntimeError("already attached")
        table.add_ingest_tap(self.rollup.ingest)
        self._table = table
        return self

    def detach(self) -> None:
        if self._table is not None:
            self._table.remove_ingest_tap(self.rollup.ingest)
            self._table = None

    def step(self, now: Optional[float] = None,
             force: bool = False) -> List[AlertReport]:
        """One detector pass over newly closed windows."""
        return self.bank.process(now=now, force=force)

    def on_alert(self, fn) -> None:
        self.bank.on_alert(fn)

    def root_cause(self, start: float, stop: float,
                   seeds: Optional[Sequence[str]] = None,
                   top_k: int = 5, num_iters: int = 30) -> RootCauseReport:
        """Localize likely root-cause hosts for ``[start, stop)``.  With
        no explicit seeds, the most recent alert overlapping the window
        provides them (its victim, else its suspect hosts)."""
        if seeds is None:
            for a in reversed(self.bank.alerts(limit=1024)):
                if a.window_start < stop and a.window_stop > start:
                    seeds = [a.victim] if a.victim \
                        else [str(h) for h in a.hosts[:3]]
                    if seeds:
                        break
        if not seeds:
            raise ValueError("no seeds given and no overlapping alert")
        return root_cause(self.rollup, start, stop, seeds,
                          top_k=top_k, num_iters=num_iters,
                          sep=self.rollup.sep)

    # ------------------------------------------------- pacing thread

    def start(self) -> "StreamAnalytics":
        """Run ``step()`` every ``interval`` seconds on a daemon thread
        until :meth:`close` (alerts reach subscribers via on_alert)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.interval):
                try:
                    self.step()
                except Exception:       # detector bug must not kill pacing
                    pass

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="stream-analytics")
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self.detach()

    def stats(self) -> dict:
        return {"rollup": self.rollup.stats(), "bank": self.bank.stats()}
