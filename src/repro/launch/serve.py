"""Batched serving driver: prefill + decode loop with KV/recurrent caches.

Smoke mode runs a real generate loop on CPU (reduced config); production
mode lowers the prefill/decode pair on the production mesh (the serving
analog of dryrun).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --prompt "ip.src|1.1.1.1" --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_config
from ..data import tokenizer as T
from ..models import decode_step, init_params, prefill
from .mesh import make_smoke_mesh


def generate(cfg, params, prompts: list[str], max_new: int = 32,
             s_max: int = 256, temperature: float = 0.0, seed: int = 0):
    """Batched greedy/temperature sampling."""
    toks = [np.minimum(T.encode(p), cfg.vocab - 1) for p in prompts]
    max_len = max(t.shape[0] for t in toks)
    batch = np.full((len(toks), max_len), 0, np.int32)
    for i, t in enumerate(toks):
        batch[i, -t.shape[0]:] = t      # left-pad
    pb = {"tokens": jnp.asarray(batch)}
    if cfg.frontend == "vision":
        pb["img_embeds"] = jnp.zeros(
            (len(toks), cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        pb["frames"] = jnp.zeros(
            (len(toks), cfg.encoder_seq, cfg.d_model), jnp.float32)

    logits, caches = prefill(params, pb, cfg, s_max=s_max)
    key = jax.random.key(seed)
    out_tokens = [[] for _ in prompts]
    # vision archs: decode positions continue after the image prefix
    pos = max_len + (cfg.n_img_tokens if cfg.frontend == "vision" else 0)
    step_fn = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
    cur = None
    for step in range(max_new):
        if temperature > 0:
            key, k2 = jax.random.split(key)
            nxt = jax.random.categorical(k2, logits[:, -1] / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        for i, t in enumerate(np.asarray(nxt)):
            out_tokens[i].append(int(t))
        db = {"tokens": nxt[:, None].astype(jnp.int32),
              "positions": jnp.full((len(prompts), 1), pos, jnp.int32)}
        if cfg.is_encdec:
            db["enc_out"] = jnp.zeros(
                (len(prompts), cfg.encoder_seq, cfg.d_model), jnp.float32)
        logits, caches = step_fn(params, caches, db)
        pos += 1
    return ["".join(T.decode(np.asarray(t))) for t in out_tokens]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, jax.random.key(0))
    prompts = args.prompt or ["ip.src|1.1.1.1 talked to",
                              "tcp.dstport|6667 beacons from"]
    t0 = time.time()
    outs = generate(cfg, params, prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = args.max_new * len(prompts)
    for p, o in zip(prompts, outs):
        print(f"PROMPT {p!r}\n  → {o!r}")
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
