import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimb driver for the three selected cells.

For each (cell, iteration) this computes the analytic roofline terms
(perfmodel, validated by calibrate.py) AND re-lowers the real module to
capture measured per-device memory + the HLO collective census — every
iteration is a (hypothesis → change → measure → validate) record written
to results/perf/<cell>__<tag>.json and summarized by EXPERIMENTS.md.

Cells (chosen per the assignment's three criteria):
* qwen3-moe-235b-a22b / train_4k / single — worst roofline fraction
  (0.78%), collective-dominated MoE training.
* granite-moe-3b-a800m / train_4k / single — most collective-bound
  (coll/comp ≈ 27×): 40 experts don't divide tp=16.
* h2o-danube-1.8b / train_4k / single — representative of the paper-
  integrated workload (LM trained on the D4M pipeline's packet corpus).
"""
import dataclasses
import json

from ..configs import get_config
from ..train import OptConfig
from . import perfmodel as PM
from .dryrun import RESULTS_DIR, run_cell
from .roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops_per_device

PERF_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "perf")


@dataclasses.dataclass
class Iter:
    tag: str
    hypothesis: str
    knobs: PM.PerfKnobs
    cfg_patch: dict = dataclasses.field(default_factory=dict)
    opt: OptConfig = None
    profile: str = "2d"
    measure: bool = True       # re-lower the real module for evidence


def terms(arch, shape, mesh, knobs, cfg=None):
    perf = PM.cell_perf(arch, shape, mesh, knobs, cfg=cfg)
    t = {"t_compute": perf.flops / PEAK_FLOPS,
         "t_memory": perf.hbm_bytes / HBM_BW,
         "t_collective": perf.coll_bytes / LINK_BW,
         "coll_by_kind": {k: v / LINK_BW
                          for k, v in perf.coll_by_kind.items()}}
    bound = max(t["t_compute"], t["t_memory"], t["t_collective"])
    mf = model_flops_per_device(arch, shape, 256 if mesh == "single"
                                else 512)
    t["dominant"] = max((t["t_compute"], "compute"),
                        (t["t_memory"], "memory"),
                        (t["t_collective"], "collective"))[1]
    t["roofline_fraction"] = (mf / PEAK_FLOPS) / bound
    return t


def run_iteration(arch: str, shape: str, mesh: str, it: Iter,
                  force: bool = False) -> dict:
    os.makedirs(PERF_DIR, exist_ok=True)
    from ..configs import canonical
    cell = f"{canonical(arch)}__{shape}__{mesh}__{it.tag}"
    path = os.path.join(PERF_DIR, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    if it.cfg_patch:
        patch = dict(it.cfg_patch)
        cap = patch.pop("moe_capacity", None)
        if cap is not None:
            patch["moe"] = dataclasses.replace(cfg.moe,
                                               capacity_factor=cap)
        cfg = dataclasses.replace(cfg, **patch)
    rec = {"arch": canonical(arch), "shape": shape, "mesh": mesh,
           "tag": it.tag, "hypothesis": it.hypothesis,
           "model_terms": terms(arch, shape, mesh, it.knobs, cfg=cfg)}
    if it.measure:
        dr = run_cell(arch, shape, mesh, cfg_override=cfg,
                      tag="perf_" + it.tag, force=force,
                      opt_override=it.opt, profile=it.profile)
        rec["measured"] = {
            "ok": dr.get("ok"), "error": dr.get("error"),
            "temp_gib": dr.get("memory", {}).get("temp_bytes", 0) / 2**30,
            "args_gib": dr.get("memory", {}).get("argument_bytes", 0)
            / 2**30,
            "hlo_collectives": dr.get("collective_bytes"),
            "compile_s": dr.get("compile_s"),
        }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def h2o_iterations():
    ga = 2
    base = PM.PerfKnobs(grad_accum=ga)
    return "h2o-danube-1.8b", "train_4k", "single", [
        Iter("baseline", "production 2-D (FSDP×TP) defaults",
             base, opt=OptConfig(grad_accum=ga)),
        Iter("save_coll",
             "TP all-reduce replay in remat is 1/3 of collective time; "
             "saving collective outputs cuts passes 3→2",
             dataclasses.replace(base, save_coll=True),
             cfg_patch={"remat": "block_save_coll"},
             opt=OptConfig(grad_accum=ga)),
        Iter("bf16_wire",
             "f32 param gathers + grad reduces are 2× the needed bytes; "
             "bf16 on the wire halves both",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2),
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("zero3",
             "1.8B params need no TP on 256 chips: pure ZeRO-3 removes "
             "all per-layer TP all-reduces; param gathers (bf16) are the "
             "only collective left",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 grad_accum=1, profile="zero3"),
             opt=OptConfig(grad_accum=1, gather_dtype="bfloat16",
                           grad_dtype="bfloat16"),
             profile="zero3"),
        Iter("zero3_tri",
             "now compute-bound: masked-full attention does 2× the "
             "causal work; triangular schedule removes the waste",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 grad_accum=1, profile="zero3",
                                 attention_tri=True),
             cfg_patch={"attention_impl": "chunked_tri"},
             opt=OptConfig(grad_accum=1, gather_dtype="bfloat16",
                           grad_dtype="bfloat16"),
             profile="zero3"),
        Iter("zero3_tri_noremat",
             "zero3 leaves 13 GB HBM headroom: dropping remat removes "
             "the recompute pass (4/3× compute) entirely",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 grad_accum=1, profile="zero3",
                                 attention_tri=True, remat=False),
             cfg_patch={"attention_impl": "chunked_tri", "remat": "none"},
             opt=OptConfig(grad_accum=1, gather_dtype="bfloat16",
                           grad_dtype="bfloat16"),
             profile="zero3"),
    ]


def moe_iterations(arch, ga):
    base = PM.PerfKnobs(grad_accum=ga)
    return arch, "train_4k", "single", [
        Iter("baseline", "production 2-D (FSDP×TP/EP) defaults",
             base, opt=OptConfig(grad_accum=ga)),
        Iter("bf16_wire",
             "halve gather/reduce wire bytes via bf16",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2),
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("save_coll",
             "skip collective replay in remat (passes 3→2)",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 save_coll=True),
             cfg_patch={"remat": "block_save_coll"},
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("cap_1_0",
             "capacity factor 1.25→1.0 cuts a2a + expert flops 20%",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 save_coll=True),
             cfg_patch={"remat": "block_save_coll", "moe_capacity": 1.0},
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
    ]


def qwen3_extra():
    """Feasibility push: 26–40 GiB temp at ga=8 exceeds 16 GB HBM; ga=16
    halves the activation working set at the cost of 2× param gathers
    (the model shows the collective-term price explicitly)."""
    arch, shape, mesh, iters = moe_iterations("qwen3-moe-235b-a22b", 8)
    base16 = PM.PerfKnobs(grad_accum=16, gather_bytes=2, grad_bytes=2)
    iters += [
        Iter("ga16",
             "halve activation memory via 2× micro-batching; param "
             "gathers double (collective-term price, modeled)",
             base16,
             opt=OptConfig(grad_accum=16, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("ga16_cap10",
             "recover a2a bytes with capacity 1.0 on top of ga16",
             dataclasses.replace(base16),
             cfg_patch={"moe_capacity": 1.0},
             opt=OptConfig(grad_accum=16, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("ga16_save_coll",
             "combine: ga16 memory headroom may absorb save_coll's "
             "saved tp_out tensors, buying the 3→2 collective passes",
             dataclasses.replace(base16, save_coll=True),
             cfg_patch={"remat": "block_save_coll", "moe_capacity": 1.0},
             opt=OptConfig(grad_accum=16, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
    ]
    return arch, shape, mesh, iters


CELLS = {
    "h2o": h2o_iterations,
    "qwen3": qwen3_extra,
    "granite": lambda: moe_iterations("granite-moe-3b-a800m", 4),
}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["all"], default="all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for name in names:
        arch, shape, mesh, iters = CELLS[name]()
        print(f"=== {arch} {shape} {mesh} ===")
        for it in iters:
            rec = run_iteration(arch, shape, mesh, it, force=args.force)
            t = rec["model_terms"]
            meas = rec.get("measured", {})
            print(f"{it.tag:12s} comp={t['t_compute']:.3f}s "
                  f"mem={t['t_memory']:.3f}s coll={t['t_collective']:.3f}s "
                  f"dom={t['dominant'][:4]} "
                  f"frac={t['roofline_fraction']:.2%} "
                  f"| measured temp={meas.get('temp_gib', 0):.2f}GiB "
                  f"ok={meas.get('ok')}", flush=True)


def prefill_iterations():
    """Bonus cell: serving-side prefill (qwen2.5 prefill_32k, baseline
    25.4%) — the triangular schedule halves causal attention work, and
    prefill has no remat/optimizer confounders."""
    base = PM.PerfKnobs()
    return "qwen2.5-14b", "prefill_32k", "single", [
        Iter("baseline", "production serving defaults (masked-full attn)",
             base),
        Iter("tri",
             "causal prefill at 32k does 2x the visible-pair work under "
             "the masked-full schedule; triangular removes it",
             dataclasses.replace(base, attention_tri=True),
             cfg_patch={"attention_impl": "chunked_tri"}),
    ]


CELLS["qwen25_prefill"] = prefill_iterations


def rg_iterations():
    """4th cell: recurrentgemma train (the only memory-dominant train
    cell — 6 matmul streams per RG-LRU block + 256k-vocab embeddings)."""
    ga = 4
    base = PM.PerfKnobs(grad_accum=ga)
    return "recurrentgemma-9b", "train_4k", "single", [
        Iter("baseline", "production 2-D defaults", base,
             opt=OptConfig(grad_accum=ga)),
        Iter("bf16_wire",
             "memory term is dominated by ga·3 re-reads of gathered f32 "
             "params; bf16 gathers halve both HBM and wire bytes",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2),
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("bf16_save_coll",
             "then collectives dominate: skip replay (passes 3→2)",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 save_coll=True),
             cfg_patch={"remat": "block_save_coll"},
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("bf16_sc_tri",
             "local-attention blocks still do masked-full work; "
             "triangular/banded schedule trims the window waste",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 save_coll=True, attention_tri=True),
             cfg_patch={"remat": "block_save_coll",
                        "attention_impl": "chunked_tri"},
             opt=OptConfig(grad_accum=ga, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
        Iter("ga8_sc_tri",
             "save_coll at ga=4 overruns HBM (27.4 GiB): double the "
             "micro-batching to absorb the saved tp_out tensors",
             dataclasses.replace(base, gather_bytes=2, grad_bytes=2,
                                 save_coll=True, attention_tri=True,
                                 grad_accum=8),
             cfg_patch={"remat": "block_save_coll",
                        "attention_impl": "chunked_tri"},
             opt=OptConfig(grad_accum=8, gather_dtype="bfloat16",
                           grad_dtype="bfloat16")),
    ]


CELLS["recurrentgemma"] = rg_iterations


if __name__ == "__main__":
    main()
