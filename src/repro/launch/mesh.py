"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Shapes:

* single-pod: (16, 16) = 256 chips, axes (data, model) — one TPU v5e pod.
* multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the
  ``pod`` axis is data-parallel across DCN; only gradient reductions
  cross it.

The dry-run launcher sets ``--xla_force_host_platform_device_count=512``
before any jax import so these meshes build on the CPU container.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return jax.make_mesh(
        (1, n_devices), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
