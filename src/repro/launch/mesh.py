"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Shapes:

* single-pod: (16, 16) = 256 chips, axes (data, model) — one TPU v5e pod.
* multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the
  ``pod`` axis is data-parallel across DCN; only gradient reductions
  cross it.

The dry-run launcher sets ``--xla_force_host_platform_device_count=512``
before any jax import so these meshes build on the CPU container.
"""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` when this JAX has explicit axis types
    (>= 0.5); empty on older releases, where ``jax.make_mesh`` neither
    accepts the kwarg nor needs it (every axis is implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes, **kw):
    """Version-guarded ``jax.make_mesh``: every axis auto-sharded,
    portable across the JAX 0.5 ``AxisType`` API change."""
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)), **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    return make_mesh((1, n_devices), ("data", "model"))
