"""Analytic per-device performance model (FLOPs / HBM bytes / collective
bytes) for every (arch × shape × mesh) cell.

Why analytic: XLA's ``cost_analysis()`` on this container counts while-
loop bodies ONCE (measured — see EXPERIMENTS.md §Roofline methodology),
so any scanned module (layers, grad-accum, blocked attention) is under-
counted by its trip counts.  The model below reproduces the exact matmul
dimensions the modules lower to — per device, given the sharding rules —
and is **validated against cost_analysis on fully-unrolled unit modules**
(launch/calibrate.py) to <10%.

Everything is per device per step.  Knobs that §Perf iterates on are
explicit parameters: attention schedule (masked-full vs triangular),
grad dtype, remat policy, grad accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..configs import get_config
from ..models.config import (ATTN, LOCAL_ATTN, ModelConfig, RGLRU, RWKV,
                             ShapeConfig, shape_by_name)


@dataclasses.dataclass
class MeshDims:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def n_devices(self):
        return self.pod * self.data * self.model

    @property
    def n_data(self):
        return self.pod * self.data


MESH_SINGLE = MeshDims(1, 16, 16)
MESH_MULTI = MeshDims(2, 16, 16)


@dataclasses.dataclass
class PerfKnobs:
    attention_tri: bool = False      # triangular schedule (vs masked-full)
    grad_accum: int = 1
    grad_bytes: int = 4              # f32 grads on the wire (bf16 = 2)
    param_bytes: int = 4             # master params f32
    gather_bytes: int = 4            # dtype gathered over FSDP (bf16 = 2)
    gather_passes: int = 2           # fwd + bwd regather (1 = persisted)
    act_bytes: int = 2               # bf16 activations
    remat: bool = True               # block remat (recompute fwd in bwd)
    save_coll: bool = False          # remat keeps TP-collective outputs
    profile: str = "2d"              # "2d" (FSDP×TP) | "zero3"


@dataclasses.dataclass
class CellPerf:
    flops: float                     # per device per step
    hbm_bytes: float
    coll_bytes: float
    coll_by_kind: dict

    def merged(self, other: "CellPerf") -> "CellPerf":
        kinds = dict(self.coll_by_kind)
        for k, v in other.coll_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return CellPerf(self.flops + other.flops,
                        self.hbm_bytes + other.hbm_bytes,
                        self.coll_bytes + other.coll_bytes, kinds)


# ---------------------------------------------------------------------------
# per-layer forward FLOPs (per device)
# ---------------------------------------------------------------------------

def _attn_layer_fwd(cfg: ModelConfig, S: int, B: int, m: MeshDims,
                    k: PerfKnobs, window: int, s_kv: Optional[int] = None,
                    cross: bool = False) -> float:
    """One attention layer forward (qkv + attention + out + mlp)."""
    D = cfg.d_model
    H, KV = cfg.phys_heads, cfg.phys_kv_heads   # padded = shardable
    Dh = cfg.resolved_head_dim
    tp = m.model
    s_kv = s_kv if s_kv is not None else S
    f = 0.0
    # q/k/v + out projections (head dims sharded over tp); k,v read from
    # the kv source (self: S tokens; cross: encoder_seq; decode: 1 new)
    f += 2 * B * S * D * (H * Dh) / tp                  # q
    kv_src = s_kv if cross else (S if S > 1 else 1)
    f += 2 * 2 * B * kv_src * D * (KV * Dh) / tp        # k, v
    f += 2 * B * S * (H * Dh) * D / tp                  # out
    # attention scores + pv
    eff = s_kv
    if window:
        eff = min(window, s_kv)
    if S > 1 and not cross and window == 0:
        # causal self-attention: masked-full does all S·s_kv block pairs,
        # triangular ~half
        pair_frac = 0.5 if k.attention_tri else 1.0
        f += 2 * 2 * B * S * s_kv * pair_frac * (H / tp) * Dh
    elif S > 1 and not cross and window:
        W = min(window, s_kv)
        # triangular+banded: visible pairs = Σ_q min(q+1, W) ≈ W·S − W²/2
        pair_frac = (W * s_kv - W * W / 2) / (S * s_kv) \
            if k.attention_tri else 1.0
        f += 2 * 2 * B * S * s_kv * pair_frac * (H / tp) * Dh
    else:
        f += 2 * 2 * B * S * eff * (H / tp) * Dh
    return f


def _mlp_fwd(cfg: ModelConfig, S: int, B: int, m: MeshDims) -> float:
    if cfg.moe is None:
        return 6 * B * S * cfg.d_model * cfg.d_ff / m.model
    mo = cfg.moe
    T = B * S
    router = 2 * T * cfg.d_model * mo.n_experts          # f32, replicated
    expert = 6 * mo.capacity_factor * T * mo.top_k * \
        cfg.d_model * mo.d_expert / m.model
    return router + expert


def _rglru_fwd(cfg: ModelConfig, S: int, B: int, m: MeshDims) -> float:
    D, Dr, W = cfg.d_model, cfg.d_rnn_resolved, cfg.conv_width
    tp = m.model
    f = 2 * 2 * B * S * D * Dr / tp          # wx, wg
    f += 2 * W * B * S * Dr / tp             # conv
    f += 2 * 2 * B * S * Dr * Dr / tp        # gates wa, wi
    f += 10 * B * S * Dr / tp                # scan combine work
    f += 2 * B * S * Dr * D / tp             # out proj
    return f


def _rwkv_fwd(cfg: ModelConfig, S: int, B: int, m: MeshDims) -> float:
    D, F, Lw = cfg.d_model, cfg.d_ff, cfg.decay_lora
    H = cfg.n_heads
    Dh = D // H
    C = cfg.rwkv_chunk
    tp = m.model
    f = 5 * 2 * B * S * D * D / tp           # r,k,v,g,out projections
    f += 2 * 2 * B * S * D * Lw              # decay lora (replicated)
    # chunked wkv per head: inter/state 4·C·Dh² + intra 4·C²·Dh per chunk
    f += B * S * (H / tp) * (4 * Dh * Dh + 4 * C * Dh)
    # channel mix
    f += 2 * B * S * (2 * D * F + D * D) / tp
    return f


def _layer_fwd(cfg, ltype, S, B, m, k, s_kv=None) -> float:
    if ltype in (ATTN, LOCAL_ATTN):
        window = cfg.window if ltype == LOCAL_ATTN else 0
        f = _attn_layer_fwd(cfg, S, B, m, k, window, s_kv)
        if cfg.cross_attention:
            f += _attn_layer_fwd(cfg, S, B, m, k, 0, cfg.encoder_seq,
                                 cross=True)
        return f + _mlp_fwd(cfg, S, B, m)
    if ltype == RGLRU:
        return _rglru_fwd(cfg, S, B, m) + \
            6 * B * S * cfg.d_model * cfg.d_ff / m.model
    if ltype == RWKV:
        return _rwkv_fwd(cfg, S, B, m)
    raise ValueError(ltype)


def _embed_head_fwd(cfg, S, B, m) -> float:
    V = cfg.padded_vocab
    f = B * S * cfg.d_model                      # embed scale
    f += 2 * B * S * cfg.d_model * V / m.model   # head matmul
    f += 5 * B * S * V / m.model                 # softmax/lse
    return f


def _encoder_fwd(cfg, B, m, k) -> float:
    if not cfg.is_encdec:
        return 0.0
    S = cfg.encoder_seq
    per = _attn_layer_fwd(cfg, S, B, m, k, 0) + \
        6 * B * S * cfg.d_model * cfg.d_ff / m.model
    return cfg.encoder_layers * per


# ---------------------------------------------------------------------------
# HBM + collective bytes (first-order, per device per step)
# ---------------------------------------------------------------------------

def _layer_act_bytes(cfg, S, B, m, k) -> float:
    """Residual-stream traffic per layer (write+read, bf16)."""
    return 2 * B * S * cfg.d_model * k.act_bytes


def train_cell(cfg: ModelConfig, shape: ShapeConfig, m: MeshDims,
               k: PerfKnobs) -> CellPerf:
    if k.profile == "zero3":
        # pure FSDP: the whole mesh is one data axis; tp factors vanish
        m = MeshDims(pod=1, data=m.n_devices, model=1)
    B_glob = shape.global_batch
    S = shape.seq_len
    ga = k.grad_accum
    B_micro = B_glob // m.n_data // ga           # per device micro batch
    L = cfg.n_layers
    P = cfg.n_params()
    P_loc = P / m.n_devices                       # FSDP+TP sharded at rest

    # ---- FLOPs: (fwd + remat recompute + bwd) per micro ----
    # vision archs prepend n_img_tokens patch embeddings to every sequence
    S_eff = S + (cfg.n_img_tokens if cfg.frontend == "vision" else 0)
    fwd_layers = sum(_layer_fwd(cfg, t, S_eff, B_micro, m, k)
                     for t in cfg.layer_types())
    fwd_layers += _encoder_fwd(cfg, B_micro, m, k)
    fwd_head = _embed_head_fwd(cfg, S, B_micro, m)
    mult = 4.0 if k.remat else 3.0               # fwd+recompute+2·bwd
    flops = ga * (mult * fwd_layers + 3.0 * fwd_head)
    flops += 12.0 * P_loc                        # AdamW update

    # ---- HBM bytes ----
    # FSDP: after all-gather each device reads the FULL layer params,
    # 3× per micro (fwd, recompute, bwd) — the dominant traffic for
    # big-model training.  Reads happen in the GATHERED dtype (bf16
    # gathers halve this too).
    hbm = ga * 3 * P * k.gather_bytes
    hbm += ga * L * _layer_act_bytes(cfg, S, B_micro, m, k) * 3
    hbm += ga * 2 * B_micro * S * cfg.padded_vocab / m.model * 4  # logits
    hbm += 3 * P_loc * 4 * 2                     # adam m,v read+write
    hbm += P_loc * k.param_bytes * 2             # param read+write (update)

    # ---- collective bytes ----
    coll = {}
    # FSDP param all-gather (fwd + bwd regather) + grad reduce-scatter.
    # Params are 2-D sharded (data × model): each device only gathers its
    # model-axis shard's data extent → P/tp bytes, not P.
    P_tp = P / m.model
    gathered = P_tp * k.gather_bytes * (m.data - 1) / m.data
    coll["all-gather"] = ga * k.gather_passes * gathered
    coll["reduce-scatter"] = P_tp * k.grad_bytes * (m.data - 1) / m.data
    # cross-pod gradient all-reduce (DP over pod axis) on the local shard
    if m.pod > 1:
        coll["all-reduce-pod"] = 2 * (P / m.n_devices) * k.grad_bytes \
            * (m.pod - 1) / m.pod
    # TP activation all-reduces: ~2 per layer fwd, ×3 passes (fwd/rc/bwd)
    # — or ×2 when remat keeps the collective outputs (save_coll)
    passes = 2 if (k.save_coll or not k.remat) else 3
    act = B_micro * S * cfg.d_model * k.act_bytes
    ring = 2 * (m.model - 1) / m.model
    coll["all-reduce"] = ga * L * 2 * passes * act * ring
    # MoE all-to-all dispatch+combine — only under expert parallelism
    # (hybrid sharding replicates experts: dispatch is shard-local)
    if cfg.moe is not None and cfg.moe.n_experts % m.model == 0:
        tok = B_micro * S * cfg.moe.top_k * cfg.d_model * k.act_bytes
        coll["all-to-all"] = ga * L * 2 * passes * tok \
            * (m.model - 1) / m.model
    total = sum(coll.values())
    return CellPerf(flops, hbm, total, coll)


def serve_cell(cfg: ModelConfig, shape: ShapeConfig, m: MeshDims,
               k: PerfKnobs) -> CellPerf:
    S = shape.seq_len
    B_glob = shape.global_batch
    B = B_glob // m.n_data if B_glob % m.n_data == 0 else B_glob
    replicated_batch = B_glob % m.n_data != 0
    L = cfg.n_layers
    P = cfg.n_params()

    if shape.kind == "prefill":
        S_eff = S + (cfg.n_img_tokens if cfg.frontend == "vision" else 0)
        fwd = sum(_layer_fwd(cfg, t, S_eff, B, m, k)
                  for t in cfg.layer_types())
        fwd += _encoder_fwd(cfg, B, m, k)
        fwd += _embed_head_fwd(cfg, 1, B, m)      # last-token logits
        flops = fwd
        hbm = P * k.param_bytes + L * _layer_act_bytes(cfg, S, B, m, k)
        act = B * S * cfg.d_model * k.act_bytes
    else:  # decode: one token, cache length S
        fwd = sum(_layer_fwd(cfg, t, 1, B, m, k, s_kv=S)
                  for t in cfg.layer_types())
        fwd += _embed_head_fwd(cfg, 1, B, m)
        flops = fwd
        # params + full KV/state cache read per token
        cache = _cache_bytes(cfg, S, B, m, k)
        hbm = P * k.param_bytes + cache + \
            L * 2 * B * cfg.d_model * k.act_bytes
        act = B * cfg.d_model * k.act_bytes

    coll = {}
    ring = 2 * (m.model - 1) / m.model
    coll["all-reduce"] = L * 2 * act * ring
    if replicated_batch:
        pass                                      # batch replicated: no DP
    if cfg.moe is not None:
        Sq = S if shape.kind == "prefill" else 1
        tok = B * Sq * cfg.moe.top_k * cfg.d_model * k.act_bytes
        coll["all-to-all"] = L * 2 * tok * (m.model - 1) / m.model
    total = sum(coll.values())
    return CellPerf(flops, hbm, total, coll)


def _cache_bytes(cfg, S, B, m, k) -> float:
    """Per-device cache traffic for one decode step (read k+v/state)."""
    KV, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    # full cache bytes / model shards (kv-head or head-dim sharded)
    per_layer = {
        ATTN: 2 * B * S * KV * Dh / m.model * k.act_bytes,
        LOCAL_ATTN: 2 * B * min(cfg.window, S) * KV * Dh / m.model
        * k.act_bytes,
        RGLRU: B * cfg.d_rnn_resolved / m.model * 4,
        RWKV: B * cfg.n_heads * (cfg.d_model // cfg.n_heads) ** 2
        / m.model * 4,
    }
    return sum(per_layer[t] for t in cfg.layer_types())


def cell_perf(arch: str, shape_name, mesh_kind: str,
              knobs: Optional[PerfKnobs] = None,
              cfg: Optional[ModelConfig] = None) -> CellPerf:
    from .dryrun import TRAIN_GRAD_ACCUM
    from ..configs import canonical
    cfg = cfg or get_config(arch)
    shape = shape_name if isinstance(shape_name, ShapeConfig) \
        else shape_by_name(shape_name)
    m = MESH_MULTI if mesh_kind == "multi" else MESH_SINGLE
    if knobs is None:
        knobs = PerfKnobs(
            grad_accum=TRAIN_GRAD_ACCUM.get(canonical(arch), 2)
            if shape.kind == "train" else 1)
    if shape.kind == "train":
        return train_cell(cfg, shape, m, knobs)
    return serve_cell(cfg, shape, m, knobs)
