"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/<cell>.json and derives, per (arch × shape × mesh):

  compute term    = FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(cost_analysis of the SPMD-partitioned module reports *per-device*
FLOPs/bytes, so the formulas divide by per-chip peaks directly — the
"/ chips" of the global-numbers formulation is already applied.)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Multi-pod 'pod' axis collectives ride DCN (~6.25 GB/s effective); the
per-op HLO doesn't label medium, so the collective term uses ICI bw and
the DCN adjustment is discussed qualitatively where it matters.

MODEL_FLOPS = 6·N·T (train) / 2·N·T (prefill) / 2·N·B (decode), with
N = active params for MoE; the ratio MODEL_FLOPS / HLO_FLOPs measures
how much compiled compute is "useful" (catches remat/redundancy waste —
values > 1 mean the compiler sees *less* than model flops, values ≪ 1
mean recompute/dispatch overhead dominates).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

from ..configs import ARCHS, get_config
from ..models.config import shape_by_name

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s ICI per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops_per_device(arch: str, shape_name: str, n_devices: int
                           ) -> float:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    n = cfg.n_active_params()
    if shape.kind == "train":
        total = 6.0 * n * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / n_devices


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        cells.append(rec)
    return cells


def analyze(rec: dict, knobs=None) -> Optional[dict]:
    """Roofline terms for one dry-run cell.

    FLOPs / HBM / collective bytes come from the validated analytic model
    (launch/perfmodel — XLA cost_analysis undercounts scanned modules;
    see launch/calibrate for the unit-module validation).  The dry-run
    JSON supplies the per-device memory footprint and the HLO collective
    census used to sanity-check which collective kinds exist.
    """
    if not rec.get("ok") or rec.get("skipped"):
        return None
    from . import perfmodel as PM
    perf = PM.cell_perf(rec["arch"], rec["shape"], rec["mesh"], knobs)
    t_c = perf.flops / PEAK_FLOPS
    t_m = perf.hbm_bytes / HBM_BW
    t_x = perf.coll_bytes / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_device(rec["arch"], rec["shape"],
                                rec["n_devices"])
    bound = max(t_c, t_m, t_x)
    # roofline fraction: useful-model-compute time over the bounding term
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": mf / perf.flops if perf.flops else 0.0,
        "roofline_fraction": frac,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
        "coll_by_kind": perf.coll_by_kind,
        "hlo_census": rec.get("collective_bytes", {}),
        "grad_accum": rec.get("grad_accum"),
    }


def table(tag: str = "") -> list[dict]:
    return [a for a in (analyze(r) for r in load_cells(tag))
            if a is not None]


def fmt_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s |"
           " dominant | MF/HLO | roofline frac | temp GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} "
            f"| {r['temp_gib']:.2f} |")
    return "\n".join(out)


def main():
    rows = table()
    print(fmt_markdown(rows))
    print()
    # summary: worst fractions / most collective-bound
    rows_s = sorted(rows, key=lambda r: r["roofline_fraction"])
    print("worst roofline fractions:")
    for r in rows_s[:6]:
        print(f"  {r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['roofline_fraction']:.2%} dom={r['dominant']}")
    coll = sorted(rows, key=lambda r: -(r["t_collective_s"] /
                                        max(r["t_compute_s"], 1e-12)))
    print("most collective-bound (vs compute):")
    for r in coll[:6]:
        print(f"  {r['arch']:26s} {r['shape']:12s} {r['mesh']:6s} "
              f"coll/comp={r['t_collective_s']/max(r['t_compute_s'],1e-12):.2f}")


if __name__ == "__main__":
    main()
