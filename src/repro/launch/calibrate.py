import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Validate the analytic perf model against XLA cost_analysis.

XLA's cost analysis counts while-loop bodies once (measured), so the
production scanned modules undercount FLOPs by their trip counts.  Here
we build **scan-free unit variants** of every architecture — layers
unrolled (one pattern period), grad_accum=1, naive attention, unrolled
wkv — where cost_analysis *is* exact, and compare it to the analytic
model's prediction for the same configuration.  Agreement on the units
justifies using the analytic model for the full-scale roofline terms.

Writes results/calib/<arch>.json and prints a summary table.
"""
import dataclasses
import json

import jax

from ..configs import ARCHS, get_config
from ..models.config import ModelConfig, ShapeConfig
from ..train import OptConfig
from . import perfmodel as PM
from .dryrun import RESULTS_DIR, lower_cell
from .mesh import make_production_mesh

CALIB_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "calib")


def unit_config(arch: str) -> ModelConfig:
    cfg = get_config(arch)
    period = len(cfg.pattern)
    return dataclasses.replace(
        cfg, n_layers=period, scan_layers=False,
        attention_impl="naive", rwkv_impl="unrolled", rwkv_chunk=8,
        encoder_layers=2 if cfg.is_encdec else 0,
        loss_chunk=0)


def run_arch(arch: str, force: bool = False) -> dict:
    os.makedirs(CALIB_DIR, exist_ok=True)
    path = os.path.join(CALIB_DIR, f"{arch}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = unit_config(arch)
    shape = ShapeConfig("unit_train", 256, 32, "train")
    mesh = make_production_mesh()
    lowered = lower_cell(cfg, shape, mesh, opt=OptConfig(grad_accum=1))
    compiled = lowered.compile()
    measured = float(compiled.cost_analysis()["flops"])
    knobs = PM.PerfKnobs(attention_tri=False, grad_accum=1, remat=True)
    predicted = PM.cell_perf(arch, shape, "single", knobs, cfg=cfg).flops
    rec = {"arch": arch, "unit_layers": cfg.n_layers,
           "measured_flops": measured, "predicted_flops": predicted,
           "ratio": predicted / measured if measured else float("nan")}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    print(f"{'arch':28s} {'measured':>12s} {'predicted':>12s} {'pred/meas':>9s}")
    for arch in ARCHS:
        try:
            r = run_arch(arch)
            print(f"{arch:28s} {r['measured_flops']:12.4e} "
                  f"{r['predicted_flops']:12.4e} {r['ratio']:9.3f}",
                  flush=True)
        except Exception as e:
            print(f"{arch:28s} FAIL {e!r}", flush=True)


if __name__ == "__main__":
    main()
