import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill/decode for serving shapes) against ShapeDtypeStruct
inputs with full production shardings, compiles it for the forced
512-device CPU topology, and records:

* ``memory_analysis()``  — per-device HBM footprint (proves it fits),
* ``cost_analysis()``    — HLO FLOPs / bytes for the roofline terms,
* collective bytes parsed from the HLO (launch.hlo),
* wall-clock lower/compile times.

Results are cached incrementally in results/dryrun/<cell>.json so the
full sweep is restartable (same contract as the pipeline journal).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, canonical, get_config
from ..models import inputs as I
from ..models import model as M
from ..models.config import ALL_SHAPES, ModelConfig, shape_by_name
from ..train import OptConfig, abstract_train_state, sharding as S
from ..train.trainer import make_decode_step, make_prefill_step, \
    make_train_step
from . import hlo
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Production micro-batching defaults (per arch): chosen so the per-device
# training working set fits 16 GB v5e HBM (see EXPERIMENTS.md §Perf —
# activation memory scales linearly with micro-batch).
TRAIN_GRAD_ACCUM = {
    "h2o_danube_1_8b": 2, "granite_moe_3b_a800m": 4, "rwkv6_1_6b": 2,
    "phi3_mini_3_8b": 2, "phi_3_vision_4_2b": 2, "whisper_large_v3": 2,
    "qwen2_5_14b": 4, "internlm2_20b": 4, "recurrentgemma_9b": 4,
    "qwen3_moe_235b_a22b": 8,
}


def default_opt(arch: str) -> OptConfig:
    return OptConfig(grad_accum=TRAIN_GRAD_ACCUM.get(canonical(arch), 2))


def _per_device_batch(shape, mesh) -> None:
    # train shapes must tile the data axes exactly; small serving batches
    # (long_500k B=1) replicate across data instead (batch_shardings).
    if shape.kind == "train":
        data_par = 1
        for n, s in zip(mesh.axis_names, mesh.devices.shape):
            if n in ("pod", "data"):
                data_par *= s
        assert shape.global_batch % data_par == 0, \
            (shape.name, shape.global_batch, data_par)


def lower_cell(cfg: ModelConfig, shape, mesh, opt: OptConfig = None,
               profile: str = "2d"):
    """Build + lower the step function for one cell. Returns lowered."""
    opt = opt or OptConfig()
    specs = I.input_specs(cfg, shape)           # raises SkipCell
    _per_device_batch(shape, mesh)
    batch_sh = S.batch_shardings(specs, mesh, profile)

    if shape.kind == "train":
        params, opt_state = abstract_train_state(cfg)
        p_sh = S.param_shardings(params, mesh, profile)
        o_sh = {"m": p_sh, "v": p_sh,
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())}
        step = make_train_step(cfg, opt, mesh, profile)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, batch_sh),
                         donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(params, opt_state, specs)

    params = M.abstract_params(cfg)
    if getattr(cfg, "serve_param_dtype", None) == "bfloat16":
        # production serving loads bf16 weights — halves the param-read
        # memory term and the checkpoint footprint (§Perf decode lever)
        params = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(
                sd.shape, jnp.bfloat16 if sd.dtype == jnp.float32
                else sd.dtype), params)
    p_sh = S.param_shardings(params, mesh)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, s_max=shape.seq_len, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
        with mesh:
            return jitted.lower(params, specs)

    # decode: one token against a seq_len cache
    caches = I.cache_specs(cfg, shape)
    c_sh = S.cache_shardings(caches, mesh)
    step = make_decode_step(cfg, mesh=mesh)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, batch_sh),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(params, caches, specs)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             cfg_override=None, tag: str = "", force: bool = False,
             opt_override: OptConfig = None, profile: str = "2d") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cell = f"{canonical(arch)}__{shape_name}__{mesh_kind}" + \
        (f"__{tag}" if tag else "")
    path = os.path.join(RESULTS_DIR, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("ok"):        # cached failures re-run (bugs get fixed)
            return cached

    cfg = cfg_override or get_config(arch)
    shape = shape_by_name(shape_name)
    opt = opt_override or default_opt(arch)
    record = {"arch": canonical(arch), "shape": shape_name,
              "mesh": mesh_kind, "tag": tag, "config": cfg.name,
              "grad_accum": opt.grad_accum if shape_name.startswith("train")
              else None}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        t0 = time.time()
        lowered = lower_cell(cfg, shape, mesh, opt=opt, profile=profile)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        # collectives only exist after SPMD partitioning → compiled text
        hlo_text = compiled.as_text()
        coll = hlo.collective_bytes(hlo_text)
        census = hlo.op_census(hlo_text)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        record.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "collective_bytes": coll,
            "op_census": census,
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes",
                                              0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "code_bytes": int(getattr(mem, "generated_code_size_in_bytes",
                                          0)),
            },
            "n_devices": int(mesh.devices.size),
        })
    except I.SkipCell as e:
        record.update({"ok": True, "skipped": str(e)})
    except Exception as e:  # record failures — they are bugs to fix
        record.update({"ok": False, "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]})
    with open(path + ".tmp", "w") as f:
        json.dump(record, f, indent=1)
    os.replace(path + ".tmp", path)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force)
                status = ("SKIP: " + rec["skipped"]) if rec.get("skipped") \
                    else ("OK" if rec.get("ok") else
                          "FAIL: " + rec.get("error", "?"))
                mem = rec.get("memory", {})
                print(f"{rec['arch']:26s} {shape:12s} {mesh_kind:6s} "
                      f"{status}"
                      + (f"  temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB"
                         f" args={mem.get('argument_bytes', 0)/2**30:.2f}GiB"
                         f" lower={rec.get('lower_s')}s"
                         f" compile={rec.get('compile_s')}s"
                         if rec.get("ok") and not rec.get("skipped") else ""),
                      flush=True)


if __name__ == "__main__":
    main()
