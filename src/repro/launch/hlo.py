"""HLO-text analysis: collective bytes + op census for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic;
this module parses the (lowered or compiled) HLO text and sums operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction, bucketed by op kind.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# shape tokens like f32[16,128]{1,0} or bf16[2,4,8]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# ops appear as  %name = TYPE[...] all-reduce(ARGS), or all-gather-start etc
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind (plus 'total').

    '-done' halves of async pairs are skipped to avoid double counting.
    """
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.":
            # async done ops re-mention the payload; skip only *-done calls
            if re.search(r"(all-gather|all-reduce|reduce-scatter|"
                         r"all-to-all|collective-permute)-done", line):
                continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, args = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(args))
        if nbytes == 0:
            # operands referenced by %name only — fall back to result shape
            pre = line.split("=", 1)[0] + "=" + \
                line.split("=", 1)[1].split(kind)[0]
            nbytes = sum(_shape_bytes(d, dims)
                         for d, dims in _SHAPE_RE.findall(pre))
        out[kind] += nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def op_census(hlo_text: str, ops=("fusion", "dot", "scatter", "gather",
                                  "transpose", "reshape", "copy",
                                  "while")) -> Dict[str, int]:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"= [a-z0-9_\[\]{{}},.]* ?{op}\(",
                                 hlo_text))
    return out
