"""End-to-end training driver.

Modes:
* ``--smoke`` — run a real training loop on CPU with a reduced config
  (the per-arch smoke family), optionally from the D4M pipeline's packet
  corpus — this is the runnable end-to-end example path.
* default    — production loop: sharded params on the production mesh,
  checkpoint/restart, async checkpointing, data-sampler state restore.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke \
      --steps 20 --data 'work/*.tsv'
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt
from ..configs import canonical, get_config, smoke_config
from ..data import SamplerState, TokenStream
from ..models import inputs as I
from ..models.config import ShapeConfig
from ..train import OptConfig, init_train_state, sharding as S
from ..train.trainer import make_train_step
from .mesh import make_production_mesh, make_smoke_mesh


def synth_corpus(workdir: str, n_files: int = 2) -> str:
    """Generate a small packet-log corpus via the D4M pipeline (stage 3
    TSV outputs) if none exists. Returns a glob pattern."""
    from ..db import EdgeStore
    from ..pipeline import PipelineConfig, TrafficConfig, run_pipeline
    pattern = os.path.join(workdir, "*.tsv")
    import glob
    if not glob.glob(pattern):
        cfg = PipelineConfig(
            workdir=workdir, n_files=n_files, duration_per_file_s=1.0,
            traffic=TrafficConfig(n_hosts=128, pkt_rate=2000.0),
            n_workers=2)
        run_pipeline(cfg, EdgeStore(n_tablets=2))
    return pattern


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--data", default=None,
                    help="glob of text/TSV files (default: synthesize "
                         "packet logs via the pipeline)")
    ap.add_argument("--workdir", default="work/train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.workdir, exist_ok=True)
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_smoke_mesh(len(jax.devices())) if args.smoke \
        else make_production_mesh()
    opt = OptConfig(warmup_steps=10)

    data_glob = args.data or synth_corpus(os.path.join(args.workdir, "data"))
    stream = TokenStream(data_glob, seq_len=args.seq, batch=args.batch)

    params, opt_state = init_train_state(cfg, jax.random.key(0))
    step0 = 0
    ckpt_dir = os.path.join(args.workdir, f"ckpt_{canonical(args.arch)}")
    if args.resume and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt_state, sampler), meta = ckpt.restore(
            ckpt_dir, (params, opt_state, stream.state.to_dict()))
        stream.state = SamplerState.from_dict(
            jax.tree.map(lambda x: int(np.asarray(x)), sampler))
        step0 = meta["step"] + 1
        print(f"resumed from step {meta['step']}")

    train_step = jax.jit(make_train_step(cfg, opt, mesh),
                         donate_argnums=(0, 1))
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    losses = []
    with mesh:
        for step in range(step0, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     stream.next_batch().items()}
            # clip token ids into this config's vocab for smoke runs
            batch = {k: jnp.minimum(v, cfg.vocab - 1) for k, v in
                     batch.items()}
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:4d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"{time.time()-t0:6.2f}s", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                saver.save_async(step, (params, opt_state,
                                        stream.state.to_dict()),
                                 {"step": step, "loss": loss})
    saver.wait()
    if len(losses) >= 10:
        first, last = np.mean(losses[:3]), np.mean(losses[-3:])
        print(f"loss {first:.3f} → {last:.3f} "
              f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
