"""Networked shard backend — the paper's topology, finally over a wire.

Everything before this module scales *inside* one process: the binding,
planner, cache, and WriterPool all run against in-process stores.  The
paper's headline result is topological — parallel Accumulo tablet
servers fed by independent D4M writer processes — and its follow-ons
push the same shape to 8×16 instance grids (arXiv:1902.00846) and
1.9B updates/s of streaming ingest (arXiv:1907.04217).  This module is
that shape: shard *servers* each owning a durable
:class:`~repro.db.lsmstore.LSMStore` (or a volatile
:class:`~repro.db.edgestore.EdgeStore`), and a *client* backend that
speaks the full EdgeStore scan protocol so ``DBTable``, ``LazyAssoc``
planning, the :class:`~repro.db.binding.ScanCache`, and the
:class:`~repro.db.writer.WriterPool` run on it completely unchanged.

Wire protocol — length-prefixed frames over TCP::

    frame   := magic(0xD5, 1B) | len(4B LE) | payload(len bytes)
    payload := JSON array
    request := [op, kwargs]
    reply   := ["ok", result]           one frame   (unary ops)
             | ["chunk", items]*        then
               ["end", null]                        (streaming scans)
             | ["err", type, message]               (op raised)

Design notes, each previously proven by the orphaned ``BENCH_net.json``
experiment:

* **batched puts** — one RPC per coalesced WriterPool block (the pool's
  tier-2 drain already concatenates everything queued), 10–35x over
  naive per-put RPCs;
* **chunked streaming scans** — servers stream ``chunk`` frames of
  ``chunk_items`` records, so a full-table scan never materializes on
  either side and the client's k-way instance merge
  (:meth:`MultiInstanceDB._merged`) stays streaming end-to-end;
* **sync barrier** — :meth:`NetMultiInstanceDB.sync` fans out to every
  shard whose client saw a write since the last barrier (per-shard
  dirty gate) and the server fsyncs its WAL; a clean barrier is a pure
  client-side check (~µs), which matters because *every* binding read
  issues a flush;
* **failover** — a dead shard surfaces as :class:`ConnectionError` from
  the RPC; the WriterPool's bounded-backoff retry path re-dials on each
  attempt (a restarted shard server picks the block up), and a shard
  that stays dead propagates a clear
  :class:`~repro.db.writer.AsyncWriterError` at the next barrier.

Delivery is at-least-once under retry (Accumulo BatchWriter semantics):
edge cells are last-write-wins so replays are idempotent; a retried
block whose first attempt died *after* the server applied it can
double-count degree sums — the same caveat Accumulo's combiner
documents.

Run a standalone shard server with::

    python -m repro.db.netstore --port 9101 --path /data/shard0

and bind the cluster with ``DB(..., backend="net",
addresses=["host:9101", ...])``.  With no ``addresses``,
``DB(..., backend="net", n_instances=4)`` auto-starts that many local
in-process servers (LSM-backed under ``path``, volatile otherwise) —
the single-node topology tests and benchmarks use.
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import zlib
from typing import Iterable, Optional, Sequence

import numpy as np

from ..core.assoc import Assoc
from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from ..obs.trace import span as _span, traced_iter as _traced_iter
from .edgestore import EdgeStore, MultiInstanceDB, connections_query

_MAGIC = 0xD5
_HDR = struct.Struct("<BI")
_MAX_FRAME = 1 << 30            # 1 GiB sanity bound on a length prefix

DEFAULT_CHUNK_ITEMS = 512       # records per streamed scan frame

# Client-side RPC metric families.  Children are labeled with both the
# shard address and a per-client id, so two clients dialing the same
# shard (e.g. across rebinds in one process) never merge counts — the
# ``n_rpcs`` compat property must read back only its own.  Replaces the
# unsynchronized ``self.n_rpcs += 1`` that concurrent reader threads
# used to race on.
_M_RPCS = _REGISTRY.counter(
    "repro_rpc_total", "Completed shard RPCs (client side)",
    labels=("shard", "client"))
_M_RPC_BYTES = _REGISTRY.counter(
    "repro_rpc_bytes_total",
    "Framed RPC bytes on the wire (client side), by direction",
    labels=("shard", "client", "dir"))


class ShardError(RuntimeError):
    """The shard server's op raised; message carries the remote error."""


# ---------------------------------------------------------------------------
# Framing.
# ---------------------------------------------------------------------------

def _send_frame(sock: socket.socket, obj) -> int:
    """Send one frame; returns its size on the wire (header + payload)."""
    payload = json.dumps(obj).encode()
    buf = _HDR.pack(_MAGIC, len(payload)) + payload
    sock.sendall(buf)
    return len(buf)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """n bytes or None on clean EOF; raises on a torn read mid-frame."""
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(min(n - got, 1 << 20))
        if not b:
            if got:
                raise ConnectionError("connection closed mid-frame")
            return None
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def _recv_frame_sized(sock: socket.socket):
    """(decoded payload, wire bytes), or (None, 0) on clean EOF between
    frames — the sized variant the client's byte counters use."""
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None, 0
    magic, n = _HDR.unpack(hdr)
    if magic != _MAGIC or n > _MAX_FRAME:
        raise ConnectionError(f"bad frame header (magic={magic:#x}, len={n})")
    payload = _recv_exact(sock, n)
    if payload is None:
        raise ConnectionError("connection closed mid-frame")
    return json.loads(payload.decode()), _HDR.size + n


def _recv_frame(sock: socket.socket):
    """Decoded payload, or None on clean EOF between frames."""
    return _recv_frame_sized(sock)[0]


# ---------------------------------------------------------------------------
# Server.
# ---------------------------------------------------------------------------

_STREAM_OPS = ("scan_keys", "scan_key_range", "scan_prefix",
               "scan_everything", "degree_items")


class ShardServer:
    """One shard: a TCP accept loop over a store speaking the EdgeStore
    scan protocol (one handler thread per connection; the store's own
    locks provide consistency).  ``port=0`` binds an ephemeral port —
    read it back from :attr:`address`."""

    def __init__(self, store, host: str = "127.0.0.1", port: int = 0,
                 chunk_items: int = DEFAULT_CHUNK_ITEMS):
        self.store = store
        self.chunk_items = chunk_items
        self._sock = socket.create_server((host, port))
        # poll the listener: a thread blocked in accept() is not reliably
        # woken by close() from stop(), and a 5 s join stall per shard
        # would dominate every backend teardown
        self._sock.settimeout(0.25)
        self.host, self.port = self._sock.getsockname()[:2]
        self.address = f"{self.host}:{self.port}"
        self._stopped = threading.Event()
        self._conns: set[socket.socket] = set()
        self._conns_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ShardServer":
        self._thread = threading.Thread(
            target=self._accept_loop, name=f"shard/{self.address}",
            daemon=True)
        self._thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return              # listener closed by stop()
            with self._conns_lock:
                if self._stopped.is_set():
                    conn.close()
                    return
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name=f"shard/{self.address}/conn",
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            conn.settimeout(None)   # accepted conns inherit the poll
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while True:
                try:
                    req = _recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                if req is None:
                    return
                op, kw = req
                try:
                    self._dispatch(conn, op, kw or {})
                except (BrokenPipeError, ConnectionError, OSError):
                    return
                except Exception as e:  # op failed: report, keep serving
                    try:
                        _send_frame(conn, ["err", type(e).__name__, str(e)])
                    except OSError:
                        return
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, conn: socket.socket, op: str, kw: dict) -> None:
        store = self.store
        if op in _STREAM_OPS:
            self._stream(conn, getattr(store, op)(**kw))
        elif op == "put_triples":
            n = store.put_triples(np.asarray(kw["r"], dtype=str),
                                  np.asarray(kw["c"], dtype=str),
                                  np.asarray(kw["v"], dtype=str))
            _send_frame(conn, ["ok", n])
        elif op == "put_degree":
            n = store.put_degree(Assoc(
                np.asarray(kw["keys"], dtype=str), "degree,",
                np.asarray(kw["counts"], dtype=np.float64)))
            _send_frame(conn, ["ok", n])
        elif op == "degree":
            _send_frame(conn, ["ok", store.degree(kw["col_key"])])
        elif op == "keys_with_prefix":
            _send_frame(conn, ["ok", list(store.keys_with_prefix(**kw))])
        elif op == "row":
            _send_frame(conn, ["ok", store.row(kw["row_key"])])
        elif op == "col":
            _send_frame(conn, ["ok", store.col(kw["col_key"])])
        elif op == "connections":
            _send_frame(conn, ["ok", connections_query(store, **kw)])
        elif op == "sync":
            sync = getattr(store, "sync", None)
            if sync is not None:
                sync()
            _send_frame(conn, ["ok", None])
        elif op == "n_entries":
            _send_frame(conn, ["ok", store.n_entries])
        elif op == "ping":
            _send_frame(conn, ["ok", "pong"])
        else:
            _send_frame(conn, ["err", "ValueError", f"unknown op {op!r}"])

    def _stream(self, conn: socket.socket, it: Iterable) -> None:
        chunk: list = []
        for item in it:
            k, v = item
            chunk.append([k, v])
            if len(chunk) >= self.chunk_items:
                _send_frame(conn, ["chunk", chunk])
                chunk = []
        if chunk:
            _send_frame(conn, ["chunk", chunk])
        _send_frame(conn, ["end", None])

    def stop(self, close_store: bool = False) -> None:
        """Stop serving: close the listener and every live connection
        (in-flight RPCs fail on the client as :class:`ConnectionError` —
        the failover tests kill shards this way).  ``close_store`` also
        closes the store (a durable store fsyncs on close)."""
        self._stopped.set()
        try:    # poke the listener so a blocked accept() observes the stop
            with socket.create_connection((self.host, self.port),
                                          timeout=0.5):
                pass
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=5)
        if close_store:
            close = getattr(self.store, "close", None)
            if close is not None:
                close()

    def __repr__(self) -> str:
        return f"ShardServer({self.address}, {type(self.store).__name__})"


# ---------------------------------------------------------------------------
# Client.
# ---------------------------------------------------------------------------

class ShardClient:
    """One shard's client: the EdgeStore scan/write protocol over framed
    RPCs.  Unary ops use a small pool of persistent connections (one
    in-flight request per connection); each streaming scan holds its own
    connection so a long scan never blocks concurrent puts, and an
    abandoned scan generator just discards its socket.

    Connections are (re-)dialed lazily per attempt, so the WriterPool's
    bounded-backoff retry path doubles as failover: a restarted shard
    server picks up the retried block, a shard that stays dead raises
    :class:`ConnectionError` until the pool gives up and surfaces
    :class:`~repro.db.writer.AsyncWriterError` at the barrier."""

    def __init__(self, address: str, name: Optional[str] = None,
                 connect_timeout: float = 5.0):
        host, _, port = address.rpartition(":")
        self.address = address
        self.host, self.port = host, int(port)
        self.name = name or f"shard@{address}"
        self.connect_timeout = connect_timeout
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()
        self._closed = False
        # dirty gate: sync() only pays the RPC when this client wrote
        # since the last barrier — every binding read flushes, and a
        # clean barrier must stay ~µs (pure client-side check)
        self._dirty = False
        # RPC counters live in the process registry (atomic — reader
        # threads scan concurrently); n_rpcs below reads them back
        self.metrics_label = _obj_label("client")
        self._m_rpcs = _M_RPCS.labels(shard=address,
                                      client=self.metrics_label)
        self._m_tx = _M_RPC_BYTES.labels(shard=address,
                                         client=self.metrics_label,
                                         dir="tx")
        self._m_rx = _M_RPC_BYTES.labels(shard=address,
                                         client=self.metrics_label,
                                         dir="rx")

    @property
    def n_rpcs(self) -> int:
        """Completed RPCs (unary replies + finished scan streams)."""
        return self._m_rpcs.value

    # -- connection pool ---------------------------------------------------
    def _dial(self) -> socket.socket:
        try:
            s = socket.create_connection((self.host, self.port),
                                         timeout=self.connect_timeout)
        except OSError as e:
            raise ConnectionError(
                f"shard {self.name} at {self.address} unreachable: {e}"
            ) from e
        s.settimeout(None)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    def _acquire(self) -> socket.socket:
        if self._closed:
            raise ConnectionError(f"shard client {self.name} is closed")
        with self._pool_lock:
            if self._pool:
                return self._pool.pop()
        return self._dial()

    def _release(self, s: socket.socket) -> None:
        with self._pool_lock:
            if not self._closed:
                self._pool.append(s)
                return
        s.close()

    @staticmethod
    def _discard(s: socket.socket) -> None:
        try:
            s.close()
        except OSError:
            pass

    # -- RPC core ----------------------------------------------------------
    def _rpc(self, op: str, **kw):
        with _span(f"rpc.{op}", shard=self.address):
            s = self._acquire()
            try:
                self._m_tx.inc(_send_frame(s, [op, kw]))
                reply, nbytes = _recv_frame_sized(s)
            except (ConnectionError, OSError) as e:
                self._discard(s)
                raise ConnectionError(
                    f"shard {self.name} at {self.address} failed during "
                    f"{op}: {e}") from e
            if reply is None:
                self._discard(s)
                raise ConnectionError(
                    f"shard {self.name} at {self.address} closed the "
                    f"connection during {op}")
            self._release(s)
            self._m_rx.inc(nbytes)
            self._m_rpcs.inc()
            status, *rest = reply
            if status == "err":
                raise ShardError(f"{self.name}: {rest[0]}: {rest[1]}")
            return rest[0]

    def _stream(self, op: str, **kw):
        """One traced span covers the stream's whole consumption (first
        ``next`` to exhaustion or abandonment) — spans can't be held
        open across generator suspensions, so :func:`traced_iter`
        records against the consumer's context instead."""
        return _traced_iter(f"rpc.{op}", self._stream_raw(op, **kw),
                            shard=self.address)

    def _stream_raw(self, op: str, **kw):
        s = self._acquire()
        try:
            try:
                self._m_tx.inc(_send_frame(s, [op, kw]))
                while True:
                    reply, nbytes = _recv_frame_sized(s)
                    if reply is None:
                        raise ConnectionError(
                            f"shard {self.name} at {self.address} closed "
                            f"the connection during {op}")
                    self._m_rx.inc(nbytes)
                    status, payload = reply[0], reply[1:]
                    if status == "end":
                        self._m_rpcs.inc()
                        self._release(s)
                        return
                    if status == "err":
                        self._release(s)
                        raise ShardError(
                            f"{self.name}: {payload[0]}: {payload[1]}")
                    for k, v in payload[0]:
                        yield k, v
            except (ConnectionError, OSError) as e:
                self._discard(s)
                if isinstance(e, ConnectionError):
                    raise
                raise ConnectionError(
                    f"shard {self.name} at {self.address} failed during "
                    f"{op}: {e}") from e
        except GeneratorExit:
            # abandoned mid-stream: the connection still carries frames —
            # never return it to the pool
            self._discard(s)
            raise

    # -- EdgeStore write protocol ------------------------------------------
    def put(self, E: Assoc) -> int:
        r, c, v = E.triples()
        return self.put_triples(r, c, np.asarray(v).astype(str))

    def put_triples(self, r, c, v) -> int:
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:   # client-side eviction, before the RPC
            cache.note_write(np.asarray(r, dtype=str),
                             np.asarray(c, dtype=str))
        self._dirty = True
        return int(self._rpc("put_triples",
                             r=np.asarray(r, dtype=str).tolist(),
                             c=np.asarray(c, dtype=str).tolist(),
                             v=np.asarray(v, dtype=str).tolist()))

    def put_degree(self, Edeg: Assoc) -> int:
        rr, _, vv = Edeg.triples()
        keys = np.asarray(rr, dtype=str)
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:
            cache.note_write(np.asarray([], dtype=str), keys)
        self._dirty = True
        return int(self._rpc("put_degree", keys=keys.tolist(),
                             counts=np.asarray(vv, np.float64).tolist()))

    def sync(self) -> None:
        """Durability barrier for *this client's* writes: no-op when
        clean, else one RPC that fsyncs the shard's WAL."""
        if not self._dirty:
            return
        self._rpc("sync")
        self._dirty = False

    # -- EdgeStore scan protocol -------------------------------------------
    def scan_keys(self, keys: Sequence[str], transpose: bool = False):
        yield from self._stream("scan_keys",
                                keys=[str(k) for k in keys],
                                transpose=transpose)

    def scan_key_range(self, start: str, stop: Optional[str],
                       transpose: bool = False):
        yield from self._stream("scan_key_range", start=start, stop=stop,
                                transpose=transpose)

    def scan_prefix(self, prefix: str, transpose: bool = False):
        yield from self._stream("scan_prefix", prefix=prefix,
                                transpose=transpose)

    def scan_everything(self, transpose: bool = False):
        yield from self._stream("scan_everything", transpose=transpose)

    def degree_items(self, prefix: str = ""):
        for k, v in self._stream("degree_items", prefix=prefix):
            yield k, float(v)

    def keys_with_prefix(self, prefix: str,
                         transpose: bool = True) -> list[str]:
        return list(self._rpc("keys_with_prefix", prefix=prefix,
                              transpose=transpose))

    def degree(self, col_key: str) -> float:
        return float(self._rpc("degree", col_key=col_key))

    def row(self, row_key: str) -> dict[str, str]:
        return self._rpc("row", row_key=row_key)

    def col(self, col_key: str) -> dict[str, str]:
        return self._rpc("col", col_key=col_key)

    def connections(self, ip: str, **kw) -> dict[str, float]:
        return {k: float(v)
                for k, v in self._rpc("connections", ip=ip, **kw).items()}

    def degree_assoc(self) -> Assoc:
        items = list(self.degree_items())
        if not items:
            return Assoc()
        return Assoc(np.asarray([k for k, _ in items], dtype=str),
                     "degree,",
                     np.asarray([v for _, v in items], dtype=np.float64))

    def ping(self) -> bool:
        return self._rpc("ping") == "pong"

    @property
    def n_entries(self) -> int:
        return int(self._rpc("n_entries"))

    def close(self) -> None:
        with self._pool_lock:
            self._closed = True
            pool, self._pool = self._pool, []
        for s in pool:
            self._discard(s)

    def __repr__(self) -> str:
        return f"ShardClient({self.name} at {self.address})"


# ---------------------------------------------------------------------------
# The backend: N shard clients behind the MultiInstanceDB fan-out.
# ---------------------------------------------------------------------------

class NetMultiInstanceDB(MultiInstanceDB):
    """M networked shards behind the same fan-out/merge machinery as the
    in-process topologies: ``instances`` are :class:`ShardClient`\\ s, so
    the inherited row-hash ``put_triples`` partitioning, streaming k-way
    scan merges, and degree aggregation all apply verbatim — and the
    WriterPool attaches one writer thread per shard.

    ``addresses`` connects to running :class:`ShardServer` processes.
    Without it, ``n_instances`` local in-process servers are started and
    owned by this backend (LSM-backed under ``path/db*`` when ``path``
    is given, volatile EdgeStores otherwise) — single-node mode, also
    what the tests and ``bench_net.py`` drive."""

    def __init__(self, addresses: Optional[Sequence[str]] = None,
                 n_instances: int = 2, path: Optional[str] = None,
                 tablets_per_instance: int = 4,
                 connect_timeout: float = 5.0,
                 chunk_items: int = DEFAULT_CHUNK_ITEMS, **engine_opts):
        self.servers: list[ShardServer] = []
        if addresses is None:
            for i in range(n_instances):
                if path is not None:
                    from .lsmstore import LSMStore
                    store = LSMStore(os.path.join(path, f"db{i}"),
                                     name=f"db{i}", **engine_opts)
                else:
                    store = EdgeStore(tablets_per_instance, name=f"db{i}",
                                      **engine_opts)
                self.servers.append(
                    ShardServer(store, chunk_items=chunk_items).start())
            addresses = [s.address for s in self.servers]
        elif engine_opts:
            raise ValueError(
                f"engine options {sorted(engine_opts)} apply to "
                f"auto-started local shards; remote servers own their "
                f"store configuration")
        self.instances = [
            ShardClient(addr, name=f"db{i}",
                        connect_timeout=connect_timeout)
            for i, addr in enumerate(addresses)]

    @staticmethod
    def key_hash(k: str) -> int:
        """Stable routing hash — shard placement is server-side state
        shared by every producer process, so the process-salted default
        would scatter a key's updates across shards."""
        return zlib.crc32(k.encode())

    def sync(self) -> None:
        """The cross-shard durability commit point: fan out to every
        dirty shard (each fsyncs its WAL); ~µs when no client-side
        writes are outstanding."""
        for inst in self.instances:
            inst.sync()

    def close(self) -> None:
        for inst in self.instances:
            inst.close()
        for srv in self.servers:
            srv.stop(close_store=True)

    def __repr__(self) -> str:
        kind = "local" if self.servers else "remote"
        return (f"NetMultiInstanceDB({len(self.instances)} {kind} "
                f"shard(s): {[i.address for i in self.instances]})")


# ---------------------------------------------------------------------------
# Standalone shard server CLI.
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> None:
    """``python -m repro.db.netstore --port 9101 --path /data/shard0``
    serves one shard until SIGTERM/SIGINT; prints ``LISTENING host:port``
    once bound (port 0 = ephemeral, for test harnesses)."""
    import argparse
    import signal

    p = argparse.ArgumentParser(description=main.__doc__)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0)
    p.add_argument("--path", default=None,
                   help="LSM store directory (durable); omit for a "
                        "volatile in-memory shard")
    p.add_argument("--tablets", type=int, default=4,
                   help="tablets for a volatile shard (ignored with "
                        "--path)")
    p.add_argument("--memtable-limit", type=int, default=200_000)
    p.add_argument("--chunk-items", type=int, default=DEFAULT_CHUNK_ITEMS)
    args = p.parse_args(argv)

    if args.path is not None:
        from .lsmstore import LSMStore
        store = LSMStore(args.path, memtable_limit=args.memtable_limit)
    else:
        store = EdgeStore(args.tablets, name="shard")
    srv = ShardServer(store, host=args.host, port=args.port,
                      chunk_items=args.chunk_items).start()
    print(f"LISTENING {srv.address}", flush=True)

    done = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: done.set())
    done.wait()
    srv.stop(close_store=True)


if __name__ == "__main__":
    main()
