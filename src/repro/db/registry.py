"""Backend registry — named storage engines behind the ``DB()`` surface.

PR 2 routed every caller through one binding; this registry is the
payoff: ``DB(..., backend="memory")``, ``DB(..., backend="lsm",
path=...)``, and ``DB(..., backend="net", addresses=[...])`` bind the
same query surface to interchangeable engines.
Anything implementing the :class:`~repro.db.edgestore.EdgeStore` scan
protocol (``scan_keys`` / ``scan_key_range`` / ``scan_prefix`` /
``scan_everything`` / ``degree`` / ``degree_items`` / ``put_triples`` /
``put_degree``) can register here and immediately serves ``DBTable``
subscripts, ``LazyAssoc`` planning, the :class:`ScanCache`, and the
async :class:`~repro.db.writer.WriterPool`.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from .edgestore import EdgeStore, MultiInstanceDB
from .lsmstore import LSMMultiInstanceDB, LSMStore

BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str, factory: Callable) -> None:
    """Register a named backend factory.  The factory is called as
    ``factory(n_instances=..., tablets_per_instance=..., path=...,
    **options)`` and must return a store speaking the EdgeStore scan
    protocol (single instance or a ``.instances`` fan-out)."""
    BACKENDS[name] = factory


def make_backend(name: str, *, n_instances: int = 1,
                 tablets_per_instance: int = 4,
                 path: Optional[str] = None, **options):
    try:
        factory = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(BACKENDS)}"
        ) from None
    return factory(n_instances=n_instances,
                   tablets_per_instance=tablets_per_instance,
                   path=path, **options)


def _memory(*, n_instances: int, tablets_per_instance: int,
            path: Optional[str] = None, **options):
    """The in-process engine (PR 0): volatile, fast, no ``path``."""
    if path is not None:
        raise ValueError("backend='memory' takes no path= (it is volatile); "
                         "use backend='lsm' for a durable store")
    if n_instances == 1:
        return EdgeStore(n_tablets=tablets_per_instance, **options)
    return MultiInstanceDB(n_instances=n_instances,
                           tablets_per_instance=tablets_per_instance,
                           **options)


def _net(*, n_instances: int, tablets_per_instance: int,
         path: Optional[str] = None, **options):
    """The networked shard engine: ``addresses=["host:port", ...]``
    connects to running ``repro.db.netstore`` shard servers; without it
    ``n_instances`` local servers are auto-started (LSM-backed under
    ``path`` when given).  See :mod:`repro.db.netstore`."""
    from .netstore import NetMultiInstanceDB
    return NetMultiInstanceDB(n_instances=n_instances, path=path,
                              tablets_per_instance=tablets_per_instance,
                              **options)


def _lsm(*, n_instances: int, tablets_per_instance: int,
         path: Optional[str] = None, **options):
    """The persistent LSM engine: WAL + memtable + sorted runs under
    ``path`` (one subdirectory per instance when ``n_instances > 1``).
    ``tablets_per_instance`` is accepted for signature parity and
    ignored — an LSM instance's parallelism is its run set."""
    del tablets_per_instance
    if path is None:
        raise ValueError("backend='lsm' requires path= (the store's "
                         "directory)")
    if n_instances == 1:
        return LSMStore(path, **options)
    return LSMMultiInstanceDB(path, n_instances=n_instances, **options)


register_backend("memory", _memory)
register_backend("lsm", _lsm)
register_backend("net", _net)
