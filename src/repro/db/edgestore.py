"""Sharded edge store — the Apache Accumulo analog (paper stage 6).

Accumulo is a distributed sorted key-value store; D4M's schema keeps three
tables: ``Tedge`` (packet × field|value), its transpose ``TedgeT`` (for
column queries — Accumulo only scans rows efficiently), and ``TedgeDeg``
(degree table maintained with a sum *combiner* at ingest time).  The
paper's central database finding is topological: **8 parallel 16-node
instances out-ingest one 128-node instance** because ingest throughput
scales with independent write paths while a single large instance
bottlenecks on coordination.

This module reproduces that topology faithfully:

* :class:`Tablet` — one tablet server: a sorted in-memory KV map with a
  sum-combiner degree column family and batched mutation queues.
* :class:`EdgeStore` — one Accumulo *instance*: N tablets with
  range-partitioned split points (like Accumulo tablet splits) and an
  instance-level ingest choke (models the master/coordination overhead
  that grows with instance size).
* :class:`MultiInstanceDB` — M parallel instances, hash-routed, i.e. the
  paper's "2, 4, 8 databases running in parallel each with 16 nodes".

The store is in-process (no network), but every scaling-relevant
mechanism — partitioning, combiners, batch writers, per-instance
coordination cost — is real, so the *shape* of the paper's Fig. 5 ingest
curve is reproducible (see benchmarks/bench_ingest.py).
"""
from __future__ import annotations

import bisect
import threading
from collections import defaultdict
from typing import Iterable, Optional, Sequence

import numpy as np

from ..core.assoc import Assoc


def connections_query(store, ip: str, fields=("ip.src", "ip.dst"),
                      sep: str = "|") -> dict[str, float]:
    """Fig. 2's query served *from the database*: packets touching
    ``ip`` → histogram of their other endpoints.  Works on any store
    exposing the ``row()``/``col()`` point-query protocol (EdgeStore,
    LSMStore, ...)."""
    out: defaultdict[str, float] = defaultdict(float)
    for field in fields:
        for pkt in store.col(f"{field}{sep}{ip}"):
            for ck in store.row(pkt):
                if ck.startswith("ip.src" + sep) or \
                        ck.startswith("ip.dst" + sep):
                    other = ck.split(sep, 1)[1]
                    if other != ip:
                        out[other] += 1.0
    return dict(out)


def _warn_query_deprecated(name: str) -> None:
    import warnings
    warnings.warn(
        f"EdgeStore.{name} is deprecated; query through the D4M binding "
        f"(repro.db.DB / DBTable subscripts) instead.",
        DeprecationWarning, stacklevel=3)


class Tablet:
    """One tablet server: sorted KV with sum-combiner degree support."""

    def __init__(self, tablet_id: str):
        self.tablet_id = tablet_id
        self._rows: dict[str, dict[str, str]] = {}
        self._sorted_keys: list[str] = []
        self._deg: defaultdict[str, float] = defaultdict(float)
        self._lock = threading.Lock()
        self.n_mutations = 0
        self.ingest_bytes = 0

    def mutate(self, rows: Sequence[str], cols: Sequence[str],
               vals: Sequence[str]) -> int:
        """Apply a batch of (row, col, val) mutations."""
        with self._lock:
            for r, c, v in zip(rows, cols, vals):
                cells = self._rows.get(r)
                if cells is None:
                    cells = self._rows[r] = {}
                    bisect.insort(self._sorted_keys, r)
                cells[c] = v
                self.n_mutations += 1
                self.ingest_bytes += len(r) + len(c) + len(v)
        return len(rows)

    def combine_degree(self, keys: Sequence[str], counts: Sequence[float]):
        """Sum-combiner column update (TedgeDeg maintenance)."""
        with self._lock:
            for k, n in zip(keys, counts):
                self._deg[k] += float(n)

    def scan_row(self, row: str) -> dict[str, str]:
        return dict(self._rows.get(row, {}))

    def scan_range(self, start: str, stop: str) -> Iterable[tuple[str, dict]]:
        for k in self.keys_in_range(start, stop):
            yield k, dict(self._rows[k])

    def degree(self, key: str) -> float:
        return self._deg.get(key, 0.0)

    def scan_all(self) -> Iterable[tuple[str, dict]]:
        """Full tablet scan in key order."""
        for k in self._sorted_keys:
            yield k, dict(self._rows[k])

    def keys_in_range(self, start: str, stop: str) -> list[str]:
        lo = bisect.bisect_left(self._sorted_keys, start)
        hi = bisect.bisect_right(self._sorted_keys, stop)
        return self._sorted_keys[lo:hi]

    @property
    def n_rows(self) -> int:
        return len(self._rows)


class EdgeStore:
    """One Accumulo instance: Tedge + TedgeT + TedgeDeg over N tablets.

    ``coordination_cost_s`` models the per-batch master overhead that
    grows with instance size — the mechanism behind the paper's
    8×16 > 1×128 observation.  Set to 0 for pure in-process benchmarking.
    """

    def __init__(self, n_tablets: int = 16, name: str = "db0",
                 coordination_cost_s: float = 0.0):
        self.name = name
        self.n_tablets = n_tablets
        self.tablets = [Tablet(f"{name}/t{i:03d}") for i in range(n_tablets)]
        self.tablets_t = [Tablet(f"{name}/tT{i:03d}") for i in range(n_tablets)]
        self.coordination_cost_s = coordination_cost_s
        self._lock = threading.Lock()

    # -- routing ----------------------------------------------------------
    def _route(self, keys: np.ndarray) -> np.ndarray:
        """Stable hash-partition of row keys onto tablets."""
        h = np.asarray([hash(k) for k in keys], dtype=np.int64)
        return np.abs(h) % self.n_tablets

    # -- ingest (the paper's `put(Tedge, putVal(E,'1,'))`) -----------------
    def put(self, E: Assoc) -> int:
        """Insert an incidence matrix: Tedge + transpose + degree table."""
        r, c, v = E.triples()
        return self.put_triples(r, c, np.asarray(v).astype(str))

    def put_triples(self, r: np.ndarray, c: np.ndarray,
                    v: np.ndarray) -> int:
        """Raw triple mutation batch (the binding layer's batched-writer
        entry point — skips Assoc construction on the write path)."""
        import time
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:   # evict cached bands this batch touches
            cache.note_write(r, c)
        if self.coordination_cost_s:
            time.sleep(self.coordination_cost_s * self.n_tablets / 16.0)
        # Tedge (row-keyed)
        t_ids = self._route(r)
        for t in np.unique(t_ids):
            m = t_ids == t
            self.tablets[t].mutate(r[m], c[m], v[m])
        # TedgeT (column-keyed — enables Fig. 2 queries)
        t_ids = self._route(c)
        for t in np.unique(t_ids):
            m = t_ids == t
            self.tablets_t[t].mutate(c[m], r[m], v[m])
        # TedgeDeg via sum combiner
        keys, counts = np.unique(c, return_counts=True)
        t_ids = self._route(keys)
        for t in np.unique(t_ids):
            m = t_ids == t
            self.tablets[t].combine_degree(keys[m], counts[m])
        return int(r.shape[0])

    def put_degree(self, Edeg: Assoc) -> int:
        """Explicit degree-table insert (paper: put(TedgeDeg, num2str(Edeg)))."""
        r, _, v = Edeg.triples()
        keys = np.asarray(r, dtype=str)
        counts = np.asarray(v, dtype=np.float64)
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:   # degree bands are keyed by column keys
            cache.note_write(np.asarray([], dtype=str), keys)
        t_ids = self._route(keys)
        for t in np.unique(t_ids):
            m = t_ids == t
            self.tablets[t].combine_degree(keys[m], counts[m])
        return int(keys.shape[0])

    # -- queries ------------------------------------------------------------
    def row(self, row_key: str) -> dict[str, str]:
        return self.tablets[self._route(np.asarray([row_key]))[0]] \
            .scan_row(row_key)

    def col(self, col_key: str) -> dict[str, str]:
        """All row keys bearing ``col_key`` — via the transpose table."""
        return self.tablets_t[self._route(np.asarray([col_key]))[0]] \
            .scan_row(col_key)

    # -- binding-layer scans (repro.db.binding routes through these) -------
    def _table(self, transpose: bool) -> list[Tablet]:
        return self.tablets_t if transpose else self.tablets

    def scan_keys(self, keys: Sequence[str], transpose: bool = False):
        """Yield (key, cells) in key order for the given Tedge/TedgeT
        row keys (sorted so instance streams merge without buffering)."""
        tabs = self._table(transpose)
        uniq = sorted(set(keys))
        if uniq:
            for key, t in zip(uniq, self._route(np.asarray(uniq, dtype=str))):
                cells = tabs[t].scan_row(key)
                if cells:
                    yield key, cells

    def scan_key_range(self, start: str, stop: str,
                       transpose: bool = False):
        """Yield (key, cells) in key order for the inclusive [start, stop]
        range — every tablet holds a sorted shard (a key lives in exactly
        one tablet), so a k-way merge over the N tablet range scans
        streams the result (Accumulo's tablet-parallel scan pattern)."""
        import heapq
        yield from heapq.merge(
            *(t.scan_range(start, stop) for t in self._table(transpose)),
            key=lambda kv: kv[0])

    def scan_prefix(self, prefix: str, transpose: bool = False):
        yield from self.scan_key_range(prefix, prefix + "￿",
                                       transpose=transpose)

    def scan_everything(self, transpose: bool = False):
        import heapq
        yield from heapq.merge(
            *(t.scan_all() for t in self._table(transpose)),
            key=lambda kv: kv[0])

    def keys_with_prefix(self, prefix: str,
                         transpose: bool = True) -> list[str]:
        """Enumerate stored keys under ``prefix`` (degree-guard probe)."""
        out: list[str] = []
        for t in self._table(transpose):
            out.extend(t.keys_in_range(prefix, prefix + "￿"))
        return out

    def degree_items(self, prefix: str = ""):
        """Yield (col_key, degree) pairs from TedgeDeg, optionally
        restricted to a key prefix."""
        for t in self.tablets:
            for k, v in t._deg.items():
                if not prefix or k.startswith(prefix):
                    yield k, v

    # -- deprecated pre-binding query surface ------------------------------
    def query_row(self, row_key: str) -> dict[str, str]:
        """Deprecated: use ``DB(...)`` / ``DBTable[row_key, :]``."""
        _warn_query_deprecated("query_row")
        return self.row(row_key)

    def query_col(self, col_key: str) -> dict[str, str]:
        """Deprecated: use ``DBTable[:, col_key]``."""
        _warn_query_deprecated("query_col")
        return self.col(col_key)

    def query_degree(self, col_key: str) -> float:
        """Deprecated: use ``DBTable.degree(col_key)``."""
        _warn_query_deprecated("query_degree")
        return self.degree(col_key)

    def degree(self, col_key: str) -> float:
        return self.tablets[self._route(np.asarray([col_key]))[0]] \
            .degree(col_key)

    def degree_assoc(self) -> Assoc:
        """Materialize TedgeDeg as an Assoc (for analytics)."""
        keys, vals = [], []
        for t in self.tablets:
            for k, vv in t._deg.items():
                keys.append(k)
                vals.append(vv)
        if not keys:
            return Assoc()
        return Assoc(np.asarray(keys, dtype=str), "degree,",
                     np.asarray(vals))

    def connections(self, ip: str, **kw) -> dict[str, float]:
        return connections_query(self, ip, **kw)

    # -- stats --------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return sum(t.n_mutations for t in self.tablets)

    @property
    def ingest_bytes(self) -> int:
        return sum(t.ingest_bytes for t in self.tablets) + \
            sum(t.ingest_bytes for t in self.tablets_t)


class MultiInstanceDB:
    """M parallel EdgeStore instances (the paper's winning topology)."""

    def __init__(self, n_instances: int = 8, tablets_per_instance: int = 16,
                 coordination_cost_s: float = 0.0):
        self.instances = [
            EdgeStore(tablets_per_instance, name=f"db{i}",
                      coordination_cost_s=coordination_cost_s)
            for i in range(n_instances)]

    @staticmethod
    def key_hash(k: str) -> int:
        """Row/file → instance hash.  Process-salted is fine here (the
        store is volatile); durable subclasses must override with a
        stable hash — instance placement outlives the process there."""
        return abs(hash(k))

    def route(self, file_id: str):
        return self.instances[self.key_hash(file_id) % len(self.instances)]

    def put(self, E: Assoc, file_id: str = "") -> int:
        return self.route(file_id).put(E)

    def put_triples(self, r: np.ndarray, c: np.ndarray,
                    v: np.ndarray) -> int:
        """Row-hash partition a triple batch across instances — the
        independent parallel write paths behind the paper's 8×16 > 1×128
        ingest finding, without tying a whole file to one instance."""
        if not len(r):
            return 0
        h = np.asarray([self.key_hash(k) for k in r], dtype=np.int64)
        part = h % len(self.instances)
        n = 0
        for i in np.unique(part):
            m = part == i
            n += self.instances[i].put_triples(r[m], c[m], v[m])
        return n

    # -- binding-layer scans (instance fan-out + merge) --------------------
    def scan_keys(self, keys, transpose: bool = False):
        yield from self._merged(lambda inst: inst.scan_keys(
            keys, transpose=transpose))

    def scan_key_range(self, start: str, stop: str, transpose: bool = False):
        yield from self._merged(lambda inst: inst.scan_key_range(
            start, stop, transpose=transpose))

    def scan_prefix(self, prefix: str, transpose: bool = False):
        yield from self._merged(lambda inst: inst.scan_prefix(
            prefix, transpose=transpose))

    def scan_everything(self, transpose: bool = False):
        yield from self._merged(lambda inst: inst.scan_everything(
            transpose=transpose))

    def _merged(self, scan):
        """Fan a scan out over all instances, merging cells per key (a
        key's entries may be spread across instances by batch routing).
        Instance streams are key-sorted, so this is a streaming k-way
        merge — no full-result buffering on large scans."""
        import heapq
        cur_key = None
        cur_cells: dict[str, str] = {}
        for k, cells in heapq.merge(*(scan(inst) for inst in self.instances),
                                    key=lambda kv: kv[0]):
            if k == cur_key:
                cur_cells.update(cells)
            else:
                if cur_key is not None:
                    yield cur_key, cur_cells
                cur_key, cur_cells = k, dict(cells)
        if cur_key is not None:
            yield cur_key, cur_cells

    def keys_with_prefix(self, prefix: str, transpose: bool = True):
        out: set[str] = set()
        for inst in self.instances:
            out.update(inst.keys_with_prefix(prefix, transpose=transpose))
        return sorted(out)

    def degree_items(self, prefix: str = ""):
        acc: defaultdict[str, float] = defaultdict(float)
        for inst in self.instances:
            for k, v in inst.degree_items(prefix):
                acc[k] += v
        return iter(acc.items())

    def query_row(self, row_key: str) -> dict[str, str]:
        """Deprecated: use ``DBTable[row_key, :]``."""
        _warn_query_deprecated("query_row")
        out: dict[str, str] = {}
        for inst in self.instances:
            out.update(inst.row(row_key))
        return out

    def query_col(self, col_key: str) -> dict[str, str]:
        """Deprecated: use ``DBTable[:, col_key]``."""
        _warn_query_deprecated("query_col")
        out: dict[str, str] = {}
        for inst in self.instances:
            out.update(inst.col(col_key))
        return out

    def query_degree(self, col_key: str) -> float:
        """Deprecated: use ``DBTable.degree(col_key)``."""
        _warn_query_deprecated("query_degree")
        return self.degree(col_key)

    def degree(self, col_key: str) -> float:
        return sum(inst.degree(col_key) for inst in self.instances)

    def connections(self, ip: str, **kw) -> dict[str, float]:
        out: defaultdict[str, float] = defaultdict(float)
        for inst in self.instances:
            for k, v in inst.connections(ip, **kw).items():
                out[k] += v
        return dict(out)

    def degree_assoc(self) -> Assoc:
        out = Assoc()
        for inst in self.instances:
            out = out + inst.degree_assoc()
        return out

    @property
    def n_entries(self) -> int:
        return sum(i.n_entries for i in self.instances)
