"""Persistent LSM edge store — a durable Accumulo analog behind ``DB()``.

The in-process :class:`~repro.db.edgestore.EdgeStore` reproduces the
paper's *topology* (tablets, combiners, parallel instances) but not its
*durability*: Accumulo is a persistent sorted store, and the D4M
follow-on work (arXiv:1902.00846's hierarchical in-memory databases,
arXiv:1907.04217's 1.9B updates/sec) wins precisely by layering fast
in-memory tiers over sorted on-disk runs — which is an LSM tree.

:class:`LSMStore` is one instance of that design:

* **write-ahead log** — every mutation batch is framed (CRC-checked)
  and appended to ``wal.log`` before it touches the memtable; replayed
  on open, truncated at the first torn frame, so a crash at any instant
  loses nothing past the last :meth:`sync` (the WriterPool flush
  barrier's commit point);
* **memtable** — the in-memory tier: sorted cell maps for Tedge and
  TedgeT plus the sum-combiner TedgeDeg column family (exactly the
  :class:`~repro.db.edgestore.Tablet` families, minus the sharding);
* **sorted runs** — when the memtable exceeds ``memtable_limit``
  mutations it spills to an immutable SSTable: sorted key records in
  blocks, a sparse block index, and a salted-CRC prefix bloom filter
  (point and prefix scans skip runs that cannot contain the key);
* **compaction** — ``compact()`` (and an automatic trigger at
  ``max_runs``) merges every run combiner-aware: newest run wins per
  cell, degrees *sum* — the Accumulo iterator-stack semantics;
* **recovery** — ``open`` = list runs + replay WAL; reopening after a
  kill reproduces exactly the synced state.

The scan protocol (``scan_keys`` / ``scan_key_range`` / ``scan_prefix``
/ ``scan_everything`` / ``degree`` / ``degree_items`` / ``put_triples``
/ ``put_degree``) matches :class:`EdgeStore`, so ``DB()``, ``LazyAssoc``
planning, ``ScanCache``, and ``WriterPool`` run unchanged on top.
:class:`LSMMultiInstanceDB` shards instances across subdirectories —
the paper's 8×16 parallel-instance topology, durable.
"""
from __future__ import annotations

import bisect
import io
import json
import os
import struct
import threading
import zlib
from collections import defaultdict
from typing import Optional, Sequence

import numpy as np

from ..core.assoc import Assoc
from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from ..obs.trace import span as _span, traced_iter as _traced_iter
from .edgestore import MultiInstanceDB, connections_query

# -- WAL framing -------------------------------------------------------------
# frame := magic(1B) kind(1B) len(4B LE) payload crc32(4B LE)
_WAL_MAGIC = 0xD4
_KIND_TRIPLES = 0x01
_KIND_DEGREE = 0x02
_FRAME_HDR = struct.Struct("<BBI")
_FRAME_CRC = struct.Struct("<I")

# -- LSM metric families (one labeled child per live store) ------------------
# The ROADMAP's compaction-hardening item needs these to quantify write
# amplification and stall time; ``n_syncs`` keeps its attribute shape as
# a property over the sync counter.
_M_WAL_APPENDS = _REGISTRY.counter(
    "repro_lsm_wal_appends_total", "WAL frames appended", labels=("store",))
_M_SPILLS = _REGISTRY.counter(
    "repro_lsm_spills_total", "Memtable spills to immutable runs",
    labels=("store",))
_M_COMPACTIONS = _REGISTRY.counter(
    "repro_lsm_compactions_total", "Full-merge compactions",
    labels=("store",))
_M_SYNCS = _REGISTRY.counter(
    "repro_lsm_syncs_total", "WAL fsyncs (durability barriers)",
    labels=("store",))

# -- SSTable layout ----------------------------------------------------------
_SST_FORMAT = 1
_BLOCK_KEYS = 64            # sparse-index granularity (records per block)
_BLOOM_PREFIX_LEN = 8       # chars of key prefix also inserted in the bloom
_BLOOM_BITS_PER_KEY = 10
_BLOOM_HASHES = 4


def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed file's entry is durable —
    without this, a power loss could drop a spilled run while keeping
    the subsequent WAL truncation."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return              # platform without directory fds
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _bloom_hashes(key: str, n_bits: int) -> list[int]:
    """k stable hash positions (salted CRC32 — Python's str hash is
    process-salted and must never reach disk)."""
    data = key.encode()
    return [zlib.crc32(data, seed * 0x9E3779B1 + 1) % n_bits
            for seed in range(_BLOOM_HASHES)]


class _Bloom:
    def __init__(self, n_keys: int, bits: Optional[bytearray] = None):
        n_bits = max(8, n_keys * _BLOOM_BITS_PER_KEY)
        self.bits = bits if bits is not None else bytearray((n_bits + 7) // 8)
        self.n_bits = len(self.bits) * 8

    def add(self, key: str) -> None:
        for h in _bloom_hashes(key, self.n_bits):
            self.bits[h >> 3] |= 1 << (h & 7)

    def __contains__(self, key: str) -> bool:
        return all(self.bits[h >> 3] & (1 << (h & 7))
                   for h in _bloom_hashes(key, self.n_bits))

    def hex(self) -> str:
        return self.bits.hex()

    @classmethod
    def from_hex(cls, s: str) -> "_Bloom":
        return cls(0, bytearray.fromhex(s))


class _Memtable:
    """The in-memory tier: Tedge + TedgeT cell maps and the TedgeDeg
    sum-combiner family.  Not thread-safe — the owning store locks."""

    def __init__(self):
        self.edge: dict[str, dict[str, str]] = {}
        self.edge_t: dict[str, dict[str, str]] = {}
        self.deg: defaultdict[str, float] = defaultdict(float)
        self.n_mutations = 0
        self.ingest_bytes = 0

    def apply_triples(self, r: Sequence[str], c: Sequence[str],
                      v: Sequence[str]) -> None:
        for rk, ck, vv in zip(r, c, v):
            self.edge.setdefault(rk, {})[ck] = vv
            self.edge_t.setdefault(ck, {})[rk] = vv
            self.n_mutations += 1
            self.ingest_bytes += len(rk) + len(ck) + len(vv)
        for ck, n in zip(*np.unique(np.asarray(c, dtype=str),
                                    return_counts=True)):
            self.deg[str(ck)] += float(n)

    def apply_degree(self, keys: Sequence[str], counts: Sequence[float]):
        for k, n in zip(keys, counts):
            self.deg[str(k)] += float(n)


class SSTable:
    """One immutable sorted run: per-table sorted records in blocks, a
    sparse (first-key, offset) index per table, and a bloom filter over
    full keys and their ``_BLOOM_PREFIX_LEN``-char prefixes."""

    def __init__(self, path: str):
        self.path = path
        # hold the handle for the run's lifetime: compaction unlinks
        # superseded runs, and POSIX keeps an open fd readable, so a scan
        # that snapshotted this run before a concurrent compact still works
        self._f = open(path, "rb")
        self._io_lock = threading.Lock()
        self._f.seek(-8, os.SEEK_END)
        (footer_off,) = struct.unpack("<Q", self._f.read(8))
        self._f.seek(footer_off)
        footer = json.loads(self._f.read()[:-8].decode())
        if footer.get("format") != _SST_FORMAT:
            raise ValueError(f"{path}: unknown SSTable format")
        self.index: dict[str, list] = footer["index"]   # table → [[key, off]]
        self.blooms = {t: _Bloom.from_hex(h)
                       for t, h in footer["bloom"].items()}
        self.meta = footer["meta"]    # n_mutations, ingest_bytes

    # -- readers -----------------------------------------------------------
    def _read_from(self, table: str, start: str, stop: Optional[str],
                   limit: Optional[int] = None) -> list[tuple]:
        """Records of ``table`` with start <= key (<= stop), beginning at
        the sparse-index block that may contain ``start``."""
        idx = self.index.get(table) or []
        if not idx:
            return []
        firsts = [e[0] for e in idx]
        b = max(bisect.bisect_right(firsts, start) - 1, 0)
        out: list[tuple] = []
        with self._io_lock:
            self._f.seek(idx[b][1])
            for line in self._f:
                if line.startswith(b"#end "):
                    break
                key, payload = json.loads(line.decode())
                if stop is not None and key > stop:
                    break
                if key >= start:
                    out.append((key, payload))
                    if limit is not None and len(out) >= limit:
                        break
        return out

    def scan_range(self, table: str, start: str,
                   stop: Optional[str]) -> list[tuple]:
        """(key, payload) records in the inclusive [start, stop] range
        (``stop=None`` = unbounded)."""
        return self._read_from(table, start, stop)

    def scan_all(self, table: str) -> list[tuple]:
        return self._read_from(table, "", None)

    def get(self, table: str, key: str):
        """Point lookup (bloom-gated, one block touched)."""
        if table in self.blooms and key not in self.blooms[table]:
            return None
        hit = self._read_from(table, key, key, limit=1)
        return hit[0][1] if hit else None

    def may_contain_prefix(self, table: str, prefix: str) -> bool:
        """False only when the bloom proves no key starts with ``prefix``
        (usable when the query prefix covers the indexed prefix length)."""
        bloom = self.blooms.get(table)
        if bloom is None or len(prefix) < _BLOOM_PREFIX_LEN:
            return True
        return prefix[:_BLOOM_PREFIX_LEN] in bloom

    @staticmethod
    def write(path: str, edge: dict, edge_t: dict, deg: dict,
              meta: dict) -> None:
        """Serialize sorted sections + index + bloom; atomic rename and
        fsync so a run either exists completely or not at all."""
        buf = io.BytesIO()
        buf.write(json.dumps({"format": _SST_FORMAT}).encode() + b"\n")
        index: dict[str, list] = {}
        blooms: dict[str, str] = {}
        for table, data in (("edge", edge), ("edgeT", edge_t),
                            ("deg", deg)):
            keys = sorted(data)
            bloom = _Bloom(len(keys))
            entries = []
            for i, k in enumerate(keys):
                if i % _BLOCK_KEYS == 0:
                    entries.append([k, buf.tell()])
                bloom.add(k)
                if len(k) >= _BLOOM_PREFIX_LEN:
                    bloom.add(k[:_BLOOM_PREFIX_LEN])
                buf.write(json.dumps([k, data[k]]).encode() + b"\n")
            buf.write(b"#end " + table.encode() + b"\n")
            index[table] = entries
            blooms[table] = bloom.hex()
        footer_off = buf.tell()
        buf.write(json.dumps({"format": _SST_FORMAT, "index": index,
                              "bloom": blooms, "meta": meta}).encode())
        buf.write(struct.pack("<Q", footer_off))
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(buf.getvalue())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(os.path.dirname(path) or ".")


class LSMStore:
    """One durable instance: WAL + memtable + sorted runs (see module
    docstring).  Speaks the :class:`EdgeStore` scan protocol."""

    def __init__(self, path: str, name: Optional[str] = None,
                 memtable_limit: int = 200_000, max_runs: int = 8):
        self.path = path
        self.name = name or os.path.basename(os.path.abspath(path)) or "lsm"
        self.memtable_limit = memtable_limit
        self.max_runs = max_runs
        self._lock = threading.RLock()
        self._mem = _Memtable()
        self._runs: list[SSTable] = []
        self._wal_dirty = False
        self.metrics_label = _obj_label("lsm")
        lab = dict(store=self.metrics_label)
        self._m_wal_appends = _M_WAL_APPENDS.labels(**lab)
        self._m_spills = _M_SPILLS.labels(**lab)
        self._m_compactions = _M_COMPACTIONS.labels(**lab)
        self._m_syncs = _M_SYNCS.labels(**lab)
        os.makedirs(path, exist_ok=True)
        for fn in sorted(f for f in os.listdir(path)
                         if f.startswith("run-") and f.endswith(".sst")):
            self._runs.append(SSTable(os.path.join(path, fn)))
        self._next_run = 1 + max(
            [int(os.path.basename(r.path)[4:-4]) for r in self._runs],
            default=0)
        self._wal_path = os.path.join(path, "wal.log")
        self._replay_wal()
        self._wal = open(self._wal_path, "ab")

    # -- WAL ---------------------------------------------------------------
    def _replay_wal(self) -> None:
        """Rebuild the memtable from the log; truncate at the first torn
        or corrupt frame (a crash mid-append leaves exactly that)."""
        if not os.path.exists(self._wal_path):
            return
        good = 0
        with open(self._wal_path, "rb") as f:
            data = f.read()
        off = 0
        while off + _FRAME_HDR.size <= len(data):
            magic, kind, n = _FRAME_HDR.unpack_from(data, off)
            end = off + _FRAME_HDR.size + n + _FRAME_CRC.size
            if magic != _WAL_MAGIC or end > len(data):
                break
            payload = data[off + _FRAME_HDR.size:end - _FRAME_CRC.size]
            (crc,) = _FRAME_CRC.unpack_from(data, end - _FRAME_CRC.size)
            if zlib.crc32(payload) != crc:
                break
            rec = json.loads(payload.decode())
            if kind == _KIND_TRIPLES:
                self._mem.apply_triples(*rec)
            elif kind == _KIND_DEGREE:
                self._mem.apply_degree(*rec)
            good = end
            off = end
        if good < len(data):        # drop the torn tail
            with open(self._wal_path, "r+b") as f:
                f.truncate(good)

    def _wal_append(self, kind: int, record) -> None:
        payload = json.dumps(record).encode()
        self._wal.write(_FRAME_HDR.pack(_WAL_MAGIC, kind, len(payload))
                        + payload + _FRAME_CRC.pack(zlib.crc32(payload)))
        self._wal.flush()           # to the OS; fsync only at sync()
        self._wal_dirty = True
        self._m_wal_appends.inc()

    def _wal_apply(self, kind: int, record, apply) -> None:
        """Append the frame, then apply it to the memtable; roll the WAL
        back if *either* step fails (a torn append — e.g. ENOSPC — or an
        apply error), so a writer-pool retry of the same block cannot
        leave torn or duplicate frames that a later recovery would drop
        or double-count (the degree family is a sum combiner).  Caller
        holds the lock."""
        self._wal.flush()
        wal_off = self._wal.tell()
        try:
            self._wal_append(kind, record)
            apply()
        except BaseException:
            # discard partial frame bytes (buffered and on disk) by
            # reopening at the pre-append offset; best-effort close —
            # its flush may be the very failure we are recovering from
            try:
                self._wal.close()
            except OSError:
                pass
            with open(self._wal_path, "rb+") as f:
                f.truncate(wal_off)
            self._wal = open(self._wal_path, "ab")
            raise

    def sync(self) -> None:
        """fsync the WAL — the durability commit point.  The binding's
        flush barrier (WriterPool.flush) calls this, which is what makes
        "applied at the flush barrier" also mean "survives a crash"."""
        with self._lock:
            if not self._wal_dirty:
                return
            os.fsync(self._wal.fileno())
            self._wal_dirty = False
            self._m_syncs.inc()

    @property
    def n_syncs(self) -> int:
        """WAL fsyncs performed (registry-backed compat shape)."""
        return self._m_syncs.value

    def close(self) -> None:
        with self._lock:
            self.sync()
            self._wal.close()

    # -- ingest (EdgeStore protocol) ---------------------------------------
    def put(self, E: Assoc) -> int:
        r, c, v = E.triples()
        return self.put_triples(r, c, np.asarray(v).astype(str))

    def put_triples(self, r: np.ndarray, c: np.ndarray,
                    v: np.ndarray) -> int:
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:
            cache.note_write(r, c)
        rec = [np.asarray(r, dtype=str).tolist(),
               np.asarray(c, dtype=str).tolist(),
               np.asarray(v, dtype=str).tolist()]
        with self._lock:
            self._wal_apply(_KIND_TRIPLES, rec,
                            lambda: self._mem.apply_triples(*rec))
            if self._mem.n_mutations >= self.memtable_limit:
                self._spill_locked()
        return int(np.asarray(r).shape[0])

    def put_degree(self, Edeg: Assoc) -> int:
        rr, _, vv = Edeg.triples()
        keys = np.asarray(rr, dtype=str)
        counts = np.asarray(vv, dtype=np.float64)
        cache = getattr(self, "_scan_cache", None)
        if cache is not None:
            cache.note_write(np.asarray([], dtype=str), keys)
        rec = [keys.tolist(), counts.tolist()]
        with self._lock:
            self._wal_apply(_KIND_DEGREE, rec,
                            lambda: self._mem.apply_degree(*rec))
        return int(keys.shape[0])

    # -- spill + compaction -------------------------------------------------
    def _spill_locked(self) -> None:
        """Memtable → immutable run; WAL resets only after the run is
        durably on disk (fsync'd file + rename), so no window loses data."""
        mem = self._mem
        if not mem.n_mutations and not mem.deg:
            return
        with _span("lsm.spill", store=self.name, rows=mem.n_mutations):
            self._m_spills.inc()
            path = os.path.join(self.path, f"run-{self._next_run:06d}.sst")
            SSTable.write(path, mem.edge, mem.edge_t, dict(mem.deg),
                          {"n_mutations": mem.n_mutations,
                           "ingest_bytes": mem.ingest_bytes})
            self._next_run += 1
            self._runs.append(SSTable(path))
            self._mem = _Memtable()
            self._wal.close()
            self._wal = open(self._wal_path, "wb")  # truncate: spilled
            self._wal.flush()
            os.fsync(self._wal.fileno())    # persist the truncation — or a
            self._wal_dirty = False         # power loss could resurrect the
                                            # old WAL on top of the new run
        if len(self._runs) > self.max_runs:
            self._compact_locked()

    def spill(self) -> None:
        """Explicit memtable → run spill (tests, shutdown compaction)."""
        with self._lock:
            self._spill_locked()

    def compact(self) -> None:
        """Merge every run into one, combiner-aware: newest wins per
        cell, degrees sum (the Accumulo iterator-stack semantics)."""
        with self._lock:
            self._compact_locked()

    def _compact_locked(self) -> None:
        if len(self._runs) <= 1:
            return
        with _span("lsm.compact", store=self.name, runs=len(self._runs)):
            self._m_compactions.inc()
            edge: dict[str, dict[str, str]] = {}
            edge_t: dict[str, dict[str, str]] = {}
            deg: defaultdict[str, float] = defaultdict(float)
            n_mut = n_bytes = 0
            for run in self._runs:          # oldest → newest: newer wins
                for k, cells in run.scan_all("edge"):
                    edge.setdefault(k, {}).update(cells)
                for k, cells in run.scan_all("edgeT"):
                    edge_t.setdefault(k, {}).update(cells)
                for k, d in run.scan_all("deg"):
                    deg[k] += float(d)
                n_mut += run.meta["n_mutations"]
                n_bytes += run.meta["ingest_bytes"]
            path = os.path.join(self.path, f"run-{self._next_run:06d}.sst")
            SSTable.write(path, edge, edge_t, dict(deg),
                          {"n_mutations": n_mut, "ingest_bytes": n_bytes})
            self._next_run += 1
            old = self._runs
            self._runs = [SSTable(path)]
            for run in old:
                os.remove(run.path)

    # -- scans (EdgeStore protocol) ----------------------------------------
    def _section(self, transpose: bool) -> str:
        return "edgeT" if transpose else "edge"

    def _point(self, key: str, table: str, mem_attr: str) -> dict[str, str]:
        """LSM read path for one key: oldest run first, memtable last —
        each tier overwrites the cells of the tier below."""
        with self._lock:
            runs = list(self._runs)
            mem = dict(getattr(self._mem, mem_attr).get(key, {}))
        out: dict[str, str] = {}
        for run in runs:
            cells = run.get(table, key)
            if cells:
                out.update(cells)
        out.update(mem)
        return out

    # scan generators are traced via traced_iter (one span per full
    # consumption — a span can't stay open across generator yields);
    # the *_raw variants are the real scans, also used for internal
    # delegation so one logical scan never records twice.
    def scan_keys(self, keys: Sequence[str], transpose: bool = False):
        return _traced_iter("lsm.scan_keys",
                            self._scan_keys_raw(keys, transpose),
                            store=self.name)

    def _scan_keys_raw(self, keys: Sequence[str], transpose: bool = False):
        table = self._section(transpose)
        uniq = sorted(set(keys))
        with self._lock:    # snapshot, then read/yield outside the lock
            runs = list(self._runs)
            mem_map = self._mem.edge_t if transpose else self._mem.edge
            mem = {k: dict(mem_map[k]) for k in uniq if k in mem_map}
        for key in uniq:
            out: dict[str, str] = {}
            for run in runs:
                cells = run.get(table, key)
                if cells:
                    out.update(cells)
            out.update(mem.get(key, {}))
            if out:
                yield key, out

    def scan_key_range(self, start: str, stop: Optional[str],
                       transpose: bool = False):
        """Inclusive [start, stop] in key order (``stop=None`` =
        unbounded): k-way merge of the memtable and every run, newer
        tiers overwriting per cell."""
        return _traced_iter("lsm.scan_key_range",
                            self._scan_key_range_raw(start, stop, transpose),
                            store=self.name)

    def _scan_key_range_raw(self, start: str, stop: Optional[str],
                            transpose: bool = False):
        import heapq
        table = self._section(transpose)
        with self._lock:
            runs = list(self._runs)
            mem_map = self._mem.edge_t if transpose else self._mem.edge
            mem_items = [(k, dict(mem_map[k]))
                         for k in sorted(mem_map)
                         if k >= start and (stop is None or k <= stop)]
        # tiers ordered oldest → newest; the tier index tie-breaks equal
        # keys in the merge so dict.update applies newest last
        tiers = [run.scan_range(table, start, stop) for run in runs]
        tiers.append(mem_items)

        def tag(tier, i):
            for k, cells in tier:
                yield k, i, cells

        streams = [tag(t, i) for i, t in enumerate(tiers)]
        cur_key, cur_cells = None, None
        for k, _, cells in heapq.merge(*streams, key=lambda e: (e[0], e[1])):
            if k == cur_key:
                cur_cells.update(cells)
            else:
                if cur_key is not None:
                    yield cur_key, cur_cells
                cur_key, cur_cells = k, dict(cells)
        if cur_key is not None:
            yield cur_key, cur_cells

    def scan_prefix(self, prefix: str, transpose: bool = False):
        return _traced_iter("lsm.scan_prefix",
                            self._scan_prefix_raw(prefix, transpose),
                            store=self.name)

    def _scan_prefix_raw(self, prefix: str, transpose: bool = False):
        table = self._section(transpose)
        with self._lock:
            bloom_skip = not any(r.may_contain_prefix(table, prefix)
                                 for r in self._runs)
            if bloom_skip:      # no run can hold the prefix: memtable only
                mem_map = self._mem.edge_t if transpose else self._mem.edge
                items = [(k, dict(mem_map[k])) for k in sorted(mem_map)
                         if k.startswith(prefix)]
        if bloom_skip:
            yield from items
            return
        yield from self._scan_key_range_raw(prefix, prefix + "￿",
                                            transpose=transpose)

    def scan_everything(self, transpose: bool = False):
        # stop=None, not a '￿' sentinel — astral-plane keys sort
        # above any BMP bound and must still appear in full scans
        return _traced_iter("lsm.scan_everything",
                            self._scan_key_range_raw("", None, transpose),
                            store=self.name)

    def keys_with_prefix(self, prefix: str,
                         transpose: bool = True) -> list[str]:
        return [k for k, _ in self.scan_prefix(prefix, transpose=transpose)]

    # -- degree family ------------------------------------------------------
    def degree(self, col_key: str) -> float:
        with self._lock:
            total = self._mem.deg.get(col_key, 0.0)
            runs = list(self._runs)
        for run in runs:
            d = run.get("deg", col_key)
            if d is not None:
                total += float(d)
        return total

    def degree_items(self, prefix: str = ""):
        return _traced_iter("lsm.degree_items",
                            self._degree_items_raw(prefix), store=self.name)

    def _degree_items_raw(self, prefix: str = ""):
        acc: defaultdict[str, float] = defaultdict(float)
        with self._lock:
            for k, d in self._mem.deg.items():
                if not prefix or k.startswith(prefix):
                    acc[k] += d
            runs = list(self._runs)
        for run in runs:
            if prefix and not run.may_contain_prefix("deg", prefix):
                continue
            it = (run.scan_range("deg", prefix, prefix + "￿")
                  if prefix else run.scan_all("deg"))
            for k, d in it:
                acc[k] += float(d)
        yield from acc.items()

    def degree_assoc(self) -> Assoc:
        items = list(self.degree_items())
        if not items:
            return Assoc()
        return Assoc(np.asarray([k for k, _ in items], dtype=str),
                     "degree,",
                     np.asarray([v for _, v in items], dtype=np.float64))

    # -- point queries (EdgeStore compatibility) ---------------------------
    def row(self, row_key: str) -> dict[str, str]:
        return self._point(row_key, "edge", "edge")

    def col(self, col_key: str) -> dict[str, str]:
        return self._point(col_key, "edgeT", "edge_t")

    def connections(self, ip: str, **kw) -> dict[str, float]:
        return connections_query(self, ip, **kw)

    # -- stats --------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        with self._lock:
            return self._mem.n_mutations + sum(
                r.meta["n_mutations"] for r in self._runs)

    @property
    def ingest_bytes(self) -> int:
        with self._lock:
            return self._mem.ingest_bytes + sum(
                r.meta["ingest_bytes"] for r in self._runs)

    @property
    def n_runs(self) -> int:
        return len(self._runs)

    def __repr__(self) -> str:
        return (f"LSMStore({self.path!r}, entries={self.n_entries}, "
                f"runs={self.n_runs}, mem={self._mem.n_mutations})")


class LSMMultiInstanceDB(MultiInstanceDB):
    """M parallel durable instances sharded across subdirectories
    (``<path>/db0 … dbM-1``) — the paper's 8×16 topology with each
    instance owning its own WAL and run set.  Inherits the scan fan-out
    / k-way merge machinery from :class:`MultiInstanceDB`."""

    def __init__(self, path: str, n_instances: int = 8,
                 memtable_limit: int = 200_000, max_runs: int = 8):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self.instances = [
            LSMStore(os.path.join(path, f"db{i}"), name=f"db{i}",
                     memtable_limit=memtable_limit, max_runs=max_runs)
            for i in range(n_instances)]

    @staticmethod
    def key_hash(k: str) -> int:
        """Stable routing hash: instance placement is on-disk state, so
        a row must map to the same subdirectory in every process —
        Python's salted ``hash()`` would scatter a key's updates across
        instances between restarts and break last-write-wins."""
        return zlib.crc32(k.encode())

    def sync(self) -> None:
        for inst in self.instances:
            inst.sync()

    def spill(self) -> None:
        for inst in self.instances:
            inst.spill()

    def compact(self) -> None:
        for inst in self.instances:
            inst.compact()

    def close(self) -> None:
        for inst in self.instances:
            inst.close()
