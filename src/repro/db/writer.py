"""Async batched writers — the binding layer's parallel ingest path.

The paper's ingest result (§IV-F: 8×16-node instances out-ingest one
128-node instance) and its follow-ons (arXiv:1907.04217's 1.9B
updates/sec, arXiv:1902.00846's hierarchical in-memory buffering) all
rest on one mechanism: **independent write paths kept busy with large
coalesced batches**.  The synchronous ``DBTable.put`` loop leaves that
on the table — each batch blocks the caller through every instance's
coordination stall in turn.

:class:`WriterPool` restores the overlap with a two-tier hierarchy:

* **tier 1 — caller-local buffers**: ``submit`` hash-partitions a triple
  batch and appends to per-instance buffers (no locks contended, no
  thread wake-ups on the hot path); a buffer *spills* to its writer
  queue as one coalesced block once it holds ``spill_rows`` rows;
* **tier 2 — per-instance writer threads**: one thread per
  :class:`~repro.db.edgestore.EdgeStore` instance drains its queue,
  further coalescing everything queued into a single mutation — so the
  instance's per-batch coordination stall is paid once per drain, not
  once per submitted batch, and stalls overlap across instances.

Guarantees:

* **per-instance ordering** — buffers, queues, and the single writer
  thread are all FIFO; row-hash partitioning sends a given row to the
  same instance every time, so per-key last-write-wins order holds;
* **bounded memory** — buffers spill at ``spill_rows``; queues have
  ``maxsize`` (backpressure, not unbounded buffering);
* **flush barrier** — :meth:`flush` spills every buffer and returns only
  when every block queued *before the call* is applied (mutations
  visible to scans); :meth:`drain` is the same wait without the
  durability fsync — the binding's read barrier, so gateway reader
  threads are never serialized behind ingest that keeps arriving while
  they wait (each barrier is a snapshot of the spill sequence, not a
  wait for an empty queue);
* **bounded retry** — a failed block is re-put with exponential backoff
  (``max_retries`` per block, Accumulo BatchWriter semantics); the
  single writer thread retries in place, so per-instance FIFO order is
  preserved across retries;
* **error propagation** — a block that exhausts its retries is recorded
  and re-raised as :class:`AsyncWriterError` from the next ``submit``,
  ``flush``, or ``close`` (the writer keeps draining so barriers never
  hang; the dead block's writes are lost — the caller decides whether
  to re-put).

Durability contract: an async ``put`` is *applied* no later than the
next ``flush()`` — the pipeline's stage-6 tasks enqueue and return, and
the driver's end-of-DAG flush barrier is the commit point (see
``pipeline/driver.py``).  On durable backends (anything exposing
``sync()``, e.g. :class:`~repro.db.lsmstore.LSMStore`) ``flush`` also
fsyncs the WAL, so the barrier commits to disk, not just to memory.
"""
from __future__ import annotations

import queue
import threading
import time
import weakref
import zlib
from typing import Optional

import numpy as np

from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from ..obs.trace import span as _span

_STOP = object()

# Writer-pool metric families: one labeled child per live pool (the pool
# keeps the only strong ref).  n_written / n_retried / tap_errors are
# properties over these children — one count, read by both stats() and
# /metrics.  The gauges read live pool state at scrape via weakref.
_M_WRITTEN = _REGISTRY.counter(
    "repro_writer_written_total", "Triples applied by writer threads",
    labels=("pool",))
_M_RETRIED = _REGISTRY.counter(
    "repro_writer_retried_total",
    "Blocks that succeeded only after at least one retry",
    labels=("pool",))
_M_WRITE_ERRORS = _REGISTRY.counter(
    "repro_writer_errors_total",
    "Blocks that exhausted their retries (writes lost)", labels=("pool",))
_M_TAP_ERRORS = _REGISTRY.counter(
    "repro_writer_tap_errors_total",
    "Ingest-tap callbacks that raised (counted, never propagated)",
    labels=("pool",))
_M_PENDING = _REGISTRY.gauge(
    "repro_writer_pending",
    "Rows buffered plus blocks enqueued but not yet applied",
    labels=("pool",))
_M_QUEUE_DEPTH = _REGISTRY.gauge(
    "repro_writer_queue_depth", "Blocks sitting in writer queues",
    labels=("pool",))


def _stable_key_hash(k: str) -> int:
    """Fallback routing hash for backends without a ``key_hash`` hook:
    crc32, matching ``LSMMultiInstanceDB.key_hash`` — ``pin=``-based
    file→instance routing must agree across producer processes, and
    Python's ``hash()`` is process-salted."""
    return zlib.crc32(k.encode())


class AsyncWriterError(RuntimeError):
    """A background writer thread failed; raised at the next barrier."""


class _InstanceWriter:
    """One store's write path: a bounded queue drained by one thread."""

    def __init__(self, store, maxsize: int, pool: "WriterPool"):
        self.store = store
        self.pool = pool
        self.q: "queue.Queue" = queue.Queue(maxsize=maxsize)
        self.buf: list = []          # tier-1 buffer, guarded by pool lock
        self.buf_rows = 0
        # spill-sequence barrier state: blocks are queued as
        # (seq, block); applied_seq advances (under cond) once a block's
        # mutation has landed — error or not, so barriers never hang.
        # Barriers snapshot spilled_seq and wait for applied_seq to
        # reach it, which waits only on blocks that *preceded* the
        # barrier, never on ingest still arriving behind it.
        self.spilled_seq = 0         # guarded by pool lock (spill path)
        self.applied_seq = 0         # guarded by cond
        self.cond = threading.Condition()
        self.thread = threading.Thread(
            target=self._loop, name=f"writer/{store.name}", daemon=True)
        self.thread.start()

    def _loop(self) -> None:
        while True:
            # tier-2 coalescing: drain everything queued and apply it as
            # ONE mutation — one coordination stall per drain, not per
            # submitted batch.
            items = [self.q.get()]
            try:
                while True:
                    items.append(self.q.get_nowait())
            except queue.Empty:
                pass
            stop = any(it is _STOP for it in items)
            batches = [it for it in items if it is not _STOP]
            # any failure (even concatenation OOM) must be recorded, and
            # task_done must run, or flush()'s q.join() hangs forever
            try:
                if batches:
                    r = np.concatenate([b[0] for (_, b) in batches])
                    c = np.concatenate([b[1] for (_, b) in batches])
                    v = np.concatenate([b[2] for (_, b) in batches])
                    self._apply_with_retry(r, c, v)
            except BaseException as e:  # noqa: BLE001 — propagate at barrier
                self.pool._record_error(e)
            finally:
                if batches:
                    with self.cond:
                        self.applied_seq = max(self.applied_seq,
                                               *(s for (s, _) in batches))
                        self.cond.notify_all()
                for _ in items:
                    self.q.task_done()
            if stop:
                return

    def _await_applied(self, seq: int) -> None:
        """Block until every block spilled at or before ``seq`` has been
        applied (or recorded as failed — ``applied_seq`` advances either
        way, so a dead block can never wedge a barrier)."""
        with self.cond:
            while self.applied_seq < seq:
                self.cond.wait()

    def _apply_with_retry(self, r, c, v) -> None:
        """Re-put a failed block with bounded exponential backoff
        (Accumulo BatchWriter semantics).  Retrying in place on the
        single writer thread keeps per-instance FIFO order; a block
        that exhausts ``max_retries`` is recorded for the next barrier."""
        for attempt in range(self.pool.max_retries + 1):
            try:
                fault = self.pool.fault_injector
                if fault is not None:
                    fault.maybe_kill(f"writer/{self.store.name}")
                self.pool._m_written.inc(self.store.put_triples(r, c, v))
                if attempt:
                    self.pool._m_retried.inc()
                self.pool._notify_taps(r, c, v)
                return
            except BaseException as e:  # noqa: BLE001 — propagate at barrier
                if attempt >= self.pool.max_retries:
                    self.pool._record_error(e)
                    return
                time.sleep(min(self.pool.retry_backoff_s * (2 ** attempt),
                               self.pool.retry_backoff_max_s))


class WriterPool:
    """Background writer pool over any registered backend (EdgeStore,
    MultiInstanceDB, LSMStore, or their multi-instance fan-outs).

    One writer thread per instance.  ``submit`` partitions a triple batch
    by row hash across instances (mirroring
    :meth:`MultiInstanceDB.put_triples`) or pins it to one instance when
    ``pin`` (a file id) is given — the paper's file→instance routing.
    """

    def __init__(self, backend, maxsize: int = 32,
                 spill_rows: int = 25_000, fault_injector=None,
                 max_retries: int = 2, retry_backoff_s: float = 0.05,
                 retry_backoff_max_s: float = 2.0):
        # duck-typed so any registered backend works: a multi-instance
        # store exposes .instances; a single instance exposes the
        # EdgeStore write protocol directly
        if hasattr(backend, "instances"):
            stores = list(backend.instances)
        elif callable(getattr(backend, "put_triples", None)):
            stores = [backend]
        else:
            raise TypeError(f"cannot attach writers to {type(backend)!r}")
        self.backend = backend
        # partition with the backend's own routing hash — durable
        # backends use a process-stable hash so queued writes land in
        # the same instance directories as every other process's
        self._key_hash = getattr(backend, "key_hash",
                                 None) or _stable_key_hash
        self.spill_rows = spill_rows
        self.fault_injector = fault_injector
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_max_s = retry_backoff_max_s
        self._lock = threading.Lock()       # guards tier-1 buffers
        # errors get their own lock: _spill can block on a full queue
        # while holding _lock, and the writer thread must still be able
        # to record a failure (and free a queue slot) without deadlock
        self._err_lock = threading.Lock()
        self._errors: list[BaseException] = []
        self._closed = False
        # ingest taps: callables observing every applied block *as it
        # drains* (streaming rollups ride this — no extra table scan).
        # Registration is copy-on-write so _notify_taps never locks.
        self._taps: tuple = ()
        self.metrics_label = _obj_label("pool")
        lab = dict(pool=self.metrics_label)
        self._m_written = _M_WRITTEN.labels(**lab)
        self._m_retried = _M_RETRIED.labels(**lab)
        self._m_write_errors = _M_WRITE_ERRORS.labels(**lab)
        self._m_tap_errors = _M_TAP_ERRORS.labels(**lab)
        self._m_pending = _M_PENDING.labels(**lab)
        self._m_queue_depth = _M_QUEUE_DEPTH.labels(**lab)
        # live-read gauges: weakref-closing so the gauge (held weakly by
        # its family anyway) never resurrects or pins a closed pool
        ref = weakref.ref(self)
        self._m_pending.set_function(lambda: ref().pending)
        self._m_queue_depth.set_function(lambda: ref().queue_depth)
        self._writers = [_InstanceWriter(s, maxsize, self) for s in stores]

    # -- ingest taps --------------------------------------------------------
    def add_tap(self, fn) -> None:
        """Register ``fn(rows, cols, vals)`` to observe each triple block
        right after its mutation lands (called on the writer thread, so a
        slow tap backpressures that instance's queue — keep taps cheap).
        A tap exception is counted, not propagated: observers must never
        fail ingest."""
        with self._err_lock:
            self._taps = self._taps + (fn,)

    def remove_tap(self, fn) -> None:
        with self._err_lock:
            self._taps = tuple(t for t in self._taps if t is not fn)

    def _notify_taps(self, r, c, v) -> None:
        for fn in self._taps:
            try:
                fn(r, c, v)
            except BaseException:   # noqa: BLE001 — observer, not writer
                self._m_tap_errors.inc()

    # -- error plumbing ----------------------------------------------------
    def _record_error(self, e: BaseException) -> None:
        self._m_write_errors.inc()
        with self._err_lock:
            self._errors.append(e)

    def _check(self) -> None:
        with self._err_lock:
            if self._errors:
                e = self._errors[0]
                raise AsyncWriterError(
                    f"{len(self._errors)} async write block(s) failed; "
                    f"first: {e!r}") from e

    # -- ingest ------------------------------------------------------------
    def submit(self, r: np.ndarray, c: np.ndarray, v: np.ndarray,
               pin: Optional[str] = None) -> int:
        """Buffer a triple batch; spills to the writers once the
        per-instance buffer reaches ``spill_rows``.  Blocks only on
        queue backpressure during a spill."""
        self._check()
        if self._closed:
            raise RuntimeError("writer pool is closed")
        n = int(np.asarray(r).shape[0])
        if not n:
            return 0
        nw = len(self._writers)
        # partition outside the lock — the O(n) hashing must not
        # serialize concurrent producers; the lock only covers appends
        if nw == 1:
            parts = [(0, (r, c, v), n)]
        elif pin is not None:
            parts = [(self._key_hash(pin) % nw, (r, c, v), n)]
        else:
            h = np.asarray([self._key_hash(k) for k in r], dtype=np.int64)
            part = h % nw
            parts = []
            for i in np.unique(part):
                m = part == i
                parts.append((int(i), (r[m], c[m], v[m]), int(m.sum())))
        with self._lock:
            for i, item, ni in parts:
                self._buffer(self._writers[i], item, ni)
        return n

    def _buffer(self, w: _InstanceWriter, item, n: int) -> None:
        """Tier-1 append; spill when full.  Caller holds the lock."""
        w.buf.append(item)
        w.buf_rows += n
        if w.buf_rows >= self.spill_rows:
            self._spill(w)

    def _spill(self, w: _InstanceWriter) -> None:
        if not w.buf:
            return
        if len(w.buf) == 1:
            block = w.buf[0]
        else:
            block = tuple(np.concatenate([b[i] for b in w.buf])
                          for i in range(3))
        w.buf = []
        w.buf_rows = 0
        w.spilled_seq += 1
        w.q.put((w.spilled_seq, block))

    # -- barriers ----------------------------------------------------------
    def _barrier(self) -> None:
        """Spill every buffer, then wait for the *snapshot* of spilled
        blocks to apply.  Ingest submitted while we wait does not extend
        the wait — the property that keeps many concurrent reader
        barriers live during sustained ingest."""
        with self._lock:
            for w in self._writers:
                self._spill(w)
            targets = [(w, w.spilled_seq) for w in self._writers]
        for w, seq in targets:
            w._await_applied(seq)
        self._check()

    def drain(self) -> None:
        """Visibility barrier (the binding's read path): all ``submit``\\ s
        that happened before this call are applied and visible to scans.
        No durability fsync — reads need visibility, not persistence —
        so on LSM/net backends concurrent readers skip the WAL/RPC sync
        entirely."""
        self._barrier()

    def flush(self) -> None:
        """Durability barrier: :meth:`drain` semantics *plus* the backend
        fsync; re-raises writer errors.  After ``flush`` returns cleanly,
        all prior ``submit``\\ s are visible to scans and, on a durable
        backend, committed to disk (the WAL commit point)."""
        self._barrier()
        self._sync_backend()

    def _sync_backend(self) -> None:
        sync = getattr(self.backend, "sync", None)
        if sync is not None:
            with _span("backend.sync"):
                sync()

    def close(self) -> None:
        """Flush, stop the writer threads, and re-raise pending errors."""
        if self._closed:
            self._check()
            return
        self._closed = True
        with self._lock:
            for w in self._writers:
                self._spill(w)
        for w in self._writers:
            w.q.put(_STOP)
        for w in self._writers:
            w.thread.join()
        self._check()
        self._sync_backend()
        # the writers' back-pointers make pool <-> writer a reference
        # cycle; cut it so a closed pool (and the backend it pins) frees
        # by refcount instead of waiting on a gen-2 gc pass
        for w in self._writers:
            w.pool = None

    # -- introspection -----------------------------------------------------
    @property
    def pending(self) -> int:
        """Rows buffered plus blocks enqueued but not yet applied.  Read
        under the pool lock: ``buf_rows`` moves to ``unfinished_tasks``
        at spill time while that lock is held, so a locked read can't
        see a row in both tiers (or neither) mid-spill."""
        with self._lock:
            return (sum(w.buf_rows for w in self._writers)
                    + sum(w.q.unfinished_tasks for w in self._writers))

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return sum(w.q.qsize() for w in self._writers)

    # registry-backed counter reads (compat: pre-obs attribute shapes)
    @property
    def n_written(self) -> int:
        return self._m_written.value

    @property
    def n_retried(self) -> int:
        """Blocks that succeeded only after at least one retry."""
        return self._m_retried.value

    @property
    def tap_errors(self) -> int:
        return self._m_tap_errors.value

    def stats(self) -> dict:
        """Counter snapshot (merged into ``DBTable.stats()``).  The
        queue-state pair is taken in one locked pass so ``pending`` /
        ``queue_depth`` can't tear against a concurrent spill."""
        with self._err_lock:
            n_err = len(self._errors)
        with self._lock:
            pending = (sum(w.buf_rows for w in self._writers)
                       + sum(w.q.unfinished_tasks for w in self._writers))
            depth = sum(w.q.qsize() for w in self._writers)
        return {"pending": pending,
                "queue_depth": depth,
                "n_written": self.n_written,
                "n_retried": self.n_retried,
                "n_errors": n_err,
                "n_writers": len(self._writers),
                "n_taps": len(self._taps),
                "tap_errors": self.tap_errors}

    def __repr__(self) -> str:
        return (f"WriterPool({len(self._writers)} writer(s), "
                f"pending={self.pending}, written={self.n_written})")
