"""repro.db — the Accumulo-analog edge store and its D4M binding.

Query through :func:`DB` / :class:`DBTable` (tables as associative
arrays); :class:`EdgeStore` / :class:`MultiInstanceDB` remain the
storage engines underneath.
"""
from .binding import (DB, DEFAULT_SCAN_TTL, AccidentalDenseError, DBTable,
                      ScanCache, bind, put)
from .edgestore import EdgeStore, MultiInstanceDB, Tablet
from .writer import AsyncWriterError, WriterPool

__all__ = ["DB", "DBTable", "put", "bind", "AccidentalDenseError",
           "EdgeStore", "MultiInstanceDB", "Tablet",
           "WriterPool", "AsyncWriterError", "ScanCache",
           "DEFAULT_SCAN_TTL"]
