from .edgestore import EdgeStore, MultiInstanceDB, Tablet

__all__ = ["EdgeStore", "MultiInstanceDB", "Tablet"]
