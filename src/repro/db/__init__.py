"""repro.db — the Accumulo-analog edge store and its D4M binding.

Query through :func:`DB` / :class:`DBTable` (tables as associative
arrays); storage engines live behind the backend registry:
``backend="memory"`` (:class:`EdgeStore` / :class:`MultiInstanceDB`),
``backend="lsm"`` (:class:`LSMStore` / :class:`LSMMultiInstanceDB`,
the durable WAL + sorted-runs store), or ``backend="net"``
(:class:`NetMultiInstanceDB` — networked shard servers, each owning an
LSM or memory store behind a framed TCP protocol).
"""
from .binding import (DB, DEFAULT_FULL_SCAN_WPS_LIMIT, DEFAULT_SCAN_TTL,
                      AccidentalDenseError, DBTable, ScanCache, TableStats,
                      bind, put)
from .edgestore import EdgeStore, MultiInstanceDB, Tablet
from .lsmstore import LSMMultiInstanceDB, LSMStore, SSTable
from .netstore import (NetMultiInstanceDB, ShardClient, ShardError,
                       ShardServer)
from .registry import BACKENDS, make_backend, register_backend
from .writer import AsyncWriterError, WriterPool

__all__ = ["DB", "DBTable", "put", "bind", "AccidentalDenseError",
           "EdgeStore", "MultiInstanceDB", "Tablet",
           "LSMStore", "LSMMultiInstanceDB", "SSTable",
           "NetMultiInstanceDB", "ShardServer", "ShardClient", "ShardError",
           "BACKENDS", "register_backend", "make_backend",
           "WriterPool", "AsyncWriterError", "ScanCache", "TableStats",
           "DEFAULT_SCAN_TTL", "DEFAULT_FULL_SCAN_WPS_LIMIT"]
