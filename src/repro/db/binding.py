"""The D4M database binding: tables *are* associative arrays.

The paper's whole productivity claim (§IV-G, the 135-line pipeline) rests
on one API idea::

    T = DB('Tedge', 'TedgeT', 'TedgeDeg')   # bind the table triple
    put(T, putval(E, '1,'))                  # ingest an incidence matrix
    A = T[:, 'ip.dst|1.1.1.1,']              # Fig. 2 query — an Assoc

A :class:`DBTable` speaks the full :class:`~repro.core.assoc.Assoc`
selection grammar — key lists ``'a,b,'``, ranges ``'a,:,b,'``, prefixes
``'ip.src|*,'`` / :class:`StartsWith`, ``:`` — and routes each subscript
to the physically right table:

* row subscripts scan **Tedge** (Accumulo scans rows efficiently);
* column subscripts scan the transpose table **TedgeT**;
* column queries first consult **TedgeDeg**, the combiner-maintained
  degree table, when a ``degree_limit`` is set — the paper's guard
  against *accidental densification* (subscripting a super-node column
  would otherwise materialize a near-dense result).

Subscripts return :class:`~repro.core.expr.LazyAssoc` nodes, so chains of
algebra over table queries build one operator DAG: the planner pushes the
selection down into the tablet scan and fuses the elementwise stages
(see ``repro.core.expr``).  ``put`` replaces direct tablet mutation with
batched writers that keep every :class:`MultiInstanceDB` instance's write
path busy — the paper's parallel-instance ingest topology — and with
``sync=False`` enqueues to the backend's async
:class:`~repro.db.writer.WriterPool` (writes visible at the next
``flush()``, which every binding read issues automatically).  Hot scans
are served from a per-backend :class:`ScanCache` (TTL + write-path
invalidation); see docs/api.md "Performance".
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from collections import deque
from typing import Iterable, Optional, Sequence, Union

import numpy as np

from ..core import keys as K
from ..core.assoc import Assoc
from ..core.expr import LazyAssoc, _is_all, _sel_key
from ..obs.metrics import REGISTRY as _REGISTRY, obj_label as _obj_label
from ..obs.trace import span as _span
from .edgestore import EdgeStore, MultiInstanceDB
from .lsmstore import LSMMultiInstanceDB, LSMStore
from .registry import make_backend
from .writer import AsyncWriterError, WriterPool

Backend = Union[EdgeStore, MultiInstanceDB, LSMStore, LSMMultiInstanceDB]

_KNOWN_TABLES = ("Tedge", "TedgeT", "TedgeDeg")

# Default TTL (seconds) for the binding-layer scan cache; 0 disables.
DEFAULT_SCAN_TTL = 60.0

# Default writes/sec above which full-table ('any'-band) scan results are
# not admitted to the cache — they are evicted by any write and churn.
DEFAULT_FULL_SCAN_WPS_LIMIT = 50.0

# Scan-cache metric families: one labeled child per live ScanCache (the
# cache keeps the only strong ref; see repro.obs.metrics).  The cache's
# public hits/misses/… attributes are properties over these children, so
# /metrics and T.stats() report the same underlying counts.
_M_CACHE_HITS = _REGISTRY.counter(
    "repro_cache_hits_total", "ScanCache lookups served from memory",
    labels=("cache",))
_M_CACHE_MISSES = _REGISTRY.counter(
    "repro_cache_misses_total", "ScanCache lookups that hit the tablets",
    labels=("cache",))
_M_CACHE_EVICTIONS = _REGISTRY.counter(
    "repro_cache_evictions_total",
    "ScanCache entries evicted (TTL, capacity, write invalidation)",
    labels=("cache",))
_M_CACHE_ADMISSION_SKIPS = _REGISTRY.counter(
    "repro_cache_admission_skips_total",
    "Full-table scan results refused admission under write load",
    labels=("cache",))
_M_CACHE_BATCH_HITS = _REGISTRY.counter(
    "repro_cache_batch_hits_total",
    "Batched-eval members served from the ScanCache", labels=("cache",))
_M_CACHE_BATCH_MISSES = _REGISTRY.counter(
    "repro_cache_batch_misses_total",
    "Batched-eval members that joined a union tablet scan",
    labels=("cache",))


class AccidentalDenseError(RuntimeError):
    """A column query would materialize a super-node block.

    Raised when a subscript's column keys have combined TedgeDeg degree
    above the table's ``degree_limit``.  Re-issue with a tighter selector,
    or bind with a higher/absent limit (``T.with_degree_limit(None)``).
    """

    def __init__(self, offenders: list[tuple[str, float]], limit: float):
        self.offenders = offenders
        self.limit = limit
        worst = ", ".join(f"{k} (deg={v:g})" for k, v in offenders[:5])
        super().__init__(
            f"column query exceeds degree_limit={limit:g}: {worst}"
            + (" …" if len(offenders) > 5 else ""))


# ---------------------------------------------------------------------------
# Selector classification — one grammar, three physical routes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Atoms:
    """A selector normalized to scan units: exact keys, prefixes, or a
    single inclusive range; ``kind == 'all'`` means the full axis."""
    kind: str                       # 'all' | 'atoms' | 'range'
    keys: tuple = ()
    prefixes: tuple = ()
    range: Optional[tuple] = None   # (start, stop)


def _classify(sel) -> _Atoms:
    if _is_all(sel):
        return _Atoms("all")
    if isinstance(sel, np.ndarray) and sel.dtype.kind in "biu":
        raise TypeError(
            "boolean/integer positional selectors are meaningless against "
            "a database table — subscript with keys, ranges, or prefixes")
    if isinstance(sel, K.StartsWith):
        return _Atoms("atoms", prefixes=(sel.prefix,))
    if isinstance(sel, K.KeyRange):
        return _Atoms("range", range=(sel.start, sel.stop))
    if isinstance(sel, str):
        parts = K.parse_keys(sel)
        if parts.shape[0] == 3 and parts[1] == ":":
            return _Atoms("range", range=(str(parts[0]), str(parts[2])))
    else:
        parts = K.parse_keys(sel)
    keys, prefixes = [], []
    for p in parts:
        p = str(p)
        (prefixes if p.endswith("*") else keys).append(
            p[:-1] if p.endswith("*") else p)
    return _Atoms("atoms", keys=tuple(keys), prefixes=tuple(prefixes))


# ---------------------------------------------------------------------------
# TTL scan cache — hot column bands served without re-hitting tablets.
# ---------------------------------------------------------------------------

class ScanCache:
    """Binding-layer cache of table scans, keyed by the planner's
    structural scan key (the same identity ``repro.core.expr._skey`` uses
    for CSE), so a repeated hot band — ``T[:, 'ip.dst|*,']`` issued by
    every analyst — is served from memory across *separate* expression
    DAGs, not just within one.

    Coherence comes from two mechanisms:

    * **write-path invalidation** — every ``put`` through the binding (or
      directly through an attached store) calls :meth:`note_write`; any
      cached entry whose scanned band intersects the written keys is
      evicted *before* the mutation lands;
    * **TTL** — entries expire ``ttl`` seconds after insertion, bounding
      staleness against writers that bypass the store entirely.

    One cache is shared per backend (all :class:`DBTable` views of a
    store see the same entries); cached ``Assoc`` results are shared by
    reference and must be treated as immutable — the same contract the
    lazy executor's memoization already imposes.
    """

    def __init__(self, ttl: float = DEFAULT_SCAN_TTL, maxsize: int = 128,
                 clock=time.monotonic,
                 full_scan_wps_limit: float = DEFAULT_FULL_SCAN_WPS_LIMIT,
                 wps_window: float = 10.0):
        self.ttl = ttl
        self.maxsize = maxsize
        self.clock = clock
        # admission policy for 'any'-band (full-table) entries: they are
        # evicted by *any* write, so on a write-heavy backend caching
        # them is pure churn.  When the observed write rate exceeds
        # ``full_scan_wps_limit`` writes/s (over ``wps_window`` seconds),
        # full-table scans are not admitted.
        self.full_scan_wps_limit = full_scan_wps_limit
        self.wps_window = wps_window
        self._write_times: deque = deque(maxlen=1024)
        # skey → (assoc, expiry, axis, atoms); insertion-ordered for
        # oldest-first eviction when full.
        self._entries: dict = {}
        self._lock = threading.RLock()
        # bumped on every write; admission is gated on it so a scan that
        # raced a concurrent write cannot re-populate the cache with a
        # pre-write result (the write's note_write ran before the scan
        # finished, when the entry wasn't there to evict)
        self.version = 0
        # counters live in the process registry (one labeled child per
        # cache); hits/misses/… below read them back, so /metrics and
        # stats() can never disagree.  batch_* are the batch-path probes
        # (a subset of hits/misses): how often a batched eval was served
        # by / had to populate per-member entries.
        self.metrics_label = _obj_label("cache")
        lab = dict(cache=self.metrics_label)
        self._m_hits = _M_CACHE_HITS.labels(**lab)
        self._m_misses = _M_CACHE_MISSES.labels(**lab)
        self._m_evictions = _M_CACHE_EVICTIONS.labels(**lab)
        self._m_admission_skips = _M_CACHE_ADMISSION_SKIPS.labels(**lab)
        self._m_batch_hits = _M_CACHE_BATCH_HITS.labels(**lab)
        self._m_batch_misses = _M_CACHE_BATCH_MISSES.labels(**lab)

    # registry-backed counter reads (compat: pre-obs attribute shapes)
    @property
    def hits(self):
        return self._m_hits.value

    @property
    def misses(self):
        return self._m_misses.value

    @property
    def evictions(self):
        return self._m_evictions.value

    @property
    def admission_skips(self):
        return self._m_admission_skips.value

    @property
    def batch_hits(self):
        return self._m_batch_hits.value

    @property
    def batch_misses(self):
        return self._m_batch_misses.value

    def get(self, key) -> Optional[Assoc]:
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self._m_misses.inc()
                return None
            assoc, expiry, _, _ = hit
            if self.clock() > expiry:
                del self._entries[key]
                self._m_evictions.inc()
                self._m_misses.inc()
                return None
            self._m_hits.inc()
            return assoc

    def put(self, key, assoc: Assoc, axis: str, atoms: _Atoms,
            ttl: Optional[float] = None,
            if_version: Optional[int] = None) -> None:
        """Admit a scan result.  ``ttl`` overrides the cache default (the
        inserting view's knob); ``if_version`` skips admission when any
        write landed since the caller captured :attr:`version` (i.e. the
        scan may predate that write)."""
        ttl = self.ttl if ttl is None else ttl
        if ttl <= 0:
            return
        with self._lock:
            if if_version is not None and self.version != if_version:
                return
            if axis == "any" and \
                    self._writes_per_s_locked() > self.full_scan_wps_limit:
                self._m_admission_skips.inc()
                return
            while len(self._entries) >= self.maxsize:
                self._entries.pop(next(iter(self._entries)))
                self._m_evictions.inc()
            self._entries[key] = (assoc, self.clock() + ttl, axis, atoms)

    def note_write(self, rows: np.ndarray, cols: np.ndarray) -> None:
        """Evict every cached band the written keys touch (called on the
        write path *before* the mutation is applied/enqueued).  Always
        bumps :attr:`version`, even with nothing cached — in-flight
        scans gate their admission on it."""
        rows = np.asarray(rows, dtype=str)
        cols = np.asarray(cols, dtype=str)
        with self._lock:
            self.version += 1
            self._write_times.append(self.clock())
            if not self._entries:
                return
            doomed = [k for k, (_, _, axis, atoms) in self._entries.items()
                      if self._touches(axis, atoms, rows, cols)]
            for k in doomed:
                del self._entries[k]
            if doomed:
                self._m_evictions.inc(len(doomed))

    @staticmethod
    def _touches(axis: str, atoms: _Atoms, rows: np.ndarray,
                 cols: np.ndarray) -> bool:
        if axis == "any" or atoms.kind == "all":
            return True
        written = rows if axis == "row" else cols
        if written.shape[0] == 0:
            return False
        if atoms.kind == "range":
            lo, hi = atoms.range
            return bool(((written >= lo) & (written <= hi)).any())
        if atoms.keys and bool(
                np.isin(written, np.asarray(atoms.keys, dtype=str)).any()):
            return True
        return any(bool(np.char.startswith(written, p).any())
                   for p in atoms.prefixes)

    def _writes_per_s_locked(self) -> float:
        """Write rate over the trailing ``wps_window`` seconds.  When
        the sample deque is saturated (its maxlen evicted timestamps
        still inside the window), rate over the *retained* span — the
        bounded buffer must not cap the estimate at maxlen/window."""
        now = self.clock()
        cutoff = now - self.wps_window
        while self._write_times and self._write_times[0] < cutoff:
            self._write_times.popleft()
        n = len(self._write_times)
        if n and n == self._write_times.maxlen:
            return n / max(now - self._write_times[0], 1e-9)
        return n / self.wps_window

    @property
    def writes_per_s(self) -> float:
        with self._lock:
            return self._writes_per_s_locked()

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return (f"ScanCache(ttl={self.ttl:g}s, entries={len(self)}, "
                f"hits={self.hits}, misses={self.misses})")


class TableStats(dict):
    """Route counters (a plain mapping: ``T.stats["col"]``) that is also
    *callable*: ``T.stats()`` returns one merged observability snapshot —
    route counts plus :class:`ScanCache` hit/miss/admission counters, the
    :class:`~repro.db.writer.WriterPool` queue state, and backend sync/RPC
    counts — so serving layers (the gateway's ``/stats`` endpoint, bench
    assertions) read a single structure instead of poking three objects.

    The snapshot is read-mostly: it takes no barriers, issues no scans,
    and touches only in-process counters (no per-shard RPCs on the net
    backend), so it is safe to poll at stream frequency.
    """

    def __init__(self, table: "DBTable"):
        super().__init__(row=0, col=0, full=0, deg=0,
                         cache_hit=0, cache_miss=0)
        # weakref, not a strong back-pointer: stats lives on the table,
        # so a strong ref here is a table<->stats cycle that keeps every
        # closed backend (and its cells) parked until a full gc pass —
        # a real leak for anything that binds stores in a loop.
        self._table_ref = weakref.ref(table)

    def __call__(self) -> dict:
        t = self._table_ref()
        if t is None:       # table collected mid-call; nothing to report
            return {"routes": {k: v for k, v in self.items()}}
        out = {"routes": {k: v for k, v in self.items()}}
        cache = t._cache or getattr(t.backend, "_scan_cache", None)
        if cache is not None:
            out["cache"] = {"hits": cache.hits, "misses": cache.misses,
                            "batch_hits": cache.batch_hits,
                            "batch_misses": cache.batch_misses,
                            "evictions": cache.evictions,
                            "admission_skips": cache.admission_skips,
                            "entries": len(cache),
                            "writes_per_s": cache.writes_per_s,
                            "full_scan_wps_limit": cache.full_scan_wps_limit}
        else:
            out["cache"] = {"hits": 0, "misses": 0,
                            "batch_hits": 0, "batch_misses": 0,
                            "evictions": 0,
                            "admission_skips": 0, "entries": 0,
                            "writes_per_s": 0.0,
                            "full_scan_wps_limit": float("inf")}
        pool = getattr(t.backend, "_writer_pool", None)
        out["writers"] = pool.stats() if pool is not None else {
            "pending": 0, "queue_depth": 0, "n_written": 0,
            "n_retried": 0, "n_errors": 0, "n_writers": 0,
            "n_taps": 0, "tap_errors": 0}
        insts = getattr(t.backend, "instances", [t.backend])
        out["backend"] = {
            "kind": type(t.backend).__name__,
            "n_instances": len(insts),
            "n_syncs": sum(getattr(i, "n_syncs", 0) for i in insts),
            "n_rpcs": sum(getattr(i, "n_rpcs", 0) for i in insts)}
        return out


# Serializes lazy attachment of shared per-backend state (scan cache,
# writer pool): concurrent pipeline tasks binding the same store must
# never each create one — the loser's buffered writes would be orphaned.
_ATTACH_LOCK = threading.Lock()


def _cache_for(backend, ttl: Optional[float]) -> Optional[ScanCache]:
    """One shared ScanCache per backend; on a MultiInstanceDB the same
    cache is attached to every instance so direct instance writes also
    invalidate.  ``ttl <= 0`` opts this view out (the backend cache, if
    any, still sees invalidations via the store-side hook).  The cache's
    default TTL comes from the first view; each view's own ``cache_ttl``
    still governs the entries *it* inserts (per-entry TTL)."""
    if ttl is None:
        ttl = DEFAULT_SCAN_TTL
    if ttl <= 0:
        return None
    cache = getattr(backend, "_scan_cache", None)
    if cache is None:
        with _ATTACH_LOCK:
            cache = getattr(backend, "_scan_cache", None)
            if cache is None:
                cache = ScanCache(ttl=ttl)
                if isinstance(backend, MultiInstanceDB):
                    for inst in backend.instances:
                        inst._scan_cache = cache
                backend._scan_cache = cache
    return cache


# ---------------------------------------------------------------------------
# DBTable
# ---------------------------------------------------------------------------

class DBTable:
    """An Assoc-compatible view of the edge database.

    Subscripts build deferred expressions (:class:`LazyAssoc`); call
    ``.eval()`` — or any data accessor like ``.triples()`` — to execute.
    ``stats`` counts which physical route served each scan
    (``row``/``col``/``full``/``deg``), which the routing tests assert
    on; *calling* it (``T.stats()``) returns the merged observability
    snapshot (routes + cache + writers + backend) — see
    :class:`TableStats`.
    """

    def __init__(self, backend: Backend, tables: Sequence[str],
                 name: str = "Tedge",
                 degree_limit: Optional[float] = None,
                 cache_ttl: Optional[float] = None):
        unknown = set(tables) - set(_KNOWN_TABLES)
        if unknown:
            raise ValueError(f"unknown table(s) {sorted(unknown)}; "
                             f"expected a subset of {_KNOWN_TABLES}")
        self.backend = backend
        self.tables = tuple(tables)
        self.name = name
        self.degree_limit = degree_limit
        self.cache_ttl = DEFAULT_SCAN_TTL if cache_ttl is None else cache_ttl
        self._cache = _cache_for(backend, self.cache_ttl)
        self.stats = TableStats(self)

    # -- construction-time variants ---------------------------------------
    def with_degree_limit(self, limit: Optional[float]) -> "DBTable":
        t = DBTable(self.backend, self.tables, self.name, limit,
                    cache_ttl=self.cache_ttl)
        t.stats = self.stats        # share counters with the parent view
        return t

    @property
    def _has_transpose(self) -> bool:
        return "TedgeT" in self.tables

    @property
    def _is_degree(self) -> bool:
        return self.tables == ("TedgeDeg",)

    # -- the Assoc surface -------------------------------------------------
    def __getitem__(self, idx) -> LazyAssoc:
        rsel, csel = idx if isinstance(idx, tuple) else (idx, None)
        return LazyAssoc.scan(self, rsel, csel)

    def lazy(self) -> LazyAssoc:
        return LazyAssoc.scan(self, None, None)

    def eval(self) -> Assoc:
        return self.lazy().eval()

    @property
    def T(self) -> LazyAssoc:
        return self.lazy().T

    def logical(self) -> LazyAssoc:
        return self.lazy().logical()

    def sum(self, axis: int) -> LazyAssoc:
        return self.lazy().sum(axis)

    # -- degree table ------------------------------------------------------
    def degree(self, col_key: str) -> float:
        """Point TedgeDeg lookup (the combiner-maintained degree)."""
        self._read_barrier()
        self.stats["deg"] += 1
        return self.backend.degree(col_key)

    def degree_assoc(self, prefix: str = "") -> Assoc:
        """TedgeDeg as an Assoc (keys × 'degree'), optionally restricted
        to a column-key prefix — the power-law analytics input."""
        self._read_barrier()
        self.stats["deg"] += 1
        items = list(self.backend.degree_items(prefix))
        if not items:
            return Assoc()
        keys = np.asarray([k for k, _ in items], dtype=str)
        vals = np.asarray([v for _, v in items], dtype=np.float64)
        return Assoc(keys, "degree,", vals)

    # -- ingest ------------------------------------------------------------
    def put(self, A: Union[Assoc, LazyAssoc], file_id: str = "",
            batch_size: int = 100_000, sync: bool = True) -> int:
        """Batched triple ingest: Tedge + TedgeT + TedgeDeg in one pass.

        Batches model Accumulo's BatchWriter flushes.  On a
        :class:`MultiInstanceDB` each batch is row-hash partitioned across
        instances (independent write paths); passing ``file_id`` instead
        pins the whole put to one instance — the paper's file→instance
        routing used by the pipeline's stage 6.

        With ``sync=False`` batches are *enqueued* to the backend's
        :class:`~repro.db.writer.WriterPool` (created on first use) and
        ``put`` returns immediately; writes become visible no later than
        the next :meth:`flush` — which every scan through the binding
        issues automatically.  Once a pool exists, synchronous puts also
        route through it (then flush) so ordering stays single-streamed
        per instance.
        """
        if isinstance(A, LazyAssoc):
            A = A.eval()
        r, c, v = A.triples()
        v = np.asarray(v).astype(str)
        pool = getattr(self.backend, "_writer_pool", None)
        if not sync and pool is None:
            pool = self.writer()
        cache = self._cache or getattr(self.backend, "_scan_cache", None)
        dest = self.backend
        if file_id and isinstance(dest, MultiInstanceDB):
            dest = dest.route(file_id)
        n = 0
        for lo in range(0, r.shape[0], batch_size):
            hi = lo + batch_size
            rb, cb, vb = r[lo:hi], c[lo:hi], v[lo:hi]
            if pool is not None:
                if cache is not None:   # evict at enqueue, before apply
                    cache.note_write(rb, cb)
                n += pool.submit(rb, cb, vb, pin=file_id or None)
            else:                       # store-side hook invalidates
                n += dest.put_triples(rb, cb, vb)
        if sync and pool is not None:
            pool.flush()
        return n

    # -- async writer control ----------------------------------------------
    def writer(self, **kw) -> WriterPool:
        """The backend's shared :class:`WriterPool`, created on demand
        (``kw`` — e.g. ``maxsize``, ``fault_injector`` — applies only at
        creation).  Creation is serialized: concurrent ingest tasks must
        share one pool, or the loser's buffered writes would vanish."""
        pool = getattr(self.backend, "_writer_pool", None)
        if pool is None:
            with _ATTACH_LOCK:
                pool = getattr(self.backend, "_writer_pool", None)
                if pool is None:
                    pool = WriterPool(self.backend, **kw)
                    self.backend._writer_pool = pool
        return pool

    def add_ingest_tap(self, fn) -> None:
        """Register ``fn(rows, cols, vals)`` to observe every triple
        block as the backend's writers drain it — the streaming-rollup
        hook (:class:`repro.stream.TemporalRollup.ingest` attaches
        here).  Ensures the shared :class:`WriterPool` exists first, so
        *synchronous* puts also route through the pool (and hence the
        tap) from this point on; only direct ``backend.put_triples``
        calls bypass it.  No extra scan is ever issued: the tap sees
        the very arrays the writer just applied."""
        self.writer().add_tap(fn)

    def remove_ingest_tap(self, fn) -> None:
        pool = getattr(self.backend, "_writer_pool", None)
        if pool is not None:
            pool.remove_tap(fn)

    def flush(self) -> None:
        """Barrier: block until queued async writes are applied,
        re-raising any writer error — and, on durable backends, fsync
        the WAL (the commit point; see docs/api.md "Backends").  On a
        synced, empty pool this is cheap (the store's dirty flag gates
        the fsync)."""
        pool = getattr(self.backend, "_writer_pool", None)
        if pool is not None:
            pool.flush()            # drains, then syncs the backend
        else:
            sync = getattr(self.backend, "sync", None)
            if sync is not None:
                sync()              # sync puts still commit at the barrier

    def _read_barrier(self) -> None:
        """Visibility barrier on the read path: waits only for writes
        enqueued *before* this read (the pool's spill-sequence snapshot)
        and skips the durability fsync — so many concurrent reader
        threads stay live during sustained ingest instead of serializing
        behind a write barrier that never empties.  Sync (poolless) puts
        are applied inline and need no wait at all."""
        pool = getattr(self.backend, "_writer_pool", None)
        if pool is not None:
            with _span("writer.drain"):
                pool.drain()

    # -- serving-layer admission hook --------------------------------------
    @property
    def write_rate(self) -> float:
        """Trailing writes/s seen by this backend's scan cache (0.0 when
        caching is disabled) — the admission signal serving layers use."""
        cache = self._cache or getattr(self.backend, "_scan_cache", None)
        return 0.0 if cache is None else cache.writes_per_s

    def admit_full_scan(self) -> bool:
        """Read-mostly admission check for full-table work: False while
        the trailing write rate exceeds the cache's
        ``full_scan_wps_limit`` (the same signal that stops 'any'-band
        cache admission) — a full scan issued now would be stale before
        it finished and its cache entry evicted by the next write.  The
        gateway maps a refusal to HTTP 429 + Retry-After."""
        cache = self._cache or getattr(self.backend, "_scan_cache", None)
        if cache is None:
            return True
        return cache.writes_per_s <= cache.full_scan_wps_limit

    def close(self) -> None:
        """Flush and stop the backend's writer pool (if any); on a
        durable backend with no pool, still fsync — close is a commit
        point either way."""
        pool = getattr(self.backend, "_writer_pool", None)
        if pool is not None:
            try:
                pool.close()            # drains, then syncs the backend
            finally:
                self.backend._writer_pool = None
        else:
            sync = getattr(self.backend, "sync", None)
            if sync is not None:
                sync()

    # -- scan execution (called by the LazyAssoc executor) -----------------
    def _scan(self, rsel, csel) -> Assoc:
        with _span("db.scan", table="+".join(self.tables)) as sp:
            self._read_barrier()        # async writes become visible here
            ratoms = catoms = None
            if not self._is_degree:
                ratoms, catoms = _classify(rsel), _classify(csel)
                if ratoms.kind == "all" and catoms.kind != "all":
                    # the degree guard fires before the cache so a guarded
                    # view refuses super-node bands even when they are hot
                    self._degree_guard(catoms)
            cache = self._cache
            if cache is None:
                return self._scan_route(rsel, csel, ratoms, catoms)
            key = (self.tables, _sel_key(rsel), _sel_key(csel))
            hit = cache.get(key)
            if hit is not None:
                self.stats["cache_hit"] += 1
                sp.tag(cache="hit")
                return hit
            sp.tag(cache="miss")
            v0 = cache.version      # writes after this gate admission
            out = self._scan_route(rsel, csel, ratoms, catoms)
            self.stats["cache_miss"] += 1
            axis, atoms = self._band(rsel, ratoms, catoms)
            cache.put(key, out, axis, atoms, ttl=self.cache_ttl,
                      if_version=v0)
            return out

    def _scan_batch(self, sels) -> list:
        """Serve a batch of subscripts with one union tablet scan per
        physical route (the ``repro.core.expr.eval_batch`` prefetch
        hook): members are grouped row/col/deg, their atoms unioned,
        scanned once, and split per member host-side — each member's
        result is byte-identical to its individual :meth:`_scan` and
        lands its own :class:`ScanCache` entry.

        Route counters tick once per *union* scan (that is what hit the
        tablets); cache hit/miss counters still tick per member, plus
        the batch-path ``batch_hits``/``batch_misses``.

        Returns a list aligned with ``sels``; ``None`` marks members
        this table declines to prefetch (ranges, full scans, positional
        selectors, degree-guard refusals) — they fall back to individual
        :meth:`_scan`, where any error surfaces on the member that
        caused it.
        """
        with _span("db.scan_batch", table="+".join(self.tables),
                   n=len(sels)):
            return self._scan_batch_impl(sels)

    def _scan_batch_impl(self, sels) -> list:
        self._read_barrier()        # one visibility barrier for the batch
        out: list = [None] * len(sels)
        cache = self._cache
        groups: dict = {"row": [], "col": [], "deg": []}
        for i, (rsel, csel) in enumerate(sels):
            try:
                if self._is_degree:
                    atoms = _classify(rsel)
                    if atoms.kind == "atoms":
                        groups["deg"].append((i, atoms, rsel, csel))
                    continue
                ratoms, catoms = _classify(rsel), _classify(csel)
            except TypeError:
                continue            # positional — raises in its own _scan
            if ratoms.kind == "all" and catoms.kind == "atoms":
                try:
                    self._degree_guard(catoms)
                except AccidentalDenseError:
                    continue        # member re-raises on its own scan
                groups["col"].append((i, catoms, rsel, csel))
            elif ratoms.kind == "atoms":
                groups["row"].append((i, ratoms, rsel, csel))
        for axis, members in groups.items():
            if not members:
                continue
            misses = []
            for m in members:
                i, atoms, rsel, csel = m
                if cache is not None:
                    hit = cache.get(
                        (self.tables, _sel_key(rsel), _sel_key(csel)))
                    if hit is not None:
                        self.stats["cache_hit"] += 1
                        cache._m_batch_hits.inc()
                        out[i] = hit
                        continue
                    cache._m_batch_misses.inc()
                misses.append(m)
            if not misses:
                continue
            v0 = cache.version if cache is not None else None
            uatoms = _Atoms(
                "atoms",
                keys=tuple(sorted({k for _, a, _, _ in misses
                                   for k in a.keys})),
                prefixes=tuple(sorted({p for _, a, _, _ in misses
                                       for p in a.prefixes})))
            U = self._scan_union(axis, uatoms)
            for i, atoms, rsel, csel in misses:
                A = self._split_member(U, axis, rsel, csel)
                out[i] = A
                self.stats["cache_miss"] += 1
                if cache is not None:
                    cache.put(
                        (self.tables, _sel_key(rsel), _sel_key(csel)),
                        A, "col" if axis == "deg" else axis, atoms,
                        ttl=self.cache_ttl, if_version=v0)
        return out

    def _scan_union(self, axis: str, uatoms: _Atoms) -> Assoc:
        """One tablet scan covering every batch member on a route."""
        if axis == "deg":
            self.stats["deg"] += 1
            items = [(k, self.backend.degree(k)) for k in uatoms.keys]
            for p in uatoms.prefixes:
                items.extend(self.backend.degree_items(p))
            # a key may match both an exact atom and a prefix atom —
            # dedupe so the split sees each degree once
            dd = {k: v for k, v in items if v}
            if not dd:
                return Assoc()
            return Assoc(np.asarray(list(dd.keys()), dtype=str), "degree,",
                         np.asarray(list(dd.values()), dtype=np.float64))
        if axis == "col":
            self.stats["col"] += 1
            return self._assemble(self._iter_cells(uatoms, transpose=True),
                                  transposed=True)
        self.stats["row"] += 1
        return self._assemble(self._iter_cells(uatoms, transpose=False))

    @staticmethod
    def _split_member(U: Assoc, axis: str, rsel, csel) -> Assoc:
        """A member's slice of the union scan — equal to its own scan
        (the union only adds rows/cols the member's selector rejects)."""
        if U.nnz == 0:
            return Assoc()
        if axis == "col":
            return U[K.All(), csel]
        A = U[rsel, K.All()]
        return A if _is_all(csel) else A[K.All(), csel]

    def _band(self, rsel, ratoms, catoms) -> tuple:
        """(axis, atoms) describing which written keys invalidate this
        scan: degree scans watch column keys (the combiner's inputs),
        row/col scans watch their scanned axis, full scans watch any."""
        if self._is_degree:
            return "col", _classify(rsel)
        if ratoms.kind != "all":
            return "row", ratoms
        if catoms.kind != "all":
            return "col", catoms
        return "any", _Atoms("all")

    def _scan_route(self, rsel, csel, ratoms=None, catoms=None) -> Assoc:
        if self._is_degree:
            return self._scan_degree(rsel, csel)
        if ratoms is None:
            ratoms, catoms = _classify(rsel), _classify(csel)

        if ratoms.kind != "all":
            # row-routed: scan Tedge for the requested rows, refine
            # columns host-side on the (small) result.
            self.stats["row"] += 1
            A = self._assemble(self._iter_cells(ratoms, transpose=False))
            return A if catoms.kind == "all" else A[K.All(), csel]
        if catoms.kind != "all":
            # column-routed: the transpose table turns a column query
            # into a row scan (Accumulo only scans rows efficiently).
            # (degree guard already applied in _scan)
            self.stats["col"] += 1
            A = self._assemble(self._iter_cells(catoms, transpose=True),
                               transposed=True)
            return A
        self.stats["full"] += 1
        return self._assemble(self._iter_cells(_Atoms("all"),
                                               transpose=False))

    def _iter_cells(self, atoms: _Atoms, transpose: bool):
        be = self.backend
        if transpose and not self._has_transpose:
            raise KeyError(
                f"{self.name}: column query needs the transpose table; "
                f"bind with DB('Tedge', 'TedgeT', ...)")
        if atoms.kind == "all":
            yield from be.scan_everything(transpose=transpose)
            return
        if atoms.kind == "range":
            yield from be.scan_key_range(*atoms.range, transpose=transpose)
            return
        if atoms.keys:
            yield from be.scan_keys(list(atoms.keys), transpose=transpose)
        for p in atoms.prefixes:
            yield from be.scan_prefix(p, transpose=transpose)

    @staticmethod
    def _assemble(cells: Iterable[tuple[str, dict]],
                  transposed: bool = False) -> Assoc:
        rows, cols, vals = [], [], []
        for key, cellmap in cells:
            for other, v in cellmap.items():
                rows.append(other if transposed else key)
                cols.append(key if transposed else other)
                vals.append(v)
        if not rows:
            return Assoc()
        return Assoc(np.asarray(rows, dtype=str),
                     np.asarray(cols, dtype=str),
                     np.asarray(vals, dtype=str), agg="min")

    def _scan_degree(self, rsel, csel) -> Assoc:
        atoms = _classify(rsel)
        if atoms.kind == "all":
            A = self.degree_assoc()     # counts the deg route itself
        elif atoms.kind == "range":
            A = self.degree_assoc()[K.KeyRange(*atoms.range), K.All()]
        else:
            self.stats["deg"] += 1
            items = [(k, self.backend.degree(k)) for k in atoms.keys]
            for p in atoms.prefixes:
                items.extend(self.backend.degree_items(p))
            items = [(k, v) for k, v in items if v]
            if not items:
                return Assoc()
            A = Assoc(np.asarray([k for k, _ in items], dtype=str),
                      "degree,",
                      np.asarray([v for _, v in items], dtype=np.float64))
        return A if _is_all(csel) else A[K.All(), csel]

    # -- the anti-"accidental dense" guard ---------------------------------
    def _degree_guard(self, catoms: _Atoms) -> None:
        if self.degree_limit is None or "TedgeDeg" not in self.tables:
            return
        self.stats["deg"] += 1
        probed = [(k, self.backend.degree(k)) for k in catoms.keys]
        for p in catoms.prefixes:
            probed.extend(self.backend.degree_items(p))
        if catoms.kind == "range":
            lo, hi = catoms.range
            probed.extend((k, d) for k, d in self.backend.degree_items()
                          if lo <= k <= hi)
        offenders = [(k, d) for k, d in probed if d > self.degree_limit]
        if offenders:
            offenders.sort(key=lambda kv: -kv[1])
            raise AccidentalDenseError(offenders, self.degree_limit)

    # -- misc --------------------------------------------------------------
    @property
    def n_entries(self) -> int:
        return self.backend.n_entries

    def __repr__(self):
        kind = "+".join(self.tables)
        return (f"DBTable({kind} on {type(self.backend).__name__}, "
                f"degree_limit={self.degree_limit})")


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------

def DB(*tables: str, backend: Union[Backend, str, None] = None,
       n_instances: int = 1, tablets_per_instance: int = 4,
       degree_limit: Optional[float] = None,
       cache_ttl: Optional[float] = None,
       path: Optional[str] = None, **backend_options) -> DBTable:
    """Bind database tables into one associative-array view (paper §III).

    ``DB('Tedge', 'TedgeT')`` enables row *and* column subscripts;
    adding ``'TedgeDeg'`` wires in the degree guard and
    :meth:`DBTable.degree_assoc`; ``DB('TedgeDeg')`` alone views just the
    degree table.

    ``backend`` selects the storage engine: an existing store object, or
    a registered name — ``"memory"`` (the default: a fresh
    :class:`MultiInstanceDB`, or single :class:`EdgeStore` when
    ``n_instances == 1``), ``"lsm"`` (the persistent
    :class:`~repro.db.lsmstore.LSMStore`, which requires ``path=`` and
    shards instances across ``path/db*`` subdirectories when
    ``n_instances > 1``), or ``"net"`` (networked shard servers —
    :class:`~repro.db.netstore.NetMultiInstanceDB`; pass
    ``addresses=["host:port", ...]`` for running servers, or let it
    auto-start ``n_instances`` local shards).  Extra ``backend_options``
    (e.g.
    ``memtable_limit``, ``coordination_cost_s``) pass to the engine
    factory; see ``repro.db.registry``.  ``cache_ttl`` tunes the scan
    cache (default ``DEFAULT_SCAN_TTL``; ``0`` opts this view out of
    cached reads).
    """
    if not tables:
        tables = _KNOWN_TABLES
    if backend is None or isinstance(backend, str):
        backend = make_backend(
            backend if isinstance(backend, str) else "memory",
            n_instances=n_instances,
            tablets_per_instance=tablets_per_instance,
            path=path, **backend_options)
    return DBTable(backend, tables, name=tables[0],
                   degree_limit=degree_limit, cache_ttl=cache_ttl)


def bind(db, degree_limit: Optional[float] = None,
         cache_ttl: Optional[float] = None) -> DBTable:
    """Wrap an existing store (or pass a DBTable through) — the adapter
    legacy call sites use to reach the new query surface."""
    if isinstance(db, DBTable):
        return db
    return DBTable(db, _KNOWN_TABLES, degree_limit=degree_limit,
                   cache_ttl=cache_ttl)


def put(T: DBTable, A: Union[Assoc, LazyAssoc], file_id: str = "",
        batch_size: int = 100_000, sync: bool = True) -> int:
    """Module-level D4M idiom: ``put(T, putval(E, '1,'))``."""
    return T.put(A, file_id=file_id, batch_size=batch_size, sync=sync)
