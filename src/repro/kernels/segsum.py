"""Pallas TPU kernel: segmented sum over sorted ids (D4M degree/SpMV core).

The paper's hot loop — building ``TedgeDeg`` and every semiring
contraction over the incidence matrix — reduces values into segments
given *sorted* segment ids.  GPUs do this with atomics; the TPU-native
formulation is a **one-hot matmul on the MXU**: each block of nnz values
becomes a (1, Bn) × (Bn, S_tile) product accumulated into the output tile
held in VMEM across sequential grid steps.  Irregular scatter becomes
dense systolic work — the hardware-adaptation story of DESIGN.md §2.

Grid: (segment tiles, nnz blocks); the nnz-block dimension is sequential
("arbitrary"), so accumulation into ``out_ref`` is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_NNZ = 1024      # values per grid step (8 sublanes × 128 lanes)
DEFAULT_BLOCK_SEG = 1024      # output segments per tile


def _segsum_kernel(ids_ref, vals_ref, out_ref, *, block_seg: int):
    seg_tile = pl.program_id(0)
    nnz_blk = pl.program_id(1)

    @pl.when(nnz_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                       # (block_nnz,) int32
    vals = vals_ref[...].astype(jnp.float32)  # (block_nnz,)
    base = seg_tile * block_seg
    local = ids - base                        # segment id within tile
    # one-hot (block_nnz, block_seg) — rows outside the tile are all-zero
    cols = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_seg), 1)
    onehot = (cols == local[:, None]).astype(jnp.float32)
    # (1, Bn) @ (Bn, S_tile) on the MXU
    out_ref[...] += jnp.dot(vals[None, :], onehot,
                            preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit, static_argnames=("num_segments", "block_nnz",
                                             "block_seg", "interpret"))
def segsum(ids: jax.Array, vals: jax.Array, num_segments: int,
           block_nnz: int = DEFAULT_BLOCK_NNZ,
           block_seg: int = DEFAULT_BLOCK_SEG,
           interpret: bool = True) -> jax.Array:
    """out[s] = Σ_{i: ids[i]==s} vals[i].  ids sorted (not required for
    correctness — only for TPU memory locality)."""
    nnz = ids.shape[0]
    block_nnz = min(block_nnz, nnz)
    pad = (-nnz) % block_nnz
    if pad:
        ids = jnp.pad(ids, (0, pad), constant_values=-1)  # never matches
        vals = jnp.pad(vals, (0, pad))
        nnz += pad
    seg_pad = (-num_segments) % block_seg
    n_seg = num_segments + seg_pad
    grid = (n_seg // block_seg, nnz // block_nnz)

    out = pl.pallas_call(
        functools.partial(_segsum_kernel, block_seg=block_seg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_nnz,), lambda s, n: (n,)),
            pl.BlockSpec((block_nnz,), lambda s, n: (n,)),
        ],
        out_specs=pl.BlockSpec((block_seg,), lambda s, n: (s,)),
        out_shape=jax.ShapeDtypeStruct((n_seg,), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), vals)
    return out[:num_segments]


def _windowed_kernel(starts_ref, ids_ref, vals_ref, zeros_ref, out_ref, *,
                     block_seg: int):
    """Contribution of nnz block i to output tile starts[i] + j.

    Grid (n_blocks, 2): each sorted nnz block touches (almost always)
    only the 2 output tiles starting at its min id's tile — the
    scalar-prefetch index map places the write window, so total matmul
    work is O(nnz · 2·block_seg), independent of n_seg.  Entries outside
    the window are masked here and corrected by an exact XLA spill pass
    in the wrapper.  ``zeros_ref`` is aliased to the output for
    accumulation across window overlaps.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    del zeros_ref  # aliased with out_ref (initial zeros)
    tile = starts_ref[i] + j
    base = tile * block_seg
    ids = ids_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    local = ids - base
    cols = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_seg), 1)
    onehot = (cols == local[:, None]).astype(jnp.float32)
    out_ref[...] += jnp.dot(vals[None, :], onehot,
                            preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit, static_argnames=("num_segments", "block_nnz",
                                             "block_seg", "interpret"))
def segsum_windowed(ids: jax.Array, vals: jax.Array, num_segments: int,
                    block_nnz: int = DEFAULT_BLOCK_NNZ,
                    block_seg: int = DEFAULT_BLOCK_SEG,
                    interpret: bool = True) -> jax.Array:
    """Sorted-ids segmented sum, windowed (§Perf kernel iteration).

    The baseline kernel's one-hot matmul does O(nnz · n_seg) MXU work
    (every nnz block × every segment tile).  Sorted ids make the target
    tile computable per block — this version does O(nnz · 2·block_seg)
    with a runtime-offset output window, plus an exact spill correction
    (XLA segment_sum over the rare entries whose block spans > 2 tiles).
    """
    from jax.experimental.pallas import tpu as pltpu
    nnz = ids.shape[0]
    block_nnz = min(block_nnz, nnz)
    pad = (-nnz) % block_nnz
    if pad:
        ids = jnp.concatenate([ids, jnp.full((pad,), ids[-1], ids.dtype)])
        vals = jnp.pad(vals, (0, pad))
        nnz += pad
    n_blocks = nnz // block_nnz
    n_tiles = -(-num_segments // block_seg) + 2   # window overflow room
    n_seg_pad = n_tiles * block_seg

    ids_b = ids.reshape(n_blocks, block_nnz)
    starts = (ids_b[:, 0] // block_seg).astype(jnp.int32)
    # spill: entries outside the 2-tile window of their block
    in_window = (ids_b // block_seg - starts[:, None]) < 2
    vals_b = vals.reshape(n_blocks, block_nnz)
    kernel_vals = jnp.where(in_window, vals_b, 0).reshape(-1)
    spill_vals = jnp.where(in_window, 0, vals_b).reshape(-1)

    out = pl.pallas_call(
        functools.partial(_windowed_kernel, block_seg=block_seg),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks, 2),
            in_specs=[
                pl.BlockSpec((block_nnz,), lambda i, j, starts: (i,)),
                pl.BlockSpec((block_nnz,), lambda i, j, starts: (i,)),
                pl.BlockSpec((block_seg,),
                             lambda i, j, starts: (starts[i] + j,)),
            ],
            out_specs=pl.BlockSpec((block_seg,),
                                   lambda i, j, starts: (starts[i] + j,)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_seg_pad,), jnp.float32),
        input_output_aliases={3: 0},     # zeros init (after prefetch arg)
        interpret=interpret,
    )(starts, ids.astype(jnp.int32), kernel_vals,
      jnp.zeros((n_seg_pad,), jnp.float32))
    # exact spill correction (cheap: nearly all zeros for sorted data)
    spill = jax.ops.segment_sum(spill_vals, ids, num_segments=n_seg_pad)
    return (out + spill)[:num_segments]
