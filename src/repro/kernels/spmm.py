"""Pallas TPU kernels: ELL-format semiring SpMM and masked column-select
SpGEMM (the batched hypersparse analytics layer).

``spmv_ell`` (repro.kernels.spmv) answers ONE query per launch; a gateway
with eight concurrent top-K readers pays eight Python dispatches and
re-streams the sparse block from HBM each time.  Following the real-time
GraphBLAS deployment work (arXiv:2309.02464), the batched layer instead
multiplies one sparse Tedge block against a dense *multi-vector* in a
single launch:

* :func:`spmm_ell` — ``Y (n, b) = A ⊕.⊗ X (n_cols, b)``: the ELL block
  streams from HBM **once** and every one-hot gather matmul amortizes
  over all ``b`` query vectors — per-query cost approaches pure HBM
  bandwidth instead of per-launch dispatch;
* :func:`spgemm_sel` — ``Y (n, b) = A ⊕.⊗ onehot(sel)``: a *masked
  SpGEMM* that selects a batch of columns directly from the column-id
  vector ``sel`` — the one-hot mask matrix is never materialized
  host-side (the kernel compares ``cols[r, k] == sel[j]`` in VMEM).

Both support the ``plus_times`` and ``max_times`` semirings with the
same conventions as ``spmv_ell``: the max_times accumulator starts at
-inf (a 0 floor would clamp negative products), padding slots
(``col == -1``) are masked, and rows with no entries resolve to 0 — the
sparse no-entry value.  ``interpret=None`` auto-selects by backend:
compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_ell_kernel(cols_ref, vals_ref, x_ref, out_ref, *,
                     block_cols: int, ring: str):
    ct = pl.program_id(1)

    @pl.when(ct == 0)
    def _init():
        if ring == "plus_times":
            out_ref[...] = jnp.zeros_like(out_ref)
        else:                    # max_times identity is -inf, not 0
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    cols = cols_ref[...]                         # (BR, Kmax) int32
    vals = vals_ref[...].astype(jnp.float32)     # (BR, Kmax)
    x = x_ref[...].astype(jnp.float32)           # (block_cols, B)
    base = ct * block_cols
    local = cols - base
    br, kmax = cols.shape
    acc = out_ref[...]                           # (BR, B)
    iota = jax.lax.broadcasted_iota(jnp.int32, (br, block_cols), 1)
    for k in range(kmax):            # Kmax is small and static — unrolled
        onehot = (iota == local[:, k][:, None]).astype(jnp.float32)
        # the gather matmul is shared by all B columns of X — this is
        # where batching beats the SpMV loop: one (BR, bc) @ (bc, B)
        # instead of B separate (bc, 1) products
        gathered = jnp.dot(onehot, x, preferred_element_type=jnp.float32)
        if ring == "plus_times":
            acc = acc + vals[:, k][:, None] * gathered
        else:                        # max_times
            # padding cols are -1, so local < 0 on every tile — the
            # mask excludes both padding and out-of-tile slots
            hit = (local[:, k] >= 0) & (local[:, k] < block_cols)
            acc = jnp.where(hit[:, None],
                            jnp.maximum(acc, vals[:, k][:, None] * gathered),
                            acc)
    if ring != "plus_times":
        # last col tile: rows with no entries anywhere stay at the
        # -inf identity — resolve them to 0 (sparse no-entry value)
        is_last = ct == pl.num_programs(1) - 1
        acc = jnp.where(is_last & jnp.isneginf(acc), 0.0, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "ring", "interpret"))
def spmm_ell(ecols: jax.Array, evals: jax.Array, x: jax.Array,
             block_rows: int = 256, block_cols: int = 1024,
             ring: str = "plus_times",
             interpret: Optional[bool] = None) -> jax.Array:
    """``Y = A ⊕.⊗ X`` with A in ELL (n_rows, k_max), X dense (n_cols, b).

    One launch answers ``b`` queries: grid over (row blocks, col tiles),
    col-tile dimension sequential so the (block_rows, b) VMEM accumulator
    is race-free.  ``b == 1`` degenerates to :func:`~repro.kernels.spmv.
    spmv_ell` (the SpMV loop's unit).  ``interpret=None`` compiles on TPU
    and interprets elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if x.ndim != 2:
        raise ValueError(f"X must be (n_cols, b), got shape {x.shape}")
    n_rows, _ = ecols.shape
    n_cols, b = x.shape
    rpad = (-n_rows) % block_rows
    cpad = (-n_cols) % block_cols
    if rpad:
        ecols = jnp.pad(ecols, ((0, rpad), (0, 0)), constant_values=-1)
        evals = jnp.pad(evals, ((0, rpad), (0, 0)))
    if cpad:
        x = jnp.pad(x, ((0, cpad), (0, 0)))
    grid = ((n_rows + rpad) // block_rows, (n_cols + cpad) // block_cols)
    out = pl.pallas_call(
        functools.partial(_spmm_ell_kernel, block_cols=block_cols,
                          ring=ring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, ecols.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_rows, evals.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_cols, b), lambda r, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, b), lambda r, c: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows + rpad, b), jnp.float32),
        interpret=interpret,
    )(ecols, evals, x)
    return out[:n_rows]


def _spgemm_sel_kernel(cols_ref, vals_ref, sel_ref, out_ref, *, ring: str):
    cols = cols_ref[...]                         # (BR, Kmax) int32
    vals = vals_ref[...].astype(jnp.float32)     # (BR, Kmax)
    sel = sel_ref[...]                           # (B,) int32
    br, kmax = cols.shape
    if ring == "plus_times":
        acc = jnp.zeros((br, sel.shape[0]), jnp.float32)
    else:
        acc = jnp.full((br, sel.shape[0]), -jnp.inf, jnp.float32)
    for k in range(kmax):
        # the mask IS the one-hot column of the selection matrix —
        # built by comparison in VMEM, never materialized host-side
        hit = (cols[:, k][:, None] == sel[None, :]) & \
              (cols[:, k][:, None] >= 0)         # (BR, B)
        if ring == "plus_times":
            acc = acc + jnp.where(hit, vals[:, k][:, None], 0.0)
        else:
            acc = jnp.where(hit, jnp.maximum(acc, vals[:, k][:, None]),
                            acc)
    if ring != "plus_times":
        acc = jnp.where(jnp.isneginf(acc), 0.0, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows", "ring",
                                             "interpret"))
def spgemm_sel(ecols: jax.Array, evals: jax.Array, sel: jax.Array,
               block_rows: int = 256, ring: str = "plus_times",
               interpret: Optional[bool] = None) -> jax.Array:
    """``Y[r, j] = A[r, sel[j]]`` under the semiring — the masked SpGEMM
    answering a batch of column queries in one launch.

    ``sel`` is the (b,) vector of selected column indices; entries of A
    in unselected columns are skipped by the mask, so the launch cost is
    O(nnz · b) comparisons over one HBM stream of the block, not b
    scans.  Matches :func:`spmm_ell` against the dense one-hot X under
    plus_times exactly; under max_times the mask keeps GraphBLAS sparse
    semantics — only *stored* hits reduce, so a dense zero never clamps
    a negative maximum the way the one-hot product's zeros would.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_rows, _ = ecols.shape
    b = sel.shape[0]
    rpad = (-n_rows) % block_rows
    if rpad:
        ecols = jnp.pad(ecols, ((0, rpad), (0, 0)), constant_values=-1)
        evals = jnp.pad(evals, ((0, rpad), (0, 0)))
    grid = ((n_rows + rpad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_spgemm_sel_kernel, ring=ring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, ecols.shape[1]), lambda r: (r, 0)),
            pl.BlockSpec((block_rows, evals.shape[1]), lambda r: (r, 0)),
            pl.BlockSpec((b,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, b), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows + rpad, b), jnp.float32),
        interpret=interpret,
    )(ecols, evals, sel.astype(jnp.int32))
    return out[:n_rows]
