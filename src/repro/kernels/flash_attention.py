"""Pallas TPU kernel: blocked online-softmax (flash) attention.

VMEM tiling: q (1, Bq, Dh), k/v (1, Ck, Dh) per grid step; running
(m, l, acc) live in VMEM scratch across the sequential KV dimension.
Causal and sliding-window masking via block-offset iotas.  MXU dims
(Bq, Ck, Dh) are multiples of 128 in production configs.

Grid: (batch·heads, q blocks, kv blocks) — kv sequential ("arbitrary").
GQA is handled by the BlockSpec index map (each q head reads its kv
head's block directly — kv is never repeated in memory).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  sm_scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (Bq, Dh)
    k = k_ref[0].astype(jnp.float32)            # (Ck, Dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    d = q_pos - k_pos
    ok = jnp.ones_like(d, dtype=bool)
    if causal:
        ok &= d >= 0
    if window:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh) with H % KV == 0.
    Returns (B, Sq, H, Dh)."""
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert h % kv == 0 and sq % block_q == 0 and sk % block_k == 0
    groups = h // kv

    # layout: (B*H, S, Dh) for q/out; (B*KV, S, Dh) for k/v
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, dh)

    def kv_map(bh, qi, kj):
        batch, head = bh // h, bh % h
        return (batch * kv + head // groups, kj, 0)

    grid = (b * h, sq // block_q, sk // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, window=window,
                          sm_scale=dh ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh), kv_map),
            pl.BlockSpec((1, block_k, dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, dh), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
