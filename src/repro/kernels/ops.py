"""jit'd public wrappers for the Pallas kernels.

On a real TPU pass ``interpret=False`` (the default flips on backend);
this container is CPU-only, so interpret=True executes the kernel bodies
in Python for correctness validation while the pure-JAX fallbacks serve
the compiled dry-run path.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .rglru import rglru_scan
from .segsum import segsum
from .spmm import spgemm_sel, spmm_ell
from .spmv import EllOverflowError, csr_to_ell, spmv_ell
from .wkv6 import wkv6


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


__all__ = [
    "segsum", "spmv_ell", "spmm_ell", "spgemm_sel", "csr_to_ell",
    "EllOverflowError", "flash_attention", "rglru_scan", "wkv6", "on_tpu",
    "default_interpret",
]
