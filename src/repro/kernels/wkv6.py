"""Pallas TPU kernel: RWKV-6 chunked WKV recurrence.

Per (batch, head) the state S ∈ (Dh, Dh) is carried in VMEM scratch
across sequential time chunks; each chunk is three (C×Dh)·(Dh×Dh)-class
matmuls on the MXU plus a strict-lower-triangular (C×C) intra-chunk
product — the same factorization as models.blocks.wkv_chunked, so the
ref oracle is shared.

Grid: (batch·heads, time chunks) — time sequential.
Inputs are pre-scaled by the wrapper (q_eff, k_in, k_out, total) to keep
the kernel free of cumulative-log work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, qe_ref, ki_ref, ko_ref, tot_ref,
                ub_ref, o_ref, s_ref, *, chunk: int):
    tc = pl.program_id(1)

    @pl.when(tc == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rq = r_ref[0].astype(jnp.float32)       # (C, Dh)
    kq = k_ref[0].astype(jnp.float32)
    vq = v_ref[0].astype(jnp.float32)
    qe = qe_ref[0].astype(jnp.float32)
    ki = ki_ref[0].astype(jnp.float32)
    ko = ko_ref[0].astype(jnp.float32)
    tot = tot_ref[0].astype(jnp.float32)    # (1, Dh)
    u = ub_ref[...].astype(jnp.float32)     # (1, Dh)
    state = s_ref[...]                      # (Dh, Dh)

    inter = jnp.dot(qe, state, preferred_element_type=jnp.float32)
    scores = jnp.dot(qe, ki.T, preferred_element_type=jnp.float32)
    c = scores.shape[0]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >
           jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    scores = jnp.where(tri, scores, 0.0)
    intra = jnp.dot(scores, vq, preferred_element_type=jnp.float32)
    diag = jnp.sum(rq * kq * u, axis=-1, keepdims=True) * vq
    o_ref[0] = (inter + intra + diag).astype(o_ref.dtype)
    s_ref[...] = state * tot.T + jnp.dot(
        ko.T, vq, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, chunk: int = 32, interpret: bool = True) -> jax.Array:
    """r,k,v,w: (B, S, H, Dh); u: (H, Dh). Returns (B, S, H, Dh).

    w is the per-step decay in (0, 1); pre-scaling (cumulative decays)
    happens here in plain XLA, the sequential state pass in the kernel.
    """
    b, s, h, dh = r.shape
    c = min(chunk, s)
    assert s % c == 0
    n = s // c

    def reshape(t):  # (B,S,H,Dh) → (B·H, S, Dh)
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))
    cshape = (b * h, n, c, dh)
    logw = jnp.log(jnp.maximum(wc, 1e-38)).reshape(cshape)
    cum = jnp.cumsum(logw, axis=2)
    rcc = rc.reshape(cshape)
    kcc = kc.reshape(cshape)
    q_eff = (rcc * jnp.exp(cum - logw)).reshape(b * h, s, dh)
    k_in = (kcc * jnp.exp(-cum)).reshape(b * h, s, dh)
    k_out = (kcc * jnp.exp(cum[:, :, -1:, :] - cum)).reshape(b * h, s, dh)
    total = jnp.exp(cum[:, :, -1, :])                   # (BH, n, Dh)
    ub = jnp.broadcast_to(u[None], (b, h, dh)).reshape(b * h, dh)

    seq_spec = pl.BlockSpec((1, c, dh), lambda bh, t: (bh, t, 0))
    grid = (b * h, n)
    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=c),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec,               # r, k, v
            seq_spec, seq_spec, seq_spec,               # qe, ki, ko
            pl.BlockSpec((1, 1, dh), lambda bh, t: (bh, t, 0)),  # tot
            pl.BlockSpec((1, dh), lambda bh, t: (bh, 0)),        # u
        ],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(rc, kc, vc, q_eff, k_in, k_out, total, ub)
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
