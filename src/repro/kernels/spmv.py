"""Pallas TPU kernel: ELL-format semiring SpMV (PageRank / background model).

CSR's per-row ragged nnz is hostile to the MXU; the TPU adaptation packs
rows to ELL (fixed ``k_max`` nnz per row, zero-padded — D4M incidence
matrices are near-regular: one nnz per header field).  The gather
``x[cols]`` is realized as a one-hot matmul per nnz-slot, so the whole
kernel is dense systolic work:

    y[r] ⊕= Σ_k vals[r,k] ⊗ (onehot(cols[r,k]) @ x_tile)

Grid: (row blocks, col tiles); col-tile dimension is sequential so the
VMEM accumulator is race-free.  plus_times and max_times semirings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_ell_kernel(cols_ref, vals_ref, x_ref, out_ref, *,
                     block_cols: int, ring: str):
    ct = pl.program_id(1)

    @pl.when(ct == 0)
    def _init():
        if ring == "plus_times":
            out_ref[...] = jnp.zeros_like(out_ref)
        else:
            out_ref[...] = jnp.full_like(out_ref, 0.0)

    cols = cols_ref[...]                         # (BR, Kmax) int32
    vals = vals_ref[...].astype(jnp.float32)     # (BR, Kmax)
    x = x_ref[...].astype(jnp.float32)           # (block_cols,)
    base = ct * block_cols
    local = cols - base
    br, kmax = cols.shape
    acc = out_ref[...]
    iota = jax.lax.broadcasted_iota(jnp.int32, (br, block_cols), 1)
    for k in range(kmax):            # Kmax is small and static — unrolled
        onehot = (iota == local[:, k][:, None]).astype(jnp.float32)
        gathered = jnp.dot(onehot, x[:, None],
                           preferred_element_type=jnp.float32)[:, 0]
        if ring == "plus_times":
            acc = acc + vals[:, k] * gathered
        else:                        # max_times
            hit = (local[:, k] >= 0) & (local[:, k] < block_cols)
            acc = jnp.maximum(acc, jnp.where(hit, vals[:, k] * gathered,
                                             acc))
    out_ref[...] = acc


def csr_to_ell(row_ptr, cols, vals, n_rows: int, k_max: int):
    """Host-side CSR→ELL pack (pad/truncate to k_max nnz per row)."""
    import numpy as np
    row_ptr = np.asarray(row_ptr)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    ecols = np.full((n_rows, k_max), -1, np.int32)
    evals = np.zeros((n_rows, k_max), np.float32)
    for r in range(n_rows):
        lo, hi = row_ptr[r], min(row_ptr[r + 1], row_ptr[r] + k_max)
        n = hi - lo
        ecols[r, :n] = cols[lo:hi]
        evals[r, :n] = vals[lo:hi]
    return jnp.asarray(ecols), jnp.asarray(evals)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "ring", "interpret"))
def spmv_ell(ecols: jax.Array, evals: jax.Array, x: jax.Array,
             block_rows: int = 256, block_cols: int = 1024,
             ring: str = "plus_times", interpret: bool = True) -> jax.Array:
    """y = A ⊕.⊗ x with A in ELL (n_rows, k_max)."""
    n_rows, _ = ecols.shape
    n_cols = x.shape[0]
    rpad = (-n_rows) % block_rows
    cpad = (-n_cols) % block_cols
    if rpad:
        ecols = jnp.pad(ecols, ((0, rpad), (0, 0)), constant_values=-1)
        evals = jnp.pad(evals, ((0, rpad), (0, 0)))
    if cpad:
        x = jnp.pad(x, (0, cpad))
    grid = ((n_rows + rpad) // block_rows, (n_cols + cpad) // block_cols)
    out = pl.pallas_call(
        functools.partial(_spmv_ell_kernel, block_cols=block_cols,
                          ring=ring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, ecols.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_rows, evals.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_cols,), lambda r, c: (c,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda r, c: (r,)),
        out_shape=jax.ShapeDtypeStruct((n_rows + rpad,), jnp.float32),
        interpret=interpret,
    )(ecols, evals, x)
    return out[:n_rows]
