"""Pallas TPU kernel: ELL-format semiring SpMV (PageRank / background model).

CSR's per-row ragged nnz is hostile to the MXU; the TPU adaptation packs
rows to ELL (fixed ``k_max`` nnz per row, zero-padded — D4M incidence
matrices are near-regular: one nnz per header field).  The gather
``x[cols]`` is realized as a one-hot matmul per nnz-slot, so the whole
kernel is dense systolic work:

    y[r] ⊕= Σ_k vals[r,k] ⊗ (onehot(cols[r,k]) @ x_tile)

Grid: (row blocks, col tiles); col-tile dimension is sequential so the
VMEM accumulator is race-free.  plus_times and max_times semirings; for
max_times the accumulator starts at -inf and padding slots are masked,
so signed products reduce correctly (empty rows resolve to 0, the
sparse no-entry convention).

``interpret`` auto-selects by backend: compiled on TPU, interpreter
everywhere else (the kernel targets Mosaic; CPU/GPU runs validate
semantics, TPU runs take the MXU path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _spmv_ell_kernel(cols_ref, vals_ref, x_ref, out_ref, *,
                     block_cols: int, ring: str):
    ct = pl.program_id(1)

    @pl.when(ct == 0)
    def _init():
        if ring == "plus_times":
            out_ref[...] = jnp.zeros_like(out_ref)
        else:                    # max_times identity is -inf, not 0 —
            # a 0 floor would silently clamp negative products
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    cols = cols_ref[...]                         # (BR, Kmax) int32
    vals = vals_ref[...].astype(jnp.float32)     # (BR, Kmax)
    x = x_ref[...].astype(jnp.float32)           # (block_cols,)
    base = ct * block_cols
    local = cols - base
    br, kmax = cols.shape
    acc = out_ref[...]
    iota = jax.lax.broadcasted_iota(jnp.int32, (br, block_cols), 1)
    for k in range(kmax):            # Kmax is small and static — unrolled
        onehot = (iota == local[:, k][:, None]).astype(jnp.float32)
        gathered = jnp.dot(onehot, x[:, None],
                           preferred_element_type=jnp.float32)[:, 0]
        if ring == "plus_times":
            acc = acc + vals[:, k] * gathered
        else:                        # max_times
            # padding cols are -1, so local < 0 on every tile — the
            # mask excludes both padding and out-of-tile slots
            hit = (local[:, k] >= 0) & (local[:, k] < block_cols)
            acc = jnp.where(hit, jnp.maximum(acc, vals[:, k] * gathered),
                            acc)
    if ring != "plus_times":
        # last col tile: rows with no entries anywhere stay at the
        # -inf identity — resolve them to 0 (sparse no-entry value)
        is_last = ct == pl.num_programs(1) - 1
        acc = jnp.where(is_last & jnp.isneginf(acc), 0.0, acc)
    out_ref[...] = acc


class EllOverflowError(ValueError):
    """A CSR row holds more entries than the ELL pack's ``k_max``.

    Truncating would silently drop nnz (wrong query answers), so the
    pack refuses by default.  Raise ``k_max`` (the device lowering uses
    ``max(nnz per row)``), route the payload through the CSR/COO path
    instead, or pass ``on_overflow='truncate'`` to accept the loss
    explicitly (top-k style sketches only).
    """

    def __init__(self, n_over: int, worst: int, k_max: int):
        self.n_over = n_over
        self.worst = worst
        self.k_max = k_max
        super().__init__(
            f"{n_over} row(s) exceed k_max={k_max} (worst row has "
            f"{worst} nnz): truncation would silently drop entries — "
            f"raise k_max, use the CSR/COO path, or pass "
            f"on_overflow='truncate' to accept the loss")


def csr_to_ell(row_ptr, cols, vals, n_rows: int, k_max: int,
               on_overflow: str = "raise"):
    """Host-side CSR→ELL pack (pad to k_max nnz per row) — fully
    vectorized scatter, no Python row loop.

    Rows with more than ``k_max`` entries cannot be represented: the
    default ``on_overflow='raise'`` surfaces :class:`EllOverflowError`
    instead of silently truncating; ``'truncate'`` keeps the first
    ``k_max`` entries per row (explicit lossy opt-in).
    """
    if on_overflow not in ("raise", "truncate"):
        raise ValueError(f"on_overflow must be 'raise' or 'truncate', "
                         f"got {on_overflow!r}")
    row_ptr = np.asarray(row_ptr, dtype=np.int64)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    counts = np.diff(row_ptr)
    if on_overflow == "raise" and counts.size and counts.max() > k_max:
        over = counts > k_max
        raise EllOverflowError(int(over.sum()), int(counts.max()), k_max)
    ecols = np.full((n_rows, k_max), -1, np.int32)
    evals = np.zeros((n_rows, k_max), np.float32)
    keep = np.minimum(counts, k_max)
    total = int(keep.sum())
    if total:
        rows = np.repeat(np.arange(n_rows), keep)
        offs = np.arange(total) - np.repeat(np.cumsum(keep) - keep, keep)
        src = np.repeat(row_ptr[:-1], keep) + offs
        ecols[rows, offs] = cols[src]
        evals[rows, offs] = vals[src]
    return jnp.asarray(ecols), jnp.asarray(evals)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "ring", "interpret"))
def spmv_ell(ecols: jax.Array, evals: jax.Array, x: jax.Array,
             block_rows: int = 256, block_cols: int = 1024,
             ring: str = "plus_times",
             interpret: Optional[bool] = None) -> jax.Array:
    """y = A ⊕.⊗ x with A in ELL (n_rows, k_max).

    ``interpret=None`` (default) compiles on TPU and interprets on other
    backends; pass an explicit bool to force either mode.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_rows, _ = ecols.shape
    n_cols = x.shape[0]
    rpad = (-n_rows) % block_rows
    cpad = (-n_cols) % block_cols
    if rpad:
        ecols = jnp.pad(ecols, ((0, rpad), (0, 0)), constant_values=-1)
        evals = jnp.pad(evals, ((0, rpad), (0, 0)))
    if cpad:
        x = jnp.pad(x, (0, cpad))
    grid = ((n_rows + rpad) // block_rows, (n_cols + cpad) // block_cols)
    out = pl.pallas_call(
        functools.partial(_spmv_ell_kernel, block_cols=block_cols,
                          ring=ring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, ecols.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_rows, evals.shape[1]), lambda r, c: (r, 0)),
            pl.BlockSpec((block_cols,), lambda r, c: (c,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda r, c: (r,)),
        out_shape=jax.ShapeDtypeStruct((n_rows + rpad,), jnp.float32),
        interpret=interpret,
    )(ecols, evals, x)
    return out[:n_rows]
