"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum_ref(ids: jax.Array, vals: jax.Array, num_segments: int):
    return jax.ops.segment_sum(vals.astype(jnp.float32), ids,
                               num_segments=num_segments)


def spmv_ell_ref(ecols: jax.Array, evals: jax.Array, x: jax.Array,
                 ring: str = "plus_times"):
    """y[r] = ⊕_k evals[r,k] ⊗ x[ecols[r,k]] (cols == -1 are padding)."""
    xg = jnp.where(ecols >= 0, x[jnp.maximum(ecols, 0)], 0.0)
    prods = evals.astype(jnp.float32) * xg.astype(jnp.float32)
    if ring == "plus_times":
        return jnp.sum(prods, axis=1)
    if ring == "max_times":
        # padding excluded via the -inf identity (a 0 floor would clamp
        # negative products); rows with no entries resolve to 0
        masked = jnp.where(ecols >= 0, prods, -jnp.inf)
        out = jnp.max(masked, axis=1)
        return jnp.where(jnp.isneginf(out), 0.0, out)
    raise ValueError(ring)


def spmm_ell_ref(ecols: jax.Array, evals: jax.Array, x: jax.Array,
                 ring: str = "plus_times"):
    """Y[r, j] = ⊕_k evals[r,k] ⊗ x[ecols[r,k], j] (cols == -1 pad)."""
    xg = jnp.where(ecols[..., None] >= 0,
                   x[jnp.maximum(ecols, 0)], 0.0)          # (R, K, B)
    prods = evals[..., None].astype(jnp.float32) * xg.astype(jnp.float32)
    if ring == "plus_times":
        return jnp.sum(prods, axis=1)
    if ring == "max_times":
        masked = jnp.where(ecols[..., None] >= 0, prods, -jnp.inf)
        out = jnp.max(masked, axis=1)
        return jnp.where(jnp.isneginf(out), 0.0, out)
    raise ValueError(ring)


def spgemm_sel_ref(ecols: jax.Array, evals: jax.Array, sel: jax.Array,
                   ring: str = "plus_times"):
    """Y[r, j] = ⊕_k evals[r,k] ⊗ [ecols[r,k] == sel[j]] — the masked
    column-select SpGEMM (one-hot mask matrix, built densely here)."""
    hit = (ecols[..., None] == sel[None, None, :]) & \
          (ecols[..., None] >= 0)                          # (R, K, B)
    vals = evals[..., None].astype(jnp.float32)
    if ring == "plus_times":
        return jnp.sum(jnp.where(hit, vals, 0.0), axis=1)
    if ring == "max_times":
        out = jnp.max(jnp.where(hit, vals, -jnp.inf), axis=1)
        return jnp.where(jnp.isneginf(out), 0.0, out)
    raise ValueError(ring)


def flash_attention_ref(q, k, v, causal=True, window=0):
    from ..models import layers as L
    b, sq = q.shape[:2]
    sk = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    return L.attention_naive(q, k, v, q_pos, k_pos, causal, window)


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t, h_0 = 0 — sequential oracle."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, jnp.zeros((a.shape[0], a.shape[2]),
                                         jnp.float32), (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)


def wkv6_ref(r, k, v, w, u):
    from ..models.blocks import wkv_scan
    b, s, h, dh = r.shape
    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    out, _ = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), w.astype(jnp.float32),
                      u.astype(jnp.float32), state0)
    return out.astype(r.dtype)
