"""Pallas TPU kernel: RG-LRU blocked linear scan (recurrentgemma).

The recurrence h_t = a_t ⊙ h_{t-1} + b_t is channel-parallel — perfect
for the VPU's (8, 128) vector tiles — but time-sequential.  The kernel
tiles channels across the grid and runs time inside the body in blocks
of ``block_t``, keeping the running state in VMEM scratch.  Within a
time block the scan is a log-depth doubling (Blelloch) over VMEM tiles,
so each HBM round-trip covers ``block_t`` steps.

Grid: (batch, channel tiles, time blocks) — time sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)     # (block_t, Ct)
    bb = b_ref[0].astype(jnp.float32)

    # log-depth inclusive scan of the affine maps (a, b) over time
    seq = a.shape[0]
    av, bv = a, bb
    shift = 1
    while shift < seq:
        a_prev = jnp.pad(av, ((shift, 0), (0, 0)),
                         constant_values=1.0)[:seq]
        b_prev = jnp.pad(bv, ((shift, 0), (0, 0)))[:seq]
        av, bv = av * a_prev, bv + av * b_prev
        shift *= 2
    # compose with the carried state: h_t = A_t · h_in + B_t
    h_in = h_ref[...]
    h_all = av * h_in[None, :] + bv
    o_ref[0] = h_all.astype(o_ref.dtype)
    h_ref[...] = h_all[-1]


@functools.partial(jax.jit, static_argnames=("block_t", "block_c",
                                             "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, block_t: int = 256,
               block_c: int = 512, interpret: bool = True) -> jax.Array:
    """Inclusive scan h_t = a_t h_{t-1} + b_t (h_0 = 0).
    a, b: (B, S, C) → (B, S, C)."""
    bsz, s, c = a.shape
    block_t = min(block_t, s)
    block_c = min(block_c, c)
    assert s % block_t == 0 and c % block_c == 0
    grid = (bsz, c // block_c, s // block_t)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda b_, c_, t: (b_, t, c_)),
            pl.BlockSpec((1, block_t, block_c), lambda b_, c_, t: (b_, t, c_)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c),
                               lambda b_, c_, t: (b_, t, c_)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        interpret=interpret,
    )(a, b)
