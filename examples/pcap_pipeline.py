"""The paper's full pipeline as a user script (the "135 lines" artifact).

Everything an analyst writes to go from raw compressed captures to a
queryable edge database with degree tables — uncompress → split → parse
→ sort → sparse → ingest — plus the Fig. 2 connection query and the
botnet detection the paper's analytics enable.

Run:  PYTHONPATH=src python examples/pcap_pipeline.py
"""
import glob
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import analytics
from repro.core.assoc import Assoc
from repro.db import MultiInstanceDB
from repro.pipeline import (PipelineConfig, TrafficConfig, botnet_truth,
                            run_pipeline)

workdir = tempfile.mkdtemp(prefix="d4m_pipeline_")

# --- configure the capture + cluster ------------------------------------
traffic = TrafficConfig(
    n_hosts=256,            # the visible host population
    pkt_rate=150.0,         # packets/second on the tap
    n_bots=12,              # injected botnet (ground truth for eval)
    beacon_period_s=5.0,
    seed=42,
)
cfg = PipelineConfig(
    workdir=workdir,
    n_files=2,              # capture files (the paper used 385)
    duration_per_file_s=45.0,
    split_size=128 * 1024,  # the paper's 5 MB splits, scaled down
    traffic=traffic,
    n_workers=4,            # worker pool (the paper used 24,640 cores)
)

# --- the paper's §IV-F topology: parallel 16-tablet instances ------------
db = MultiInstanceDB(n_instances=2, tablets_per_instance=4)

# --- run all six stages (journaled: re-running resumes) ------------------
stats = run_pipeline(cfg, db)
print("pipeline stages:")
for stage, st in stats["stages"].items():
    if "bytes_in" in st and st["bytes_in"]:
        print(f"  {stage:10s} {st['bytes_in']:>10d}B → {st['bytes_out']:>10d}B"
              f"  ({st['bytes_out'] / st['bytes_in']:.2f}x)")
print(f"database entries: {stats['db_entries']}")

# --- Fig. 2: find a host's connections straight from the database --------
truth = botnet_truth(traffic)
c2 = truth["c2"]
conns = db.connections(c2)
print(f"\nconnections of {c2}: {len(conns)} hosts "
      f"(degree {db.degree(f'ip.dst|{c2}'):.0f})")

# --- load the incidence matrix and run the analytics ---------------------
E = Assoc()
for path in sorted(glob.glob(os.path.join(workdir, "*.E.npz"))):
    E = E + Assoc.load(path)
print(f"incidence matrix: {E.shape[0]} packets x {E.shape[1]} field|values")

report = analytics.detect_c2(E, top_k=5)
print("\nC2 candidates (fused fan-in x periodicity x port-concentration):")
for host, score, fanin in zip(report.hosts, report.scores, report.fanin):
    marker = "  <-- injected C2" if host == c2 else ""
    print(f"  {host:16s} score={score:6.3f} fanin={fanin:4.0f}{marker}")

assert c2 in list(report.hosts[:3]), "detection failed"
print("\ninjected C2 recovered from the traffic. pipeline complete.")
