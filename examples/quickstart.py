"""Quickstart: associative arrays in five minutes (paper §II-B, Fig. 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import Assoc, StartsWith, graph, parse_tsv, val2col

# --- a tiny packet-header table (what stage 3 produces) -------------------
tsv = """id\tip.src\tip.dst\ttcp.dstport
p001\t1.1.1.1\t2.2.2.2\t80
p002\t1.1.1.1\t3.3.3.3\t443
p003\t2.2.2.2\t1.1.1.1\t80
p004\t3.3.3.3\t2.2.2.2\t6667
"""
A = parse_tsv(tsv)              # dense associative array (packets × fields)
print("dense table:\n", A, "\n")

# --- the D4M schema: explode into the sparse incidence matrix -------------
E = val2col(A, "|")             # columns become field|value, entries 1
print("incidence matrix:\n", E, "\n")

# --- Fig. 2's operation: who talked to 1.1.1.1? ---------------------------
conns = graph.connections(E, "1.1.1.1")
print("connections of 1.1.1.1:\n", conns, "\n")

# --- graph construction: adjacency = E_src' * E_dst ------------------------
Adj = graph.adjacency(E)
print("directed adjacency:\n", Adj, "\n")

# --- degree table (stage 6's TedgeDeg) -------------------------------------
deg = graph.degree_table(E)
print("degree table:\n", deg, "\n")

# --- algebra: select, filter, correlate ------------------------------------
src_block = E[:, StartsWith("ip.src|")]
print("src block has", src_block.nnz, "entries")
heavy = Adj > 0.5               # threshold filter
print("edges:", list(zip(*heavy.triples()[:2])))

# --- device-side analytics: PageRank on the adjacency ----------------------
pr = graph.pagerank(graph.square(Adj).device_coo(jnp.float32), num_iters=20)
print("pagerank:", [f"{v:.3f}" for v in pr])
