"""End-to-end driver: D4M pipeline corpus → LM training → generation.

The framework integration the paper's Fig. 1 gestures at: the same
high-level environment runs the ingest pipeline AND trains/serves a
model on its output, with checkpoint/restart.  Uses a reduced rwkv6
config so it runs on CPU in a couple of minutes.

Run:  PYTHONPATH=src python examples/train_packet_lm.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.data import TokenStream
from repro.launch.serve import generate
from repro.launch.train import synth_corpus
from repro.models import init_params
from repro.train import OptConfig, adamw_init, make_train_step
from repro.launch.mesh import make_smoke_mesh
import jax.numpy as jnp

workdir = tempfile.mkdtemp(prefix="packet_lm_")

# --- stage the corpus through the pipeline --------------------------------
pattern = synth_corpus(os.path.join(workdir, "data"), n_files=2)
stream = TokenStream(pattern, seq_len=128, batch=4)

# --- train a reduced rwkv6 on packet logs ----------------------------------
cfg = smoke_config("rwkv6-1.6b")
mesh = make_smoke_mesh(len(jax.devices()))
params = init_params(cfg, jax.random.key(0))
opt_state = adamw_init(params)
step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=5),
                                  mesh), donate_argnums=(0, 1))
losses = []
with mesh:
    for step in range(30):
        batch = {k: jnp.minimum(jnp.asarray(v), cfg.vocab - 1)
                 for k, v in stream.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if step % 5 == 0:
            print(f"step {step:3d}  loss {losses[-1]:.4f}")

print(f"\nloss: {np.mean(losses[:5]):.3f} → {np.mean(losses[-5:]):.3f}")
assert np.mean(losses[-5:]) < np.mean(losses[:5]), "no learning?"

# --- generate packet-log-ish text -------------------------------------------
outs = generate(cfg, params, ["64.22."], max_new=24, s_max=192)
print("sample:", repr(outs[0]))
print("\ntrained on pipeline output; loss improved. done.")
