"""Deep-dive analytics over a captured window (paper §III-A references),
served entirely through the D4M database binding.

The window is ingested once — ``put(T, putval(E, '1,'))`` — and every
analytic below queries the database through ``DB``/``DBTable``
subscripts: column-block scans route through the transpose table
(TedgeT), the power-law background reads the combiner-maintained degree
table (TedgeDeg), and chained algebra over table queries builds a lazy
operator DAG that executes in one fused pass.

Run:  PYTHONPATH=src python examples/pcap_analytics.py
      PYTHONPATH=src python examples/pcap_analytics.py lsm /tmp/pcap_lsm

The optional arguments pick the storage engine from the backend
registry: ``memory`` (default, volatile) or ``lsm <path>`` — the
persistent store, where a re-run against the same path reopens the
previous window from disk (WAL replay + sorted runs) instead of
re-ingesting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import analytics
from repro.core import parse_tsv, val2col
from repro.db import DB, put
from repro.pipeline import TrafficConfig, botnet_truth
from repro.pipeline.pcap import records_to_tsv, synth_packets

backend = sys.argv[1] if len(sys.argv) > 1 else "memory"
path = sys.argv[2] if len(sys.argv) > 2 else (
    os.path.join("/tmp", "pcap_analytics_lsm") if backend == "lsm" else None)

# --- capture a window and ingest it ----------------------------------------
traffic = TrafficConfig(n_hosts=512, pkt_rate=400.0, n_bots=16,
                        beacon_period_s=4.0, seed=7)

T = DB('Tedge', 'TedgeT', 'TedgeDeg', backend=backend, path=path,
       n_instances=2, tablets_per_instance=4)
if T.n_entries:
    print(f"[{backend}] reopened existing store at {path} "
          f"({T.n_entries} entries recovered — skipping ingest)")
else:
    rec = synth_packets(traffic, 60.0)
    E = val2col(parse_tsv(records_to_tsv(rec)))
    put(T, E.putval("1,"))
    T.flush()   # durable backends fsync here (the commit point)
    del E  # everything below reads back through the binding

window = T[:, :].eval()
print(f"window: {window.shape[0]} packets, {window.shape[1]} field|values "
      f"({T.n_entries} db entries)")

# --- dimensional analysis [25] ---------------------------------------------
print("\nfield structure:")
for field, st in analytics.field_stats(window).items():
    print(f"  {field:22s} card={st['cardinality']:6d} "
          f"H={st['entropy_bits']:6.2f} bits")
print("top correlated field pairs:",
      analytics.top_correlated_pairs(window, top_k=3))

# --- power-law background [26] — straight from TedgeDeg --------------------
fit = analytics.fit_degree_table(T, "ip.dst|")
print(f"\nrank-size fit (from degree table): alpha={float(fit.alpha):.2f} "
      f"R2={float(fit.r2):.3f} (internet traffic ~ powerlaw)")

# --- anomaly detection — detectors query the table directly ----------------
truth = botnet_truth(traffic)
rep = analytics.detect_c2(T, top_k=5)
print(f"\ninjected C2: {truth['c2']} on port {truth['c2_port']}")
for h, s in zip(rep.hosts, rep.scores):
    print(f"  candidate {h:16s} score={s:.3f}"
          + ("   <-- C2" if h == truth["c2"] else ""))

scanners = analytics.scan_detect(T, min_fanout=24)
print("scan-like sources:", scanners[:5] if len(scanners) else "none")

# --- Fig. 2: one host's connections as a lazy chain over column scans ------
c2 = truth["c2"]
touched = (T[:, f"ip.src|{c2},"].sum(1) + T[:, f"ip.dst|{c2},"].sum(1))
conns = (touched.logical().T * T[:, "ip.dst|*,"]
         ) + (touched.logical().T * T[:, "ip.src|*,"])
print(f"\nconnections of {c2}: {conns.eval().nnz} field|value endpoints "
      f"(scan routing: {T.stats})")

# --- centrality [23] — mesh-sharded PageRank from the binding --------------
hosts, pr = analytics.distributed.pagerank_table(T, num_iters=30)
top = np.argsort(np.asarray(pr))[::-1][:5]
print("\ntop PageRank hosts:")
for i in top:
    print(f"  {hosts[i]:16s} {float(pr[i]):.4f}")
