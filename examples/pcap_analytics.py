"""Deep-dive analytics over a captured window (paper §III-A references).

Power-law background modeling [26], dimensional analysis [25], scan
detection, and PageRank centrality [23] over the incidence matrix.

Run:  PYTHONPATH=src python examples/pcap_analytics.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro import analytics
from repro.core import StartsWith, graph, parse_tsv, val2col
from repro.pipeline import TrafficConfig, botnet_truth
from repro.pipeline.pcap import records_to_tsv, synth_packets

# --- capture a window ------------------------------------------------------
traffic = TrafficConfig(n_hosts=512, pkt_rate=400.0, n_bots=16,
                        beacon_period_s=4.0, seed=7)
rec = synth_packets(traffic, 60.0)
E = val2col(parse_tsv(records_to_tsv(rec)))
print(f"window: {E.shape[0]} packets, {E.shape[1]} field|values")

# --- dimensional analysis [25] ---------------------------------------------
print("\nfield structure:")
for field, st in analytics.field_stats(E).items():
    print(f"  {field:22s} card={st['cardinality']:6d} "
          f"H={st['entropy_bits']:6.2f} bits")
print("top correlated field pairs:",
      analytics.top_correlated_pairs(E, top_k=3))

# --- power-law background [26] ----------------------------------------------
deg = E[:, StartsWith("ip.dst|")].sum(0)
d = jnp.asarray(np.asarray(deg.triples()[2], np.float32))
fit = analytics.fit_rank_size(d)
print(f"\nrank-size fit: alpha={float(fit.alpha):.2f} "
      f"R2={float(fit.r2):.3f} (internet traffic ~ powerlaw)")

# --- anomaly detection -------------------------------------------------------
truth = botnet_truth(traffic)
rep = analytics.detect_c2(E, top_k=5)
print(f"\ninjected C2: {truth['c2']} on port {truth['c2_port']}")
for h, s in zip(rep.hosts, rep.scores):
    print(f"  candidate {h:16s} score={s:.3f}"
          + ("   <-- C2" if h == truth["c2"] else ""))

scanners = analytics.scan_detect(E, min_fanout=24)
print("scan-like sources:", scanners[:5] if len(scanners) else "none")

# --- centrality [23] ----------------------------------------------------------
adj = graph.square(graph.adjacency(E))
pr = graph.pagerank(adj.device_coo(jnp.float32), num_iters=30)
top = np.argsort(np.asarray(pr))[::-1][:5]
print("\ntop PageRank hosts:")
for i in top:
    print(f"  {adj.row[i]:16s} {float(pr[i]):.4f}")
