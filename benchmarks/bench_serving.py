"""Serving benchmarks: the analytics gateway under mixed load, plus the
LM serving-loop smoke.

Gateway section (the paper's operational story — many analysts querying
while ingest streams in):

* ``gateway_read_p50/p99`` — 8 concurrent reader threads against a
  quiesced table (read-only baseline).
* ``gateway_mixed_p50/p99`` — the same readers while a WriterPool
  ingest thread streams edges through the shared backend; the snapshot
  read barrier keeps reader latency bounded by *preceding* writes only.
* ``gateway_shed_429`` — a rate-limited tenant hammering concurrently;
  asserts the limiter sheds (429s > 0) **without** degrading the
  admitted tenant's p99 more than 2x over the read-only baseline.

Coalescing section (the batched-analytics serving story):

* ``gateway_uncoalesced_*`` / ``gateway_coalesced_*`` — 8 concurrent
  column readers requesting *distinct* keys (so the ScanCache never
  serves them) against a ``coalesce_window=0`` gateway vs a windowed
  one; the windowed gateway folds each concurrent wave into one
  ``eval_batch`` union scan, collapsing tablet traffic ~8x at the cost
  of the window wait.  Asserts coalescing actually fired and that the
  coalesced band did strictly fewer column scans.

LM section: batched prefill + decode tok/s at smoke scale.  Not a TPU
number — the roofline table covers target-hardware serving.
"""
from __future__ import annotations

import http.client
import json
import threading
import time

import numpy as np

from .common import emit, smoke, write_trajectory

N_READERS = 8
PATHS = ("/v1/topk?prefix=ip.dst|&k=10",
         "/v1/scan?axis=col&prefix=ip.dst|&max_cells=200")


def _percentiles(lat: list) -> tuple:
    a = np.sort(np.asarray(lat, np.float64))
    return (float(a[int(0.50 * (len(a) - 1))]),
            float(a[int(0.99 * (len(a) - 1))]))


def _reader(addr: str, token: str, n_reqs: int, out: list,
            codes: list) -> None:
    host, port = addr.split(":")
    c = http.client.HTTPConnection(host, int(port), timeout=60)
    hdr = {"Authorization": f"Bearer {token}"}
    for i in range(n_reqs):
        t0 = time.perf_counter()
        c.request("GET", PATHS[i % len(PATHS)], headers=hdr)
        r = c.getresponse()
        r.read()
        codes.append(r.status)
        out.append(time.perf_counter() - t0)
    c.close()


def _run_readers(addr: str, token: str, n_reqs: int) -> tuple:
    lat: list = []
    codes: list = []
    ts = [threading.Thread(target=_reader,
                           args=(addr, token, n_reqs, lat, codes))
          for _ in range(N_READERS)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return lat, codes


def gateway_main() -> None:
    from repro.core.assoc import Assoc
    from repro.serve import Gateway, Tenant, TokenAuth
    from repro.serve.app import synthetic_incidence
    from repro.db import DB

    n_reqs = 12 if smoke() else 40
    T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
    T.put(synthetic_incidence(seed=7, duration=15.0 if smoke() else 60.0),
          sync=False)
    T.flush()
    gw = Gateway(T, TokenAuth({
        "bench": Tenant("bench", rate=1e6, burst=1e6),
        "limited": Tenant("limited", rate=2.0, burst=4.0),
    }), stats_interval=0.25)
    addr = gw.start()
    try:
        _run_readers(addr, "bench", 3)          # warm cache + fits
        # -- read-only baseline --------------------------------------------
        t0 = time.perf_counter()
        lat, codes = _run_readers(addr, "bench", n_reqs)
        dt = time.perf_counter() - t0
        assert all(c == 200 for c in codes), f"baseline errors: {codes}"
        base_p50, base_p99 = _percentiles(lat)
        emit("gateway_read_p50", base_p50 * 1e6,
             f"req_per_s={len(lat) / dt:.0f}",
             p50_s=base_p50, p99_s=base_p99, n_readers=N_READERS)
        emit("gateway_read_p99", base_p99 * 1e6, "")

        # -- mixed load: ingest streaming + limited tenant hammering -------
        stop = threading.Event()

        def ingest():
            # streams new edges under its own column prefix: realistic
            # arriving data that doesn't evict the analysts' hot band
            # (write-path invalidation is band-selective)
            i = 0
            while not stop.is_set():
                rows = np.asarray([f"bench{i}-{j}" for j in range(50)],
                                  str)
                T.put(Assoc(rows, np.asarray(["ingest|bench"] * 50, str),
                            np.asarray(["1"] * 50)), sync=False)
                i += 1
                time.sleep(0.005)

        shed_codes: list = []

        def hammer():
            host, port = addr.split(":")
            c = http.client.HTTPConnection(host, int(port), timeout=60)
            while not stop.is_set():
                c.request("GET", PATHS[0],
                          headers={"Authorization": "Bearer limited"})
                r = c.getresponse()
                r.read()
                shed_codes.append(r.status)
                time.sleep(0.01)
            c.close()

        side = [threading.Thread(target=ingest),
                threading.Thread(target=hammer)]
        for t in side:
            t.start()
        try:
            t0 = time.perf_counter()
            lat, codes = _run_readers(addr, "bench", n_reqs)
            dt = time.perf_counter() - t0
        finally:
            stop.set()
            for t in side:
                t.join()
        assert all(c == 200 for c in codes), f"mixed-load errors: {codes}"
        mix_p50, mix_p99 = _percentiles(lat)
        n_shed = shed_codes.count(429)
        emit("gateway_mixed_p50", mix_p50 * 1e6,
             f"req_per_s={len(lat) / dt:.0f}",
             p50_s=mix_p50, p99_s=mix_p99, n_readers=N_READERS)
        emit("gateway_mixed_p99", mix_p99 * 1e6,
             f"vs_baseline={mix_p99 / max(base_p99, 1e-9):.2f}x")
        emit("gateway_shed_429", n_shed,
             f"limited_reqs={len(shed_codes)}", n_429=n_shed)
        # the limiter must shed, and shedding must not be what keeps the
        # admitted tenant fast: p99 within 2x of read-only (+50ms noise
        # floor for CI-sized runs)
        assert n_shed > 0, "rate limiter never sheded the limited tenant"
        limit = max(2.0 * base_p99, base_p99 + 0.05)
        assert mix_p99 <= limit, \
            f"admitted-tenant p99 degraded: {mix_p99:.3f}s > {limit:.3f}s"
    finally:
        gw.stop()


def _coalesce_reader(addr: str, token: str, band: str, r: int,
                     n_iters: int, barrier: threading.Barrier,
                     lat: list, codes: list) -> None:
    host, port = addr.split(":")
    c = http.client.HTTPConnection(host, int(port), timeout=60)
    hdr = {"Authorization": f"Bearer {token}"}
    for i in range(n_iters):
        barrier.wait()               # the 8 readers fire as one wave
        t0 = time.perf_counter()
        c.request("GET",
                  f"/v1/scan?axis=col&prefix={band}|{i}-{r}&max_cells=50",
                  headers=hdr)
        resp = c.getresponse()
        resp.read()
        codes.append(resp.status)
        lat.append(time.perf_counter() - t0)
    c.close()


def coalesce_main() -> None:
    from repro.core.assoc import Assoc
    from repro.db import DB
    from repro.serve import Gateway, Tenant, TokenAuth

    n_iters = 6 if smoke() else 20
    T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
    # one private band per gateway config so the second run can't be
    # served out of cache entries the first run populated
    rows, cols = [], []
    for band in ("u", "c"):
        for i in range(n_iters):
            for r in range(N_READERS):
                for j in range(4):
                    rows.append(f"p{band}{i}-{r}-{j}")
                    cols.append(f"{band}|{i}-{r}")
    T.put(Assoc(np.asarray(rows, str), np.asarray(cols, str),
                np.ones(len(rows))), sync=False)
    T.flush()

    scans = {}
    for band, label, window in (("u", "uncoalesced", 0.0),
                                ("c", "coalesced", 0.02)):
        gw = Gateway(T, TokenAuth({
            "bench": Tenant("bench", rate=1e6, burst=1e6),
        }), stats_interval=0.25, coalesce_window=window)
        addr = gw.start()
        try:
            scans0 = T.stats["col"]
            barrier = threading.Barrier(N_READERS)
            lat: list = []
            codes: list = []
            ts = [threading.Thread(
                target=_coalesce_reader,
                args=(addr, "bench", band, r, n_iters, barrier, lat, codes))
                for r in range(N_READERS)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert all(s == 200 for s in codes), f"{label} errors: {codes}"
            scans[label] = T.stats["col"] - scans0
            p50, p99 = _percentiles(lat)
            cst = gw.coalescer.stats()
            emit(f"gateway_{label}_p50", p50 * 1e6,
                 f"col_scans={scans[label]} n_batches={cst['n_batches']}",
                 p50_s=p50, p99_s=p99, col_scans=scans[label],
                 n_batches=cst["n_batches"],
                 n_coalesced=cst["n_coalesced"], n_solo=cst["n_solo"],
                 max_batch=cst["max_batch"])
            emit(f"gateway_{label}_p99", p99 * 1e6, "")
            if label == "coalesced":
                assert cst["n_batches"] >= 1, \
                    "coalescing window never formed a batch"
        finally:
            gw.stop()
    # the point of the exercise: same 8-reader load, fewer tablet scans
    assert scans["coalesced"] < scans["uncoalesced"], \
        f"coalescing saved no scans: {scans}"
    emit("gateway_coalesce_scan_ratio",
         scans["uncoalesced"] / max(scans["coalesced"], 1),
         f"{scans['uncoalesced']} -> {scans['coalesced']} col scans",
         scans_uncoalesced=scans["uncoalesced"],
         scans_coalesced=scans["coalesced"])


def lm_main() -> None:
    import jax

    from repro.configs import smoke_config
    from repro.launch.serve import generate
    from repro.models import init_params

    for arch in ("h2o-danube-1.8b", "rwkv6-1.6b"):
        cfg = smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        prompts = ["ip.src|1.1.1.1", "tcp.dstport|6667", "10.0.0.", "a"]
        generate(cfg, params, prompts, max_new=4, s_max=96)  # warm
        t0 = time.perf_counter()
        n_new = 16
        generate(cfg, params, prompts, max_new=n_new, s_max=96)
        dt = time.perf_counter() - t0
        toks = n_new * len(prompts)
        emit(f"serve_smoke_{arch.replace('-', '_').replace('.', '_')}",
             dt / toks * 1e6, f"tok_per_s={toks / dt:.1f}")


def main() -> None:
    gateway_main()
    coalesce_main()
    lm_main()
    write_trajectory("serving")


if __name__ == "__main__":
    main()
