"""Serving throughput (smoke scale): batched prefill + decode tok/s.

Not a TPU number — the roofline table covers target-hardware serving;
this verifies the serving loop end-to-end and gives the CPU-smoke rate.
"""
from __future__ import annotations

import time

import jax

from repro.configs import smoke_config
from repro.launch.serve import generate
from repro.models import init_params

from .common import emit


def main() -> None:
    for arch in ("h2o-danube-1.8b", "rwkv6-1.6b"):
        cfg = smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        prompts = ["ip.src|1.1.1.1", "tcp.dstport|6667", "10.0.0.", "a"]
        generate(cfg, params, prompts, max_new=4, s_max=96)  # warm
        t0 = time.perf_counter()
        n_new = 16
        generate(cfg, params, prompts, max_new=n_new, s_max=96)
        dt = time.perf_counter() - t0
        toks = n_new * len(prompts)
        emit(f"serve_smoke_{arch.replace('-', '_').replace('.', '_')}",
             dt / toks * 1e6, f"tok_per_s={toks / dt:.1f}")


if __name__ == "__main__":
    main()
