"""Paper §IV-A/C/D — per-stage data expansion factors.

The paper reports: 700 GB compressed → 2.3 TB uncompressed (~3.3×),
then ~10× on dense-array construction (2.3 TB → 20 TB).  We measure the
same per-stage byte accounting on synthetic traffic.
"""
from __future__ import annotations

import shutil
import tempfile

from repro.db import EdgeStore
from repro.pipeline import PipelineConfig, TrafficConfig, run_pipeline

from .common import emit


def main() -> None:
    d = tempfile.mkdtemp(prefix="bench_expansion_")
    try:
        cfg = PipelineConfig(
            workdir=d, n_files=2, duration_per_file_s=1.0,
            split_size=96 * 1024,
            traffic=TrafficConfig(n_hosts=128, pkt_rate=4000.0, seed=3),
            n_workers=2)
        stats = run_pipeline(cfg, EdgeStore(n_tablets=4))
        order = ["uncompress", "split", "parse", "sort", "sparse"]
        for stage in order:
            st = stats["stages"].get(stage, {})
            bi, bo = st.get("bytes_in", 0), st.get("bytes_out", 0)
            if bi:
                emit(f"expansion_{stage}", 0.0,
                     f"in={bi}B;out={bo}B;factor={bo / bi:.2f}x")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
