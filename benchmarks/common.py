"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time


def timeit(fn, *, repeat: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn()."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
