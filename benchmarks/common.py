"""Shared benchmark utilities: timing, CSV emission, and machine-readable
JSON trajectory files (``BENCH_<name>.json``, one run appended per line)."""
from __future__ import annotations

import json
import os
import time

_RECORDS: list = []


def timeit(fn, *, repeat: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn()."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def smoke() -> bool:
    """True in CI's reduced-size bench smoke mode (BENCH_SMOKE=1)."""
    return os.environ.get("BENCH_SMOKE") == "1"


def emit(name: str, us_per_call: float, derived: str = "",
         **metrics) -> None:
    """Print the CSV line and record it (plus structured ``metrics`` like
    ``entries_per_s`` or ``cache_hit_rate``) for :func:`write_trajectory`."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 3),
                     "derived": derived, **metrics})


def write_trajectory(bench: str) -> str:
    """Append this run's records to ``BENCH_<bench>.json`` (JSONL — one
    run object per line, so successive runs form a trajectory).  The
    output directory defaults to cwd; override with BENCH_OUT_DIR."""
    path = os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                        f"BENCH_{bench}.json")
    run = {"bench": bench, "unix_time": round(time.time(), 3),
           "smoke": smoke(), "records": list(_RECORDS)}
    with open(path, "a") as f:
        f.write(json.dumps(run) + "\n")
    _RECORDS.clear()
    return path
