"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see each module's
docstring for the paper artifact it reproduces):

* bench_pipeline_scaling — Fig. 5 (stage speedup vs workers)
* bench_ingest           — §IV-F (multi-instance DB topology)
* bench_expansion        — §IV-A/C/D (per-stage data expansion)
* bench_loc              — §IV-G (135-line user pipeline claim)
* bench_query            — Fig. 2 (connection queries)
* bench_lsm              — persistent LSM backend vs memory (+ recovery)
* bench_net              — networked shard backend (batched RPC ingest,
                           chunk-streamed scans, sync barrier)
* bench_analytics        — §III-A (device-side graph algebra)
* bench_kernels          — Pallas kernels vs oracles
* bench_stream           — streaming rollup tap overhead + detector
                           latency per closed window
* bench_obs              — metrics/tracing overhead gates (untraced
                           hot path ≤5%, traced ≤25%)
"""
from __future__ import annotations

import traceback


def main() -> None:
    from . import (bench_analytics, bench_expansion, bench_ingest,
                   bench_kernels, bench_loc, bench_lsm, bench_net,
                   bench_obs, bench_pipeline_scaling, bench_query,
                   bench_serving, bench_stream)
    print("name,us_per_call,derived")
    for mod in (bench_loc, bench_expansion, bench_query, bench_ingest,
                bench_lsm, bench_net, bench_analytics, bench_kernels,
                bench_serving, bench_stream, bench_obs,
                bench_pipeline_scaling):
        try:
            mod.main()
        except Exception:
            print(f"{mod.__name__},FAILED,")
            traceback.print_exc()


if __name__ == "__main__":
    main()
