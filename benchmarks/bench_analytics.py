"""Paper §III-A analytics menu — device-side graph algebra throughput.

The compute hot path of every analytic is semiring SpMV / segment
reduction over the incidence matrix; these run compiled (XLA CPU here,
Pallas on TPU) and scale with nnz.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import COO, graph, spmv
from repro.analytics import powerlaw

from .common import emit, timeit


def main() -> None:
    rng = np.random.default_rng(0)
    for nnz in (10_000, 100_000, 1_000_000):
        n = nnz // 10
        m = COO.from_numpy(rng.integers(0, n, nnz), rng.integers(0, n, nnz),
                           np.ones(nnz, np.float32), (n, n))
        x = jnp.ones((n,), jnp.float32)
        spmv(m, x).block_until_ready()
        t = timeit(lambda: spmv(m, x).block_until_ready(), repeat=5)
        emit(f"spmv_nnz_{nnz}", t * 1e6,
             f"gnnz_per_s={nnz / t / 1e9:.3f}")
        pr = graph.pagerank(m, num_iters=20)
        t = timeit(lambda: graph.pagerank(m, num_iters=20)
                   .block_until_ready(), repeat=3)
        emit(f"pagerank20_nnz_{nnz}", t * 1e6,
             f"edges_x_iters_per_s={nnz * 20 / t / 1e9:.3f}G")
    deg = jnp.asarray(rng.pareto(1.3, 100_000).astype(np.float32))
    t = timeit(lambda: powerlaw.fit_rank_size(deg).alpha.block_until_ready(),
               repeat=5)
    emit("powerlaw_fit_100k", t * 1e6,
         f"alpha={float(powerlaw.fit_rank_size(deg).alpha):.3f}")


if __name__ == "__main__":
    main()
