"""Paper Fig. 2 — 'find 1.1.1.1's connections' in three systems, plus the
lazy deferred-algebra executor vs eager Assoc stepping.

Measures the same query through (a) the Assoc algebra (the D4M form),
(b) the database via legacy row scans, (c) the ``DB``/``DBTable``
binding (transpose-table routed column query), and (d) a chained
column-query workload executed eagerly (one materialized Assoc per
step) vs lazily (one fused pass over the operator DAG).  The lazy-fused
path must be no slower than eager on (d) — CI smoke-runs this module.
"""
from __future__ import annotations

import numpy as np

from repro.core import Assoc, graph, lazy
from repro.db import DB, EdgeStore, put
from repro.pipeline import TrafficConfig, botnet_truth
from repro.pipeline.pcap import records_to_tsv, synth_packets
from repro.core.schema import parse_tsv, val2col

from .common import emit, timeit


def main() -> None:
    tcfg = TrafficConfig(n_hosts=256, pkt_rate=3000.0, seed=9)
    rec = synth_packets(tcfg, 1.0)
    E = val2col(parse_tsv(records_to_tsv(rec)))
    db = EdgeStore(n_tablets=4)
    T = DB("Tedge", "TedgeT", "TedgeDeg", backend=db)
    put(T, E.putval("1,"))
    ip = botnet_truth(tcfg)["c2"]

    t = timeit(lambda: graph.connections(E, ip), repeat=5)
    n = len(graph.connections(E, ip).col)
    emit("fig2_query_assoc_algebra", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: db.connections(ip), repeat=5)
    n = len(db.connections(ip))
    emit("fig2_query_database", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: T[:, f"ip.dst|{ip},"].eval(), repeat=5)
    n = T[:, f"ip.dst|{ip},"].eval().nnz
    emit("fig2_query_binding_col", t * 1e6, f"n_packets={n}")

    t = timeit(lambda: db.degree(f"ip.dst|{ip}"), repeat=5)
    emit("fig2_degree_lookup", t * 1e6, f"deg={db.degree(f'ip.dst|{ip}')}")

    # --- lazy vs eager on the column-query workload ----------------------
    # The D4M correlation idiom, written the way analysts write it — the
    # column subscript appears twice in the chain:
    #     (T[:, 'ip.dst|*,'].logical().T * T[:, 'ip.dst|*,'].logical()) > k
    # Eager semantics materialize per step: two transpose-table scans, a
    # host Assoc per stage, and a full string-triple rebuild for the
    # comparison.  The lazy executor CSEs the repeated subscript into one
    # scan and fuses the elementwise stages into a single csr pass.
    k = 2.0
    csel = "ip.dst|*,"

    def eager_db_chain():
        return ((T[:, csel].eval().logical().T
                 * T[:, csel].eval().logical()) > k) * 2.0

    def lazy_db_chain():
        return (((T[:, csel].logical().T
                  * T[:, csel].logical()) > k) * 2.0).eval()

    assert eager_db_chain() == lazy_db_chain(), \
        "lazy/eager semantics diverged"
    te = timeit(eager_db_chain, repeat=5)
    tl = timeit(lazy_db_chain, repeat=5)
    emit("colquery_db_chain_eager", te * 1e6, f"nnz={eager_db_chain().nnz}")
    emit("colquery_db_chain_lazy", tl * 1e6,
         f"speedup_vs_eager={te / max(tl, 1e-12):.2f}x")

    # Same chain over an in-memory Assoc with the subscript hoisted by
    # hand — no scan to share, so this isolates fusion overhead: lazy
    # must hold parity even with nothing structural to exploit.
    def eager_mem_chain():
        L = E[:, csel].logical()
        return ((L.T * L) > k) * 2.0

    def lazy_mem_chain():
        L = lazy(E)[:, csel].logical()
        return (((L.T * L) > k) * 2.0).eval()

    assert eager_mem_chain() == lazy_mem_chain()
    te = timeit(eager_mem_chain, repeat=5)
    tl = timeit(lazy_mem_chain, repeat=5)
    emit("colquery_mem_chain_eager", te * 1e6, "")
    emit("colquery_mem_chain_lazy_fused", tl * 1e6,
         f"speedup_vs_eager={te / max(tl, 1e-12):.2f}x")


if __name__ == "__main__":
    main()
