"""Paper Fig. 2 — 'find 1.1.1.1's connections' in three systems, plus the
lazy deferred-algebra executor vs eager Assoc stepping, plus the
binding-layer TTL scan cache on a repeated hot column band.

Measures the same query through (a) the Assoc algebra (the D4M form),
(b) the database via legacy row scans, (c) the ``DB``/``DBTable``
binding (transpose-table routed column query), and (d) a chained
column-query workload executed eagerly (one materialized Assoc per
step) vs lazily (one fused pass over the operator DAG).  The lazy-fused
path must be no slower than eager on (d), and the cached repeat of a
column-band scan in (e) must be ≥ 5x the uncached scan — both
CI smoke-run via this module (BENCH_SMOKE=1 reduces sizes).

Sections (a)-(d) bind with ``cache_ttl=0`` so they keep measuring the
raw scan paths; section (e) is the cache measurement.  Emits a JSON
trajectory to ``BENCH_query.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core import Assoc, graph, lazy
from repro.db import DB, DBTable, EdgeStore, put
from repro.pipeline import TrafficConfig, botnet_truth
from repro.pipeline.pcap import records_to_tsv, synth_packets
from repro.core.schema import parse_tsv, val2col

from .common import emit, smoke, timeit, write_trajectory


def main() -> None:
    n_hosts, rate = (128, 1500.0) if smoke() else (256, 3000.0)
    tcfg = TrafficConfig(n_hosts=n_hosts, pkt_rate=rate, seed=9)
    rec = synth_packets(tcfg, 1.0)
    E = val2col(parse_tsv(records_to_tsv(rec)))
    db = EdgeStore(n_tablets=4)
    T = DB("Tedge", "TedgeT", "TedgeDeg", backend=db, cache_ttl=0)
    put(T, E.putval("1,"))
    ip = botnet_truth(tcfg)["c2"]

    t = timeit(lambda: graph.connections(E, ip), repeat=5)
    n = len(graph.connections(E, ip).col)
    emit("fig2_query_assoc_algebra", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: db.connections(ip), repeat=5)
    n = len(db.connections(ip))
    emit("fig2_query_database", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: T[:, f"ip.dst|{ip},"].eval(), repeat=5)
    n = T[:, f"ip.dst|{ip},"].eval().nnz
    emit("fig2_query_binding_col", t * 1e6, f"n_packets={n}")

    t = timeit(lambda: db.degree(f"ip.dst|{ip}"), repeat=5)
    emit("fig2_degree_lookup", t * 1e6, f"deg={db.degree(f'ip.dst|{ip}')}")

    # --- lazy vs eager on the column-query workload ----------------------
    # The D4M correlation idiom, written the way analysts write it — the
    # column subscript appears twice in the chain:
    #     (T[:, 'ip.dst|*,'].logical().T * T[:, 'ip.dst|*,'].logical()) > k
    # Eager semantics materialize per step: two transpose-table scans, a
    # host Assoc per stage, and a full string-triple rebuild for the
    # comparison.  The lazy executor CSEs the repeated subscript into one
    # scan and fuses the elementwise stages into a single csr pass.
    k = 2.0
    csel = "ip.dst|*,"

    def eager_db_chain():
        return ((T[:, csel].eval().logical().T
                 * T[:, csel].eval().logical()) > k) * 2.0

    def lazy_db_chain():
        return (((T[:, csel].logical().T
                  * T[:, csel].logical()) > k) * 2.0).eval()

    assert eager_db_chain() == lazy_db_chain(), \
        "lazy/eager semantics diverged"
    te = timeit(eager_db_chain, repeat=5)
    tl = timeit(lazy_db_chain, repeat=5)
    emit("colquery_db_chain_eager", te * 1e6, f"nnz={eager_db_chain().nnz}")
    emit("colquery_db_chain_lazy", tl * 1e6,
         f"speedup_vs_eager={te / max(tl, 1e-12):.2f}x",
         speedup_vs_eager=te / max(tl, 1e-12))

    # Same chain over an in-memory Assoc with the subscript hoisted by
    # hand — no scan to share, so this isolates fusion overhead: lazy
    # must hold parity even with nothing structural to exploit.
    def eager_mem_chain():
        L = E[:, csel].logical()
        return ((L.T * L) > k) * 2.0

    def lazy_mem_chain():
        L = lazy(E)[:, csel].logical()
        return (((L.T * L) > k) * 2.0).eval()

    assert eager_mem_chain() == lazy_mem_chain()
    te = timeit(eager_mem_chain, repeat=5)
    tl = timeit(lazy_mem_chain, repeat=5)
    emit("colquery_mem_chain_eager", te * 1e6, "")
    emit("colquery_mem_chain_lazy_fused", tl * 1e6,
         f"speedup_vs_eager={te / max(tl, 1e-12):.2f}x",
         speedup_vs_eager=te / max(tl, 1e-12))

    # --- (e) TTL scan cache on a repeated hot column band ----------------
    # Tc (cached) and Tun (uncached view of the SAME store) issue the
    # identical band query; the cached repeat must serve from memory.
    Tc = DB("Tedge", "TedgeT", "TedgeDeg", backend=db, cache_ttl=300.0)
    Tun = DBTable(db, ("Tedge", "TedgeT", "TedgeDeg"), cache_ttl=0)
    band = "ip.dst|*,"

    A_uncached = Tun[:, band].eval()
    t_uncached = timeit(lambda: Tun[:, band].eval(), repeat=5)
    A_cached = Tc[:, band].eval()          # prime (miss)
    t_cached = timeit(lambda: Tc[:, band].eval(), repeat=5)

    # correctness: cache hit must equal the uncached scan
    ru, cu, vu = A_uncached.triples()
    rc, cc, vc = A_cached.triples()
    assert (np.array_equal(ru, rc) and np.array_equal(cu, cc)
            and np.array_equal(np.asarray(vu, str), np.asarray(vc, str))), \
        "cached column-band result diverged from uncached scan"

    hits, misses = Tc.stats["cache_hit"], Tc.stats["cache_miss"]
    hit_rate = hits / max(hits + misses, 1)
    speedup = t_uncached / max(t_cached, 1e-12)
    emit("colband_query_uncached", t_uncached * 1e6,
         f"nnz={A_uncached.nnz}")
    emit("colband_query_cached", t_cached * 1e6,
         f"speedup_vs_uncached={speedup:.1f}x;hit_rate={hit_rate:.2f}",
         speedup_vs_uncached=speedup, cache_hit_rate=hit_rate,
         cache_hits=hits, cache_misses=misses)
    assert speedup >= 5.0, \
        f"cache hit only {speedup:.2f}x over uncached scan"

    write_trajectory("query")


if __name__ == "__main__":
    main()
