"""Paper Fig. 2 — 'find 1.1.1.1's connections' in three systems.

Measures the same query through (a) the Assoc algebra (the D4M form) and
(b) the database (Accumulo-analog row scans via the transpose table).
"""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.core import Assoc, graph
from repro.db import EdgeStore
from repro.pipeline import TrafficConfig, botnet_truth, stages
from repro.pipeline.pcap import records_to_tsv, synth_packets
from repro.core.schema import parse_tsv, val2col

from .common import emit, timeit


def main() -> None:
    tcfg = TrafficConfig(n_hosts=256, pkt_rate=3000.0, seed=9)
    rec = synth_packets(tcfg, 1.0)
    E = val2col(parse_tsv(records_to_tsv(rec)))
    db = EdgeStore(n_tablets=4)
    db.put(E.putval("1,"))
    ip = botnet_truth(tcfg)["c2"]

    t = timeit(lambda: graph.connections(E, ip), repeat=5)
    n = len(graph.connections(E, ip).col)
    emit("fig2_query_assoc_algebra", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: db.connections(ip), repeat=5)
    n = len(db.connections(ip))
    emit("fig2_query_database", t * 1e6, f"n_connections={n}")

    t = timeit(lambda: db.degree(f"ip.dst|{ip}"), repeat=5)
    emit("fig2_degree_lookup", t * 1e6, f"deg={db.degree(f'ip.dst|{ip}')}")


if __name__ == "__main__":
    main()
