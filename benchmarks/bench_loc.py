"""Paper §IV-G — "the entire pipeline ... in approximately 135 lines".

Counts the non-comment, non-blank lines of the user-facing pipeline
example (the analog artifact: what an analyst writes, not the library).
"""
from __future__ import annotations

import os

from .common import emit


def count_loc(path: str) -> int:
    n = 0
    with open(path) as f:
        in_doc = False
        for line in f:
            ls = line.strip()
            if ls.startswith('"""') or ls.startswith("'''"):
                if not (in_doc is False and ls.endswith(('"""', "'''"))
                        and len(ls) > 3):
                    in_doc = not in_doc
                continue
            if in_doc or not ls or ls.startswith("#"):
                continue
            n += 1
    return n


def main() -> None:
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    target = os.path.join(here, "examples", "pcap_pipeline.py")
    loc = count_loc(target)
    emit("loc_user_pipeline", 0.0, f"loc={loc};paper_claim=135")


if __name__ == "__main__":
    main()
