"""Persistent LSM backend vs the in-memory store: ingest rate, column
queries, and reopen/recovery timing.

The LSM engine (``repro.db.lsmstore``) pays WAL appends + memtable
maintenance on the write path and run merges on the read path in
exchange for durability — this benchmark quantifies the exchange rate
against the volatile ``EdgeStore`` topology on identical workloads:

* **ingest** — async binding ``put`` (writer pool, flush barrier as the
  fsync commit point) into memory vs LSM, entries/sec;
* **column query** — the Fig. 2 hot band (``T[:, 'ip.dst|*,']``,
  uncached) served from tablets vs memtable + sorted runs;
* **recovery** — reopen timing: WAL replay (kill before spill) and
  run-indexed open (after spill + compaction), plus a correctness check
  that the recovered store matches the memory run's entry count and
  degree sums exactly.

Emits a JSON trajectory to ``BENCH_lsm.json`` (CI smoke-runs this in a
tmpdir with BENCH_SMOKE=1).
"""
from __future__ import annotations

import shutil
import tempfile

from repro.db import DB, LSMStore

from .bench_ingest import make_batches
from .common import emit, smoke, timeit, write_trajectory


def fresh_lsm_table(path: str, n_instances: int):
    shutil.rmtree(path, ignore_errors=True)
    return DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm", path=path,
              n_instances=n_instances, cache_ttl=0)


def main() -> None:
    n_batches, rows_per = (8, 200) if smoke() else (16, 400)
    n_instances = 2
    batches = make_batches(n_batches, rows_per)
    n_entries = sum(b.nnz for b in batches)
    root = tempfile.mkdtemp(prefix="bench_lsm_")

    def ingest(T):
        for b in batches:
            T.put(b, sync=False)
        T.flush()
        T.close()
        return T

    # -- ingest: memory vs LSM (same async write path, same topology) ------
    def mem_ingest():
        return ingest(DB("Tedge", "TedgeT", "TedgeDeg",
                         n_instances=n_instances, tablets_per_instance=4,
                         cache_ttl=0))

    def lsm_ingest():
        return ingest(fresh_lsm_table(f"{root}/ingest", n_instances))

    t_mem = timeit(mem_ingest, repeat=3)
    t_lsm = timeit(lsm_ingest, repeat=3)
    emit("lsm_ingest_memory_baseline", t_mem * 1e6,
         f"rate={n_entries / t_mem:.0f}_entries_per_s",
         entries_per_s=n_entries / t_mem)
    emit("lsm_ingest_wal_fsync", t_lsm * 1e6,
         f"rate={n_entries / t_lsm:.0f}_entries_per_s;"
         f"vs_memory={t_lsm / t_mem:.2f}x_cost",
         entries_per_s=n_entries / t_lsm, cost_vs_memory=t_lsm / t_mem)

    # -- column query: the Fig. 2 hot band, uncached -----------------------
    Tm = mem_ingest()
    Tl = ingest(fresh_lsm_table(f"{root}/query", n_instances))
    assert Tm.n_entries == Tl.n_entries, \
        f"LSM dropped entries: {Tl.n_entries} != {Tm.n_entries}"
    q_mem = timeit(lambda: Tm[:, "ip.dst|*,"].eval(), repeat=3)
    q_lsm = timeit(lambda: Tl[:, "ip.dst|*,"].eval(), repeat=3)
    nnz = Tm[:, "ip.dst|*,"].eval().nnz
    assert Tl[:, "ip.dst|*,"].eval().nnz == nnz
    emit("lsm_colquery_memory_baseline", q_mem * 1e6, f"nnz={nnz}")
    emit("lsm_colquery_sorted_runs", q_lsm * 1e6,
         f"nnz={nnz};vs_memory={q_lsm / q_mem:.2f}x_cost",
         cost_vs_memory=q_lsm / q_mem)

    # -- recovery: reopen from WAL vs from compacted runs ------------------
    deg_key = str(Tm.degree_assoc("ip.dst|").triples()[0][0])
    expect_deg = Tm.degree(deg_key)
    path = f"{root}/query/db0"
    t_wal = timeit(lambda: LSMStore(path).close(), repeat=3)
    emit("lsm_reopen_wal_replay", t_wal * 1e6,
         f"entries={LSMStore(path).n_entries}")
    s = LSMStore(path)
    s.spill()
    s.compact()
    s.close()
    t_runs = timeit(lambda: LSMStore(path).close(), repeat=3)
    emit("lsm_reopen_compacted_runs", t_runs * 1e6,
         f"vs_wal={t_runs / max(t_wal, 1e-12):.2f}x")

    # recovered store == memory run (count + degree sums)
    Tr = DB("Tedge", "TedgeT", "TedgeDeg", backend="lsm",
            path=f"{root}/query", n_instances=n_instances, cache_ttl=0)
    assert Tr.n_entries == Tm.n_entries
    assert Tr.degree(deg_key) == expect_deg, \
        f"degree drift after recovery: {Tr.degree(deg_key)} != {expect_deg}"

    shutil.rmtree(root, ignore_errors=True)
    write_trajectory("lsm")


if __name__ == "__main__":
    main()
