"""Pallas kernel micro-benchmarks (interpret mode on CPU — correctness
path; wall numbers are NOT TPU perf, the roofline table covers that).
Compares each kernel's interpret-mode call against its compiled pure-jnp
oracle to document overhead and validate at benchmark shapes.

The SpMV-loop vs batched-SpMM section is the CI perf gate for the
batched analytics layer: answering b column queries as one SpMM launch
must beat b sequential SpMV launches (the per-query dispatch the
gateway used to pay) by ≥ 2x at b=8.  The roofline columns model the
TPU story: bytes/query collapse because the ELL block streams from HBM
once per *batch* instead of once per *query*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.segsum import segsum
from repro.kernels.flash_attention import flash_attention
from repro.kernels.spmm import spmm_ell
from repro.kernels.spmv import spmv_ell

from .common import emit, smoke, timeit, write_trajectory


def spmm_roofline() -> None:
    """SpMV-loop vs batched SpMM at b ∈ {1, 8, 64}: wall time (interpret
    mode — dispatch-bound, which is exactly what batching removes) plus
    the HBM-traffic roofline model (achieved GB/s vs TPU peak)."""
    from repro.launch.roofline import HBM_BW

    R, C, K = (1024, 1024, 4) if smoke() else (2048, 2048, 4)
    br, bc = 256, 1024
    rng = np.random.default_rng(42)
    ecols = jnp.asarray(rng.integers(0, C, (R, K)), jnp.int32)
    evals = jnp.asarray(rng.normal(0, 1, (R, K)).astype(np.float32))
    ell_bytes = R * K * (4 + 4)                 # cols int32 + vals f32

    ratio_at_8 = None
    for b in (1, 8) if smoke() else (1, 8, 64):
        X = jnp.asarray(rng.normal(0, 1, (C, b)).astype(np.float32))

        def loop():
            for j in range(b):
                spmv_ell(ecols, evals, X[:, j], block_rows=br,
                         block_cols=bc).block_until_ready()

        def batched():
            spmm_ell(ecols, evals, X, block_rows=br,
                     block_cols=bc).block_until_ready()

        # equivalence at bench shape before timing it
        Y = np.stack([np.asarray(spmv_ell(ecols, evals, X[:, j],
                                          block_rows=br, block_cols=bc))
                      for j in range(b)], axis=1)
        ok = np.allclose(np.asarray(spmm_ell(ecols, evals, X,
                                             block_rows=br, block_cols=bc)),
                         Y, atol=1e-4)
        t_loop = timeit(loop, repeat=3)
        t_spmm = timeit(batched, repeat=3)
        # HBM traffic model: the loop streams the ELL block per query,
        # the batch streams it once
        bytes_loop = b * (ell_bytes + C * 4 + R * 4)
        bytes_spmm = ell_bytes + C * b * 4 + R * b * 4
        gbs_loop = bytes_loop / t_loop / 1e9
        gbs_spmm = bytes_spmm / t_spmm / 1e9
        speedup = t_loop / t_spmm
        emit(f"spmv_loop_b{b}", t_loop / b * 1e6,
             f"allclose={ok} gbs={gbs_loop:.3f}",
             achieved_gb_s=round(gbs_loop, 4),
             peak_gb_s=HBM_BW / 1e9,
             pct_peak=round(100 * gbs_loop * 1e9 / HBM_BW, 4))
        emit(f"spmm_batched_b{b}", t_spmm / b * 1e6,
             f"speedup={speedup:.2f}x gbs={gbs_spmm:.3f}",
             achieved_gb_s=round(gbs_spmm, 4),
             peak_gb_s=HBM_BW / 1e9,
             pct_peak=round(100 * gbs_spmm * 1e9 / HBM_BW, 4),
             speedup_vs_loop=round(speedup, 3))
        if b == 8:
            ratio_at_8 = speedup
    # the CI gate: one launch for 8 queries ≥ 2x the 8-launch loop
    assert ratio_at_8 is not None and ratio_at_8 >= 2.0, \
        f"batched SpMM only {ratio_at_8:.2f}x the SpMV loop at b=8 (< 2x)"


def main() -> None:
    key = jax.random.key(0)
    ids = jnp.sort(jax.random.randint(key, (50_000,), 0, 4096))
    vals = jnp.ones((50_000,))
    out = segsum(ids, vals, 4096, block_nnz=2048, block_seg=1024)
    exp = ref.segsum_ref(ids, vals, 4096)
    ok = bool(jnp.allclose(out, exp, atol=1e-3))
    t = timeit(lambda: ref.segsum_ref(ids, vals, 4096).block_until_ready(),
               repeat=5)
    emit("segsum_oracle_50k", t * 1e6, f"kernel_allclose={ok}")

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    exp = ref.flash_attention_ref(q, k, v)
    ok = bool(jnp.allclose(out, exp, atol=1e-4))
    t = timeit(lambda: ref.flash_attention_ref(q, k, v).block_until_ready(),
               repeat=5)
    emit("flash_attn_oracle_256", t * 1e6, f"kernel_allclose={ok}")

    spmm_roofline()
    write_trajectory("kernels")


if __name__ == "__main__":
    main()
