"""Pallas kernel micro-benchmarks (interpret mode on CPU — correctness
path; wall numbers are NOT TPU perf, the roofline table covers that).
Compares each kernel's interpret-mode call against its compiled pure-jnp
oracle to document overhead and validate at benchmark shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.segsum import segsum
from repro.kernels.flash_attention import flash_attention

from .common import emit, timeit


def main() -> None:
    key = jax.random.key(0)
    ids = jnp.sort(jax.random.randint(key, (50_000,), 0, 4096))
    vals = jnp.ones((50_000,))
    out = segsum(ids, vals, 4096, block_nnz=2048, block_seg=1024)
    exp = ref.segsum_ref(ids, vals, 4096)
    ok = bool(jnp.allclose(out, exp, atol=1e-3))
    t = timeit(lambda: ref.segsum_ref(ids, vals, 4096).block_until_ready(),
               repeat=5)
    emit("segsum_oracle_50k", t * 1e6, f"kernel_allclose={ok}")

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, block_q=128, block_k=128)
    exp = ref.flash_attention_ref(q, k, v)
    ok = bool(jnp.allclose(out, exp, atol=1e-4))
    t = timeit(lambda: ref.flash_attention_ref(q, k, v).block_until_ready(),
               repeat=5)
    emit("flash_attn_oracle_256", t * 1e6, f"kernel_allclose={ok}")


if __name__ == "__main__":
    main()
