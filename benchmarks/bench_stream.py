"""Streaming analytics benchmarks: what the always-on path costs.

The design constraint from docs/api.md "Streaming analytics": the
rollup tap rides the WriterPool worker loop, so its cost lands on the
ingest path — it must stay a small fraction of the write cost itself.

* ``stream_ingest_base/tapped`` — the same scenario blocks through
  async ingest with and without a ``TemporalRollup`` tap attached,
  measured as interleaved base/tapped pairs (median of per-pair
  ratios, so per-process drift cancels); ``stream_tap_overhead``
  asserts the attached run stays within 10% (full mode; smoke-sized
  runs get a noise allowance) of the untapped baseline.
* ``stream_rollup_rate`` — raw ``TemporalRollup.ingest`` throughput
  (cells/s), no store underneath: the tap's own ceiling.
* ``stream_detector_per_window`` — full ``DetectorBank`` pass (SPC +
  scan + beacon sweeps) amortized per closed window, on a scenario with
  all three attack kinds firing.
* ``stream_root_cause`` — one reversed personalized-PageRank
  localization over an attack window slice.

Writes ``BENCH_stream.json`` via the shared trajectory writer.
"""
from __future__ import annotations

import time

from .common import emit, smoke, timeit, write_trajectory


def _scenario_cfg():
    from repro.stream import AttackSpec, ScenarioConfig
    dur = 30.0 if smoke() else 120.0
    rate = 60.0 if smoke() else 150.0
    return ScenarioConfig(
        duration_s=dur, n_hosts=64, base_rate=rate, seed=7,
        attacks=(
            AttackSpec("c2", start=2, duration=dur - 5, n_hosts=6,
                       period_s=2.0),
            AttackSpec("scan", start=dur * 0.3, duration=5, rate=60.0),
            AttackSpec("ddos", start=dur * 0.6, duration=5, n_hosts=8,
                       rate=40.0),
        ))


def ingest_overhead_main() -> None:
    from repro.db import DB
    from repro.stream import TemporalRollup, stream_blocks

    cfg = _scenario_cfg()
    blocks = list(stream_blocks(cfg))
    n_cells = sum(A.nnz for _, A in blocks)

    def run(tapped: bool, verify: bool = False) -> float:
        T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
        if tapped:
            roll = TemporalRollup()
            T.add_ingest_tap(roll.ingest)
        t0 = time.perf_counter()
        for _, A in blocks:
            T.put(A, sync=False)
        T.flush()
        dt = time.perf_counter() - t0
        if verify:
            # the tap saw exactly what the store did, or the number is
            # measuring a broken rollup (checked once, outside timing —
            # totals() folds degree sketches, which is read-path work)
            assert roll.totals("second")["n_cells"] == n_cells
        T.close()
        return dt

    # interleaved base/tapped pairs, summed: the two runs of a pair see
    # the same process state (allocator, caches), so slow per-process
    # drift cancels instead of landing on whichever variant runs last,
    # and summing over pairs averages out per-run scheduling noise
    # (~±6%, larger than the tap signal itself on a single pair)
    run(True, verify=True)                       # warmup + correctness
    n_pairs = 3 if smoke() else 8
    pairs = [(run(False), run(True)) for _ in range(n_pairs)]
    base = sum(b for b, _ in pairs) / n_pairs
    tap = sum(t for _, t in pairs) / n_pairs
    overhead = tap / base - 1.0
    emit("stream_ingest_base", base / len(blocks) * 1e6,
         f"cells={n_cells}", cells=n_cells, n_blocks=len(blocks),
         wall_s=round(base, 4))
    emit("stream_ingest_tapped", tap / len(blocks) * 1e6,
         f"overhead={overhead * 100:.1f}%", wall_s=round(tap, 4),
         overhead_frac=round(overhead, 4))
    emit("stream_tap_overhead", overhead * 100.0,
         f"cells_per_s={n_cells / tap:.0f}")
    # smoke-sized runs are noise-dominated (sub-second walls); the 10%
    # budget is asserted at full size, smoke gets an allowance
    limit = 0.50 if smoke() else 0.10
    assert overhead < limit, \
        f"ingest tap overhead {overhead * 100:.1f}% exceeds " \
        f"{limit * 100:.0f}% budget"


def rollup_rate_main() -> None:
    from repro.stream import TemporalRollup, stream_blocks

    blocks = [A.triples() for _, A in stream_blocks(_scenario_cfg())]
    n_cells = sum(r.shape[0] for r, _, _ in blocks)

    def run() -> None:
        roll = TemporalRollup()
        for r, c, v in blocks:
            roll.ingest(r, c, v)
        roll.close_due(force=True)

    dt = timeit(run, repeat=3)
    emit("stream_rollup_rate", dt / len(blocks) * 1e6,
         f"cells_per_s={n_cells / dt:.0f}", cells_per_s=n_cells / dt)


def detector_main() -> None:
    from repro.stream import DetectorBank, TemporalRollup, root_cause, \
        scenario_truth, stream_blocks

    cfg = _scenario_cfg()
    truth = scenario_truth(cfg)

    # warm the jit'd scoring cores out-of-band, then measure one cold
    # detector pass over every closed window
    for _ in range(2):
        roll = TemporalRollup()
        for _, A in stream_blocks(cfg):
            roll.ingest(*A.triples())
        bank = DetectorBank(roll)
        t0 = time.perf_counter()
        alerts = bank.process(force=True)
        dt = time.perf_counter() - t0
    n_windows = bank.stats()["n_windows"]
    assert n_windows > 0 and alerts
    emit("stream_detector_per_window", dt / n_windows * 1e6,
         f"windows={n_windows} alerts={len(alerts)}",
         n_windows=n_windows, n_alerts=len(alerts),
         wall_s=round(dt, 4))

    # one localization, few power iterations: the sharded SpMV loop
    # pays per-iteration dispatch overhead, so this is wall-dominated
    # by the mesh round-trips, not the tiny window graph
    att = truth["attacks"][2]            # the ddos
    rc_dt = timeit(lambda: root_cause(
        roll, att["start"] - 1.0, att["stop"] + 1.0,
        [att["victim"]], top_k=3, num_iters=10), repeat=1)
    emit("stream_root_cause", rc_dt * 1e6,
         f"hosts={len(att['attackers'])}")


def main() -> None:
    ingest_overhead_main()
    rollup_rate_main()
    detector_main()
    write_trajectory("stream")


if __name__ == "__main__":
    main()
