"""Paper §IV-F — ingest rate vs database topology.

Reproduces the paper's central database finding: multiple smaller
parallel Accumulo instances out-ingest one big instance (they ran
8×16-node instances rather than one 128-node).  We measure entries/sec
into (a) one EdgeStore with N tablets and (b) M parallel instances of
N/M tablets, with the instance-level coordination cost enabled — the
mechanism the paper attributes the effect to.
"""
from __future__ import annotations

import numpy as np

from repro.core.assoc import Assoc
from repro.db import EdgeStore, MultiInstanceDB

from .common import emit, timeit


def make_batches(n_batches: int = 16, rows_per: int = 400):
    rng = np.random.default_rng(0)
    batches = []
    for b in range(n_batches):
        pk = np.asarray([f"f{b:02d}|p{i:06d}" for i in range(rows_per)])
        field = rng.choice(["ip.src", "ip.dst", "tcp.dstport"], rows_per)
        val = rng.integers(0, 5000, rows_per).astype(str)
        cols = np.char.add(np.char.add(field, "|"), val)
        batches.append(Assoc(pk, cols, "1,"))
    return batches


def main() -> None:
    batches = make_batches()
    n_entries = sum(b.nnz for b in batches)

    # (a) one big instance (coordination cost grows with tablets)
    def one_big():
        db = EdgeStore(n_tablets=16, coordination_cost_s=2e-4)
        for i, b in enumerate(batches):
            db.put(b)
    t_big = timeit(one_big, repeat=3)
    emit("ingest_1x16_big_instance", t_big * 1e6,
         f"rate={n_entries / t_big:.0f}_entries_per_s")

    # (b) paper's topology: M parallel smaller instances
    for m, tabs in ((2, 8), (4, 4), (8, 2)):
        def multi(m=m, tabs=tabs):
            db = MultiInstanceDB(n_instances=m, tablets_per_instance=tabs,
                                 coordination_cost_s=2e-4)
            for i, b in enumerate(batches):
                db.put(b, file_id=f"f{i}")
        t = timeit(multi, repeat=3)
        emit(f"ingest_{m}x{tabs}_parallel_instances", t * 1e6,
             f"rate={n_entries / t:.0f}_entries_per_s;"
             f"vs_big={t_big / t:.2f}x")


if __name__ == "__main__":
    main()
