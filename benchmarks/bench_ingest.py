"""Paper §IV-F — ingest rate vs database topology, sync vs async writers.

Reproduces the paper's central database finding: multiple smaller
parallel Accumulo instances out-ingest one big instance (they ran
8×16-node instances rather than one 128-node).  We measure entries/sec
into (a) one EdgeStore with N tablets and (b) M parallel instances of
N/M tablets, with the instance-level coordination cost enabled — the
mechanism the paper attributes the effect to.

Section (c) measures the binding layer's write paths on the winning
multi-instance topology: synchronous ``DBTable.put`` (each batch blocks
through every instance's coordination stall in turn) vs the async
:class:`~repro.db.writer.WriterPool` (one writer thread per instance,
stalls overlap).  Async must be ≥ 1.5x sync entries/sec — asserted, and
enforced by CI's bench smoke job (BENCH_SMOKE=1).

Emits a JSON trajectory to ``BENCH_ingest.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core.assoc import Assoc
from repro.db import EdgeStore, MultiInstanceDB, bind

from .common import emit, smoke, timeit, write_trajectory


def make_batches(n_batches: int = 16, rows_per: int = 400):
    rng = np.random.default_rng(0)
    batches = []
    for b in range(n_batches):
        pk = np.asarray([f"f{b:02d}|p{i:06d}" for i in range(rows_per)])
        field = rng.choice(["ip.src", "ip.dst", "tcp.dstport"], rows_per)
        val = rng.integers(0, 5000, rows_per).astype(str)
        cols = np.char.add(np.char.add(field, "|"), val)
        batches.append(Assoc(pk, cols, "1,"))
    return batches


def main() -> None:
    n_batches, rows_per = (8, 200) if smoke() else (16, 400)
    batches = make_batches(n_batches, rows_per)
    n_entries = sum(b.nnz for b in batches)

    # (a) one big instance (coordination cost grows with tablets)
    def one_big():
        db = EdgeStore(n_tablets=16, coordination_cost_s=2e-4)
        for b in batches:
            db.put(b)
    t_big = timeit(one_big, repeat=3)
    emit("ingest_1x16_big_instance", t_big * 1e6,
         f"rate={n_entries / t_big:.0f}_entries_per_s",
         entries_per_s=n_entries / t_big)

    # (b) paper's topology: M parallel smaller instances
    for m, tabs in ((2, 8), (4, 4), (8, 2)):
        def multi(m=m, tabs=tabs):
            db = MultiInstanceDB(n_instances=m, tablets_per_instance=tabs,
                                 coordination_cost_s=2e-4)
            for j, b in enumerate(batches):
                db.put(b, file_id=f"f{j}")
        t = timeit(multi, repeat=3)
        emit(f"ingest_{m}x{tabs}_parallel_instances", t * 1e6,
             f"rate={n_entries / t:.0f}_entries_per_s;"
             f"vs_big={t_big / t:.2f}x",
             entries_per_s=n_entries / t, vs_big=t_big / t)

    # (c) sync vs async binding writers on the multi-instance topology.
    # The coordination stall dominates: sync pays it serially per
    # (batch × instance); the writer pool overlaps it across instances.
    coord = 2e-3

    def fresh_table():
        return bind(MultiInstanceDB(n_instances=8, tablets_per_instance=2,
                                    coordination_cost_s=coord),
                    cache_ttl=0)

    def sync_put():
        T = fresh_table()
        for b in batches:
            T.put(b)
        return T

    def async_put():
        T = fresh_table()
        for b in batches:
            T.put(b, sync=False)
        T.flush()
        T.close()
        return T

    # correctness: both paths land the same entries
    Ts, Ta = sync_put(), async_put()
    assert Ts.n_entries == Ta.n_entries, \
        f"async dropped entries: {Ta.n_entries} != {Ts.n_entries}"

    t_sync = timeit(sync_put, repeat=3)
    t_async = timeit(async_put, repeat=3)
    speedup = t_sync / max(t_async, 1e-12)
    emit("ingest_8x2_sync_binding", t_sync * 1e6,
         f"rate={n_entries / t_sync:.0f}_entries_per_s",
         entries_per_s=n_entries / t_sync)
    emit("ingest_8x2_async_binding", t_async * 1e6,
         f"rate={n_entries / t_async:.0f}_entries_per_s;"
         f"vs_sync={speedup:.2f}x",
         entries_per_s=n_entries / t_async, speedup_vs_sync=speedup)
    assert speedup >= 1.5, \
        f"async ingest regressed: only {speedup:.2f}x over sync"

    write_trajectory("ingest")


if __name__ == "__main__":
    main()
