"""Networked shard backend vs the in-process store: batched RPC ingest,
chunk-streamed column queries, and the cross-shard sync barrier.

This is the in-tree rerun of the orphaned ``BENCH_net.json`` experiment
(ROADMAP open item 1): shard servers over framed TCP
(``repro.db.netstore``), the binding/planner/cache/WriterPool unchanged
on top.  Three sections:

* **ingest** — naive per-put RPCs (one round trip per triple, what a
  synchronous remote store costs) vs batched puts through the async
  WriterPool (one RPC per coalesced block).  The batch path must be
  ≥ 10x — asserted; the prior experiment measured 10–35x;
* **column query** — the Fig. 2 hot band (``T[:, 'ip.dst|*,']``,
  uncached) served over chunk-streamed scans vs the local memory
  backend on the *same seed*, results asserted identical cell-for-cell
  (prior experiment: ~1.7–2.2x local cost);
* **sync barrier** — the cross-shard durability commit point: a clean
  barrier (no outstanding writes — what every binding read pays) vs a
  dirty one (fans an fsync RPC to every written shard).

Emits a JSON trajectory to ``BENCH_net.json`` (CI smoke-runs this with
BENCH_SMOKE=1).
"""
from __future__ import annotations

import time

import numpy as np

from repro.db import DB

from .bench_ingest import make_batches
from .common import emit, smoke, timeit, write_trajectory


def fresh_net_table(n_shards: int):
    return DB("Tedge", "TedgeT", "TedgeDeg", backend="net",
              n_instances=n_shards, cache_ttl=0)


def main() -> None:
    n_batches, rows_per = (6, 200) if smoke() else (10, 400)
    n_shards = 2
    batches = make_batches(n_batches, rows_per)
    n_entries = sum(b.nnz for b in batches)

    # -- ingest: per-put RPCs vs WriterPool-coalesced batched RPCs ---------
    triples = []
    for b in batches:
        r, c, v = b.triples()
        triples.append((r, c, np.asarray(v).astype(str)))

    def per_put_rpc(T):
        for r, c, v in triples:
            for i in range(r.shape[0]):         # one RPC per triple
                T.backend.put_triples(r[i:i + 1], c[i:i + 1], v[i:i + 1])
        T.backend.sync()

    def batched_rpc(T):
        for b in batches:
            T.put(b, sync=False)                # enqueue; blocks coalesce
        T.flush()                               # barrier: applied + synced
        T.close()

    def time_ingest(ingest, repeat=3):
        """Median wall seconds of the ingest alone — each run gets a
        fresh cluster, but spawn/teardown stay outside the clock (the
        section measures RPC amortization, not server lifecycle)."""
        times = []
        for _ in range(repeat + 1):             # first run = warmup
            T = fresh_net_table(n_shards)
            try:
                t0 = time.perf_counter()
                ingest(T)
                times.append(time.perf_counter() - t0)
            finally:
                T.backend.close()
        times = sorted(times[1:])
        return times[len(times) // 2]

    t_naive = time_ingest(per_put_rpc)
    t_batch = time_ingest(batched_rpc)
    speedup = t_naive / t_batch
    emit("net_ingest_per_put_rpc", t_naive * 1e6,
         f"rate={n_entries / t_naive:.0f}_entries_per_s",
         entries_per_s=n_entries / t_naive)
    emit("net_ingest_batched_rpc", t_batch * 1e6,
         f"rate={n_entries / t_batch:.0f}_entries_per_s;"
         f"speedup={speedup:.1f}x",
         entries_per_s=n_entries / t_batch, speedup_vs_per_put=speedup)
    assert speedup >= 10.0, \
        f"batched RPC ingest regressed to {speedup:.1f}x over per-put " \
        f"(the coalesced-block path should be >= 10x)"

    # -- column query: chunk-streamed scans vs local memory, same seed -----
    Tm = DB("Tedge", "TedgeT", "TedgeDeg", n_instances=n_shards,
            tablets_per_instance=4, cache_ttl=0)
    Tn = fresh_net_table(n_shards)
    try:
        for b in batches:
            Tm.put(b)
            Tn.put(b)
        a = Tm[:, "ip.dst|*,"].eval()
        b_ = Tn[:, "ip.dst|*,"].eval()
        # identical cell-for-cell: same rows, cols, values
        assert a.triples()[0].tolist() == b_.triples()[0].tolist()
        assert a.triples()[1].tolist() == b_.triples()[1].tolist()
        assert list(a.triples()[2]) == list(b_.triples()[2])
        q_mem = timeit(lambda: Tm[:, "ip.dst|*,"].eval(), repeat=3)
        q_net = timeit(lambda: Tn[:, "ip.dst|*,"].eval(), repeat=3)
        emit("net_colquery_memory_baseline", q_mem * 1e6, f"nnz={a.nnz}")
        emit("net_colquery_chunk_streamed", q_net * 1e6,
             f"nnz={b_.nnz};vs_local={q_net / q_mem:.2f}x_cost",
             cost_vs_local=q_net / q_mem)

        # -- sync barrier: clean gate vs dirty fan-out ---------------------
        Tn.flush()
        t_clean = timeit(Tn.backend.sync, repeat=3)
        one = (np.asarray(["px"]), np.asarray(["ip.dst|x"]),
               np.asarray(["1"]))

        def dirty_sync():
            Tn.backend.put_triples(*one)
            Tn.backend.sync()
        t_dirty = timeit(dirty_sync, repeat=3)
        emit("net_sync_barrier_clean", t_clean * 1e6,
             "client_side_dirty_gate")
        emit("net_sync_barrier_dirty", t_dirty * 1e6,
             f"fsync_fanout;vs_clean={t_dirty / max(t_clean, 1e-9):.0f}x")
    finally:
        Tm.close()
        Tn.backend.close()

    write_trajectory("net")


if __name__ == "__main__":
    main()
