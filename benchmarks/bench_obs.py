"""Observability overhead benchmarks — and the gates that keep the
metrics/tracing plane honest.

The obs design promise (see ``repro.obs``): layers instrument
unconditionally, and the *untraced* hot path pays one ContextVar read
per site.  Two gates enforce it:

* **traced-off ≤ 5 %**: the estimated cost of every no-op span a query
  would hit (measured no-op cost × spans-per-query) must stay under 5 %
  of the untraced query's wall time — i.e. the instrumentation is
  invisible when nobody asked for a trace.
* **traced-on ≤ 25 %**: the same ingest+query wave run inside an active
  trace (every span recorded) must stay within 1.25× of the untraced
  wave, measured as interleaved A/B pairs so drift hits both sides
  (plus a small absolute floor for CI-sized runs).

Emitted records: primitive costs (``obs_noop_span``,
``obs_counter_inc``) and the A/B wave (``obs_query_untraced`` /
``obs_query_traced`` / ``obs_untraced_overhead_pct``).
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit, smoke, timeit, write_trajectory


def _primitive_costs() -> float:
    """No-op span + counter-inc cost; returns no-op span seconds."""
    from repro.obs.metrics import Counter
    from repro.obs.trace import span

    n = 20_000 if smoke() else 200_000

    def noop_loop():
        for _ in range(n):
            with span("bench.noop"):
                pass

    noop_s = timeit(noop_loop) / n
    emit("obs_noop_span", noop_s * 1e6, f"ns={noop_s * 1e9:.0f}")

    c = Counter()

    def inc_loop():
        for _ in range(n):
            c.inc()

    inc_s = timeit(inc_loop) / n
    emit("obs_counter_inc", inc_s * 1e6, f"ns={inc_s * 1e9:.0f}")
    return noop_s


def main() -> None:
    from repro.core.assoc import Assoc
    from repro.db import DB
    from repro.obs.trace import Tracer
    from repro.serve.app import synthetic_incidence

    noop_s = _primitive_costs()

    # -- the ingest+query wave the gates run over ---------------------------
    T = DB("Tedge", "TedgeT", "TedgeDeg", tablets_per_instance=2)
    T.put(synthetic_incidence(seed=11,
                              duration=10.0 if smoke() else 30.0),
          sync=False)
    T.flush()
    seq = [0]

    def wave():
        i = seq[0]
        seq[0] += 1
        rows = np.asarray([f"obs{i}-{j}" for j in range(20)], str)
        T.put(Assoc(rows, np.asarray(["obs|bench"] * 20, str),
                    np.asarray(["1"] * 20)), sync=False)
        T[:, "ip.src|*,"].eval()        # hot band (cache-served)
        T[:, "obs|bench,"].eval()       # invalidated band (rescan)

    wave()
    wave()                              # warm caches + code paths

    # -- interleaved A/B: untraced vs fully-traced waves --------------------
    tracer = Tracer(max_traces=256, max_spans=512)
    pairs = 6 if smoke() else 30
    offs, ons = [], []
    for k in range(pairs):
        t0 = time.perf_counter()
        wave()
        offs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        with tracer.start(f"bench-wave-{k}"):
            wave()
        ons.append(time.perf_counter() - t0)
    off = sorted(offs)[len(offs) // 2]
    on = sorted(ons)[len(ons) // 2]
    ratio = on / off
    emit("obs_query_untraced", off * 1e6, "", p50_s=off)
    emit("obs_query_traced", on * 1e6, f"vs_untraced={ratio:.2f}x",
         p50_s=on, ratio=ratio)

    # spans one wave actually records (for the traced-off budget estimate)
    counting = Tracer()
    with counting.start("count"):
        wave()
    n_spans = counting.stats()["n_spans"]
    frac = n_spans * noop_s / off
    emit("obs_untraced_overhead_pct", frac * 100,
         f"n_spans_per_wave={n_spans}", n_spans=n_spans)

    # -- the gates ----------------------------------------------------------
    assert frac <= 0.05, (
        f"traced-off overhead {frac:.1%} of wave time exceeds the 5% "
        f"budget ({n_spans} spans x {noop_s * 1e9:.0f}ns no-op)")
    limit = max(1.25 * off, off + 0.002)    # 2ms floor for CI jitter
    assert on <= limit, (
        f"traced-on wave {on * 1e3:.2f}ms exceeds "
        f"{limit * 1e3:.2f}ms (untraced {off * 1e3:.2f}ms)")

    write_trajectory("obs")


if __name__ == "__main__":
    main()
