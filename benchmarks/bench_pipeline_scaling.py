"""Paper Fig. 5 — pipeline stage speedup vs worker count.

The paper shows near-linear speedup for stages 1–5 on up to 24,640
cores.  This container has ONE core, so the measurement here is the
*scheduling* scaling (thread workers over I/O-bound file tasks) plus the
paper-model extrapolation: each stage is embarrassingly parallel over
files, so modeled speedup = min(workers, n_files) for stages 1–5 and
min(db_cores, workers) for ingest — exactly the structure of Fig. 5.
"""
from __future__ import annotations

import shutil
import tempfile

from repro.db import EdgeStore
from repro.pipeline import PipelineConfig, TrafficConfig, run_pipeline

from .common import emit, timeit


def run(n_workers: int, workdir: str) -> dict:
    tcfg = TrafficConfig(n_hosts=64, pkt_rate=2000.0, seed=11)
    cfg = PipelineConfig(workdir=workdir, n_files=4,
                         duration_per_file_s=0.5, split_size=64 * 1024,
                         traffic=tcfg, n_workers=n_workers)
    db = EdgeStore(n_tablets=4)
    import time
    t0 = time.perf_counter()
    stats = run_pipeline(cfg, db)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "stages": stats["stages"]}


def main() -> None:
    base = None
    for w in (1, 2, 4):
        d = tempfile.mkdtemp(prefix=f"bench_scale_{w}_")
        try:
            r = run(w, d)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        if base is None:
            base = r["wall_s"]
        emit(f"fig5_pipeline_workers_{w}", r["wall_s"] * 1e6,
             f"speedup={base / r['wall_s']:.2f}x")
    # paper-model extrapolation (files ≫ workers, stages 1–5 par. over files)
    for cores in (385, 24640):
        emit(f"fig5_modeled_speedup_cores_{cores}", 0.0,
             f"modeled={min(cores, 500_000)}x_linear_over_files")


if __name__ == "__main__":
    main()
